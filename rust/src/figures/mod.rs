//! Shared driver code for the paper's figures/tables (used by the
//! `examples/fig*.rs` binaries and integration tests).
//!
//! Each function reproduces one evaluation cell: it builds a fresh-or-reused
//! [`Engine`] for a (model, environment, policy) triple, runs the scenario's
//! workload, and returns the paper's metric from the virtual clock.

use crate::config::serving::{Policy, ServingConfig};
use crate::config::{HardwareConfig, ModelConfig};
use crate::coordinator::Engine;
use crate::metrics::Aggregate;
use crate::workload::{Dataset, WorkloadGen};
use anyhow::Result;
use std::path::PathBuf;

/// The four systems of the paper's §4, in plot order.
pub const ALL_POLICIES: &[Policy] =
    &[Policy::Fiddler, Policy::MiiOffload, Policy::LruOffload, Policy::StaticSplit];

/// One measured cell of a figure.
#[derive(Clone, Debug)]
pub struct Cell {
    pub policy: Policy,
    pub env: String,
    pub inp: usize,
    pub out: usize,
    /// tokens/s (scenarios a, c) — end-to-end.
    pub tps: f64,
    /// TTFT in ms (scenario b, Fig. 11).
    pub ttft_ms: f64,
    /// mean ITL in ms (Fig. 12).
    pub itl_ms: f64,
}

pub fn artifact_dir(model: &str) -> PathBuf {
    crate::config::model::artifacts_root().join(model)
}

/// Build an engine for (model, env, policy) with paper-default knobs.
pub fn make_engine(model: &str, hw: &HardwareConfig, policy: Policy, seed: u64) -> Result<Engine> {
    let mut serving = ServingConfig {
        policy,
        seed,
        ..Default::default()
    };
    serving.ngl = ServingConfig::paper_ngl_for(&hw.name);
    Engine::new(artifact_dir(model), hw, serving)
}

/// Scenario (a): end-to-end single-request generation, fixed in/out lengths.
pub fn run_e2e_cell(
    engine: &mut Engine,
    dataset: &Dataset,
    inp: usize,
    out: usize,
    samples: usize,
    seed: u64,
) -> Result<Aggregate> {
    let mut agg = Aggregate::default();
    let mut gen = WorkloadGen::new(dataset.clone(), engine.model().vocab, seed);
    for _ in 0..samples {
        let prompt = gen.prompt(inp);
        let g = engine.generate(&prompt, out)?;
        agg.push(&g.metrics);
    }
    Ok(agg)
}

/// Scenario (b): long-prefill TTFT (ms).
pub fn run_prefill_cell(
    engine: &mut Engine,
    dataset: &Dataset,
    inp: usize,
    samples: usize,
    seed: u64,
) -> Result<f64> {
    let mut gen = WorkloadGen::new(dataset.clone(), engine.model().vocab, seed);
    let mut ttfts = Vec::new();
    for _ in 0..samples {
        let prompt = gen.prompt(inp);
        let (_tok, ttft_us) = engine.prefill_ttft(&prompt)?;
        ttfts.push(ttft_us / 1e3);
    }
    Ok(crate::util::stats::mean(&ttfts))
}

/// Scenario (c): beam-search tokens/s (output tokens / end-to-end latency).
pub fn run_beam_cell(
    engine: &mut Engine,
    dataset: &Dataset,
    width: usize,
    inp: usize,
    out: usize,
    seed: u64,
) -> Result<f64> {
    let mut gen = WorkloadGen::new(dataset.clone(), engine.model().vocab, seed);
    let prompt = gen.prompt(inp);
    let b = engine.beam_search(&prompt, width, out)?;
    Ok(b.metrics.tokens_per_s())
}

/// Geometric-mean speedup of `a` over `b` across paired cells.
pub fn geomean_ratio(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let log_sum: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x / y).ln())
        .sum();
    (log_sum / a.len() as f64).exp()
}

/// Print the Table-1 header for an environment (every driver shows it).
pub fn print_env_banner(hw: &HardwareConfig, cfg: &ModelConfig) {
    println!(
        "--- {} | GPU {} | CPU {} | PCIe transfer {:.1} ms/expert | \
         capacity {}/{} paper-scale experts (model: {} = {}/{} scaled) ---",
        hw.name,
        hw.gpu_name,
        hw.cpu_name,
        hw.weight_transfer_us() / 1e3,
        hw.gpu_expert_capacity(),
        256,
        cfg.name,
        ((cfg.total_experts() as f64 * hw.gpu_expert_capacity() as f64 / 256.0).round()
            as usize),
        cfg.total_experts(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_equal_is_one() {
        assert!((geomean_ratio(&[2.0, 3.0], &[2.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_ratio_scale() {
        let g = geomean_ratio(&[2.0, 8.0], &[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }
}
