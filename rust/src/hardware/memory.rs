//! GPU memory capacity accounting for expert residency.
//!
//! Tracks which (layer, expert) weights are resident in simulated GPU
//! memory.  Used both by the initialization-time placement (pinning) and by
//! the LRU-offloading baseline (dynamic residency with eviction).

use crate::config::HardwareConfig;
use std::collections::HashMap;

/// Identifies one expert of one layer.
pub type ExpertId = (usize, usize); // (layer, expert)

#[derive(Debug)]
pub struct GpuMemory {
    capacity_experts: usize,
    /// Resident experts -> logical timestamp of last use (for LRU).
    resident: HashMap<ExpertId, u64>,
    /// Pinned experts are never evicted (initialization-time placement).
    pinned: Vec<ExpertId>,
    tick: u64,
    pub transfers_in: u64,
    pub evictions: u64,
}

impl GpuMemory {
    pub fn new(hw: &HardwareConfig) -> Self {
        Self::with_capacity(hw.gpu_expert_capacity())
    }

    pub fn with_capacity(capacity_experts: usize) -> Self {
        GpuMemory {
            capacity_experts,
            resident: HashMap::new(),
            pinned: Vec::new(),
            tick: 0,
            transfers_in: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_experts
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn is_resident(&self, id: ExpertId) -> bool {
        self.resident.contains_key(&id)
    }

    pub fn is_pinned(&self, id: ExpertId) -> bool {
        self.pinned.contains(&id)
    }

    /// Pin `id` at initialization. Panics if capacity would be exceeded —
    /// placement must respect capacity by construction.
    pub fn pin(&mut self, id: ExpertId) {
        assert!(
            self.resident.len() < self.capacity_experts,
            "pin() beyond GPU capacity {}",
            self.capacity_experts
        );
        assert!(!self.is_resident(id), "pin() duplicate {id:?}");
        self.tick += 1;
        self.resident.insert(id, self.tick);
        self.pinned.push(id);
    }

    /// Mark a use of a resident expert (refreshes LRU position).
    pub fn touch(&mut self, id: ExpertId) {
        self.tick += 1;
        if let Some(t) = self.resident.get_mut(&id) {
            *t = self.tick;
        }
    }

    /// Bring `id` into GPU memory (dynamic path, used by offloading
    /// policies).  Evicts the least recently used unpinned expert if full.
    /// Returns true if a transfer occurred (i.e. it was not resident).
    pub fn fetch(&mut self, id: ExpertId) -> bool {
        if self.is_resident(id) {
            self.touch(id);
            return false;
        }
        if self.resident.len() >= self.capacity_experts {
            let victim = self
                .resident
                .iter()
                .filter(|(k, _)| !self.pinned.contains(*k))
                .min_by_key(|(_, &t)| t)
                .map(|(&k, _)| k);
            match victim {
                Some(v) => {
                    self.resident.remove(&v);
                    self.evictions += 1;
                }
                None => {
                    // Everything pinned: cannot cache this expert at all.
                    self.transfers_in += 1;
                    return true;
                }
            }
        }
        self.tick += 1;
        self.resident.insert(id, self.tick);
        self.transfers_in += 1;
        true
    }

    /// All currently resident experts (unordered).
    pub fn resident_experts(&self) -> Vec<ExpertId> {
        self.resident.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_respects_capacity() {
        let mut m = GpuMemory::with_capacity(2);
        m.pin((0, 0));
        m.pin((0, 1));
        assert_eq!(m.resident_count(), 2);
        assert!(m.is_resident((0, 0)));
    }

    #[test]
    #[should_panic]
    fn pin_over_capacity_panics() {
        let mut m = GpuMemory::with_capacity(1);
        m.pin((0, 0));
        m.pin((0, 1));
    }

    #[test]
    fn fetch_caches_and_counts() {
        let mut m = GpuMemory::with_capacity(2);
        assert!(m.fetch((0, 0))); // miss
        assert!(!m.fetch((0, 0))); // hit
        assert_eq!(m.transfers_in, 1);
    }

    #[test]
    fn lru_evicts_oldest_unpinned() {
        let mut m = GpuMemory::with_capacity(2);
        m.fetch((0, 0));
        m.fetch((0, 1));
        m.touch((0, 0)); // 1 is now LRU
        m.fetch((0, 2)); // evicts 1
        assert!(m.is_resident((0, 0)));
        assert!(!m.is_resident((0, 1)));
        assert!(m.is_resident((0, 2)));
        assert_eq!(m.evictions, 1);
    }

    #[test]
    fn pinned_never_evicted() {
        let mut m = GpuMemory::with_capacity(2);
        m.pin((9, 9));
        m.fetch((0, 0));
        m.fetch((0, 1)); // evicts (0,0), not the pinned one
        assert!(m.is_resident((9, 9)));
        assert!(!m.is_resident((0, 0)));
    }

    #[test]
    fn all_pinned_full_passthrough() {
        let mut m = GpuMemory::with_capacity(1);
        m.pin((0, 0));
        assert!(m.fetch((1, 1))); // transfer, but no eviction possible
        assert!(!m.is_resident((1, 1)));
        assert_eq!(m.evictions, 0);
    }
}
