//! Moved: expert residency accounting now lives in [`crate::expertcache`],
//! the single residency authority (capacity, pinning, eviction, async
//! transfer state, and counters).  This module remains as a compatibility
//! re-export for the old `GpuMemory` name.

pub use crate::expertcache::{ExpertCache as GpuMemory, ExpertId};
