//! Virtual clock: simulated time in microseconds.
//!
//! All latency figures reported by the serving engine come from this clock,
//! driven by the calibrated latency model — never from wall time (the
//! numerics run on whatever silicon hosts the test, which says nothing
//! about the paper's testbed).  Atomic so the metrics thread can read it
//! without locking the engine.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct VirtualClock {
    /// Nanoseconds (u64 so we can use atomics; µs precision suffices but
    /// ns avoids rounding drift when many small latencies accumulate).
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now_us(&self) -> f64 {
        self.now_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn now_ms(&self) -> f64 {
        self.now_us() / 1e3
    }

    /// Advance by `dur_us`; returns the new time in µs.
    pub fn advance_us(&self, dur_us: f64) -> f64 {
        assert!(dur_us >= 0.0, "time cannot go backwards (dur={dur_us})");
        let ns = (dur_us * 1e3).round() as u64;
        let newv = self.now_ns.fetch_add(ns, Ordering::Relaxed) + ns;
        newv as f64 / 1e3
    }

    /// Jump forward to `t_us` if it is in the future (idle wait).
    pub fn advance_to_us(&self, t_us: f64) {
        let target = (t_us * 1e3).round() as u64;
        let mut cur = self.now_ns.load(Ordering::Relaxed);
        while target > cur {
            match self.now_ns.compare_exchange_weak(
                cur,
                target,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn reset(&self) {
        self.now_ns.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let c = VirtualClock::new();
        assert_eq!(c.now_us(), 0.0);
        c.advance_us(5.5);
        assert!((c.now_us() - 5.5).abs() < 1e-9);
        c.advance_us(0.0);
        assert!((c.now_us() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn advance_to_never_rewinds() {
        let c = VirtualClock::new();
        c.advance_us(100.0);
        c.advance_to_us(50.0);
        assert!((c.now_us() - 100.0).abs() < 1e-9);
        c.advance_to_us(200.0);
        assert!((c.now_us() - 200.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn negative_advance_panics() {
        VirtualClock::new().advance_us(-1.0);
    }

    #[test]
    fn concurrent_advances_accumulate() {
        use std::sync::Arc;
        let c = Arc::new(VirtualClock::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.advance_us(1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((c.now_us() - 4000.0).abs() < 1e-6);
    }
}
