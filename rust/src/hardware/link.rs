//! PCIe link cost accounting: weight and activation transfers.

use crate::config::HardwareConfig;

#[derive(Debug, Default, Clone)]
pub struct LinkStats {
    pub weight_transfers: u64,
    pub weight_bytes: u64,
    pub act_transfers: u64,
    pub act_bytes: u64,
}

/// Simulated PCIe link between CPU memory and GPU memory.
#[derive(Debug)]
pub struct PcieLink {
    hw: HardwareConfig,
    stats: LinkStats,
}

impl PcieLink {
    pub fn new(hw: &HardwareConfig) -> Self {
        PcieLink { hw: hw.clone(), stats: LinkStats::default() }
    }

    /// Cost (µs) of moving one paper-scale expert's weights CPU -> GPU.
    pub fn weight_transfer(&mut self) -> f64 {
        self.stats.weight_transfers += 1;
        self.stats.weight_bytes += self.hw.expert_weight_bytes;
        self.hw.weight_transfer_us()
    }

    /// Cost (µs) of moving `tokens` activations one way (paper-scale:
    /// hidden 4096, 2 bytes each).
    pub fn activation_transfer(&mut self, tokens: usize) -> f64 {
        let bytes = tokens * 4096 * 2;
        self.stats.act_transfers += 1;
        self.stats.act_bytes += bytes as u64;
        self.hw.act_copy_us(bytes)
    }

    pub fn stats(&self) -> &LinkStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_transfer_matches_config() {
        let hw = HardwareConfig::env1();
        let mut link = PcieLink::new(&hw);
        let us = link.weight_transfer();
        assert!((us - hw.weight_transfer_us()).abs() < 1e-9);
        assert_eq!(link.stats().weight_transfers, 1);
        assert_eq!(link.stats().weight_bytes, hw.expert_weight_bytes);
    }

    #[test]
    fn activation_transfer_scales_with_tokens() {
        let hw = HardwareConfig::env1();
        let mut link = PcieLink::new(&hw);
        let one = link.activation_transfer(1);
        let many = link.activation_transfer(1000);
        assert!(many > one);
        assert_eq!(link.stats().act_transfers, 2);
    }

    #[test]
    fn env2_link_is_faster() {
        let mut l1 = PcieLink::new(&HardwareConfig::env1());
        let mut l2 = PcieLink::new(&HardwareConfig::env2());
        assert!(l2.weight_transfer() < l1.weight_transfer());
    }
}
