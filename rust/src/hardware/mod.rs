//! Simulated heterogeneous hardware substrate.
//!
//! The paper's testbed (GPU + host CPU + PCIe) is not available in this
//! environment, so *time* is simulated while *numerics* execute for real
//! through the PJRT CPU client (DESIGN.md §2).  The substrate provides:
//!
//! * [`VirtualClock`] — monotonically advancing simulated time,
//! * [`PcieLink`] — weight/activation transfer cost accounting,
//! * expert residency lives in [`crate::expertcache`] (`GpuMemory` remains
//!   as a compatibility alias),
//! * [`DeviceTimeline`] — per-device busy tracking so CPU and GPU work can
//!   overlap (the coordinator executes the two queues concurrently and the
//!   layer latency is the max of the two, as on real hardware).

pub mod clock;
pub mod link;
pub mod memory;

pub use clock::VirtualClock;
pub use link::PcieLink;
pub use memory::GpuMemory;

use crate::config::DeviceKind;

/// Per-device busy timeline: work items are appended serially per device,
/// and both devices proceed concurrently relative to the virtual clock.
#[derive(Clone, Debug, Default)]
pub struct DeviceTimeline {
    gpu_free_at_us: f64,
    cpu_free_at_us: f64,
}

impl DeviceTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `dur_us` of work on `device` not earlier than `ready_us`;
    /// returns the completion timestamp.
    pub fn schedule(&mut self, device: DeviceKind, ready_us: f64, dur_us: f64) -> f64 {
        let slot = match device {
            DeviceKind::Gpu => &mut self.gpu_free_at_us,
            DeviceKind::Cpu => &mut self.cpu_free_at_us,
        };
        let start = slot.max(ready_us);
        *slot = start + dur_us;
        *slot
    }

    pub fn free_at(&self, device: DeviceKind) -> f64 {
        match device {
            DeviceKind::Gpu => self.gpu_free_at_us,
            DeviceKind::Cpu => self.cpu_free_at_us,
        }
    }

    /// Timestamp when both devices are idle (a synchronization barrier,
    /// e.g. end of an MoE layer where outputs must be combined).
    pub fn barrier(&mut self) -> f64 {
        let t = self.gpu_free_at_us.max(self.cpu_free_at_us);
        self.gpu_free_at_us = t;
        self.cpu_free_at_us = t;
        t
    }

    pub fn reset_to(&mut self, t_us: f64) {
        self.gpu_free_at_us = t_us;
        self.cpu_free_at_us = t_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn devices_overlap() {
        let mut tl = DeviceTimeline::new();
        let g = tl.schedule(DeviceKind::Gpu, 0.0, 10.0);
        let c = tl.schedule(DeviceKind::Cpu, 0.0, 25.0);
        assert_eq!(g, 10.0);
        assert_eq!(c, 25.0);
        // Barrier waits for the slower device.
        assert_eq!(tl.barrier(), 25.0);
    }

    #[test]
    fn same_device_serializes() {
        let mut tl = DeviceTimeline::new();
        tl.schedule(DeviceKind::Gpu, 0.0, 10.0);
        let done = tl.schedule(DeviceKind::Gpu, 0.0, 5.0);
        assert_eq!(done, 15.0);
    }

    #[test]
    fn ready_time_respected() {
        let mut tl = DeviceTimeline::new();
        let done = tl.schedule(DeviceKind::Cpu, 100.0, 5.0);
        assert_eq!(done, 105.0);
    }
}
