//! # Fiddler — CPU-GPU orchestration for fast MoE inference (reproduction)
//!
//! Full-system reproduction of *Fiddler: CPU-GPU Orchestration for Fast
//! Inference of Mixture-of-Experts Models* (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas expert kernels + a Mixtral-style MoE
//!   model in JAX, AOT-lowered to HLO-text artifacts (`python/compile/`).
//! * **Runtime** — [`runtime`] loads artifacts through the PJRT C API.
//! * **L3 (this crate)** — the paper's contribution: the [`scheduler`]
//!   (Algorithm 1), [`placement`] (popularity pinning), the [`expertcache`]
//!   residency subsystem (pluggable eviction + async transfer tracking),
//!   the wall-clock parallel expert executor [`exec`] (worker pool +
//!   CPU/GPU overlap inside the layer loop, feeding the [`cpukernel`]
//!   host kernel), the [`pipeline`]d layer executor (one forward driver
//!   for all generation paths, with cross-layer expert prefetch and
//!   work-stealing dispatch), the serving [`coordinator`] (continuous
//!   batching, beam
//!   search), and the [`baselines`] it is evaluated against, over a
//!   simulated heterogeneous [`hardware`] substrate (virtual clock +
//!   calibrated [`latency`] model).
//!
//! See DESIGN.md for the experiment index and the hardware substitutions.

pub mod benchkit;
pub mod config;
pub mod runtime;
pub mod testkit;
pub mod util;

pub mod baselines;
pub mod control;
pub mod coordinator;
pub mod events;
pub mod exec;
pub mod expertcache;
pub mod hardware;
pub mod kvcache;
pub mod latency;
pub mod metrics;
pub mod moe;
pub mod pipeline;
pub mod placement;
pub mod popularity;
pub mod scheduler;
pub mod server;
pub mod workload;
pub mod figures;
pub mod cpukernel;
pub mod prefetch;
pub mod quant;
