//! Pipelined layer executor — the single forward driver behind every
//! generation path.
//!
//! Before this module, `ModelRunner::prefill`, `prefill_chunk`, and
//! `decode_step` each carried their own copy of the per-layer loop, and
//! every layer ended in a hard barrier: expert transfers and CPU staging
//! for layer `L+1` could not start until layer `L`'s MoE join.  The
//! pipeline models each layer as explicit stages —
//!
//! ```text
//!   attention(L) ─▶ route(L) ─▶ dispatch(L) ─▶ join(L)
//!                      │
//!                      ├─ prefetch(L+1..L+W)   (async PCIe, overlapped)
//!                      └─ record routing       (next chunk's predictor)
//! ```
//!
//! — and opens the overlap window across the attention boundary
//! (HybriMoE's impact-driven prefetch, MoE-Lightning's CPU-GPU
//! pipelining; see PAPERS.md):
//!
//! * **Cross-layer expert prefetch** (`--pipeline-lookahead W`, 0 = the
//!   serial legacy loop): once layer `L`'s routing is known, the pipeline
//!   issues asynchronous PCIe transfers for the experts predicted at
//!   layers `L+1..L+W` — scored by [`TransitionProfile`] chains for
//!   decode/fresh prefill, or by the *already observed* routing of the
//!   previous chunk for chunked-prefill continuation (the same prompt
//!   keeps the same expert affinity).  Transfers ride the
//!   [`ExpertCache`](crate::expertcache::ExpertCache)'s serialized PCIe
//!   lane and only count as resident once complete, so hidden transfers
//!   are exactly the ones layer `L`'s compute paid for.
//! * **In-flight overrides** (Algorithm 1 extended): when layer `L` plans
//!   an expert whose prefetch is still mid-flight, waiting out the
//!   residual transfer and running on the GPU can beat both demand
//!   options ([`crate::scheduler::inflight_wins`]); the override is
//!   charged at its true ready time, so the virtual timeline reflects the
//!   partial overlap instead of a full transfer.
//! * **Work-stealing CPU dispatch**: CPU-planned expert chunks enter the
//!   [`ExecutorPool`](crate::exec::ExecutorPool) longest-first
//!   (per-expert priority), and at the join the engine thread steals
//!   still-queued chunks instead of idling, so one oversized prefill
//!   expert no longer serializes the layer barrier
//!   ([`crate::exec::PendingBatch::wait_stealing`]).
//!
//! Determinism contract: for a fixed lookahead *plan effect* the numerics
//! are bit-identical at every thread count (expert-index-ordered
//! reduction, chunk-invariant host kernel — PR 2's contract, unchanged).
//! Across lookahead values the outputs are also bit-identical with the
//! host kernel off (every plan runs the same PJRT expert executable;
//! prefetch changes *where time goes*, never the arithmetic); with
//! `FIDDLER_HOST_KERNEL=1` a prefetch-flipped plan switches an expert
//! between the host kernel and the XLA executable, which agree to ~1e-3 —
//! the same caveat PR 2 documents for `--threads`.

use crate::config::model::TOKEN_BUCKETS;
use crate::control::{LookaheadController, SeededEwma, SkewTracker};
use crate::moe::{ExecContext, ModelRunner};
use crate::prefetch::TransitionProfile;
use crate::runtime::Tensor;
use crate::scheduler::ExpertPlan;
use crate::util::round_up_bucket;
use anyhow::Result;

/// Per-kind layer-gap EWMA weights: the old estimate keeps `GAP_DECAY`,
/// each new sample contributes `GAP_ALPHA`.  Both are explicit literals
/// so the update is bit-identical to the historical `0.7*e + 0.3*g`.
const GAP_DECAY: f64 = 0.7;
const GAP_ALPHA: f64 = 0.3;

/// Which generation path is driving the pipeline — selects the layer-ahead
/// expert predictor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForwardKind {
    /// Fresh prompt: transition-profile predictions.
    Prefill,
    /// Continuation chunk of a prompt whose prefix already ran: the
    /// previous pass's *observed* per-layer routing is the predictor.
    ChunkContinuation,
    /// Batched decode step: transition-profile predictions.
    Decode,
}

impl ForwardKind {
    fn idx(self) -> usize {
        match self {
            ForwardKind::Prefill => 0,
            ForwardKind::ChunkContinuation => 1,
            ForwardKind::Decode => 2,
        }
    }
}

/// Per-context pipeline state: the lookahead window, the cross-layer
/// predictor, and the routing observed on the previous forward pass.
#[derive(Debug)]
pub struct PipelineState {
    /// Layer-ahead prefetch window; 0 = serial legacy behavior (no
    /// prefetch, no overrides — the pre-pipeline engine, bit-for-bit).
    /// Under `--adaptive on` this is the *effective* window, rewritten at
    /// every pass start from the per-kind controller.
    pub lookahead: usize,
    /// Experts prefetched per looked-ahead layer.
    pub depth: usize,
    /// Cross-layer routing transitions (None disables prediction-based
    /// prefetch; continuation chunks still reuse observed routing).
    pub transitions: Option<TransitionProfile>,
    /// Current pass reuses the chunk log as its predictor.
    continuation: bool,
    /// Current pass records into the chunk log (prefill passes only).
    recording: bool,
    /// Index of the current pass kind into the per-kind gap EWMAs.
    kind_idx: usize,
    /// EWMA of consecutive layer-start gaps per pass kind (µs) — the
    /// lead-time estimate behind the issuance gate: a prefetch for layer
    /// `L+d` has roughly `d * gap` of compute to hide under.  Kept per
    /// kind because decode layers run ~ms while chunked prefill layers
    /// run tens of ms.  Seeded EWMAs: the first sample stands alone
    /// instead of blending with an implicit 0 (which would underestimate
    /// lead for the whole first window and suppress early profitable
    /// prefetches).
    gap_ewma: [SeededEwma; 3],
    /// Start time of the previous layer in this pass (reset per pass so
    /// inter-pass gaps — lm_head, sampling, scheduling — never pollute
    /// the estimate).
    last_layer_start: Option<f64>,
    /// Pins released so far into the speculative working set (lazy: a pin
    /// is only broken when a gated-profitable prefetch actually needs the
    /// slot, so workloads the gate rejects pay nothing).
    released: usize,
    /// inp_size per layer observed during the current prompt's prefill —
    /// written ONLY by `Prefill`/`ChunkContinuation` passes and reset when
    /// a fresh prompt starts, so the interleaved decode steps of the
    /// continuous-batching serve loop can never clobber the predictor
    /// between two chunks of the same prompt.  (The lifecycle scheduler
    /// admits at most one prefilling prompt at a time, which is what makes
    /// a single log per context sufficient.)  Entries are overwritten
    /// in-place as the current chunk advances, so a lookahead read at
    /// layer `L+d` still sees the *previous* chunk's routing there.
    chunk_routing: Vec<Option<Vec<usize>>>,
    /// Loop 1 of the adaptive control plane (`--adaptive on`): the
    /// per-pass-kind lookahead controller.  `None` = static pipeline,
    /// bit-identical to the pre-control-plane engine.
    controller: Option<LookaheadController>,
    /// Loop 3: per-batch-row routing history for skew-aware override
    /// pricing on batched decode.  `None` when not adaptive.
    skew: Option<SkewTracker>,
}

impl Default for PipelineState {
    fn default() -> PipelineState {
        PipelineState {
            lookahead: 0,
            depth: 0,
            transitions: None,
            continuation: false,
            recording: false,
            kind_idx: 0,
            gap_ewma: [SeededEwma::with_weights(GAP_DECAY, GAP_ALPHA); 3],
            last_layer_start: None,
            released: 0,
            chunk_routing: Vec::new(),
            controller: None,
            skew: None,
        }
    }
}

impl PipelineState {
    /// Disabled pipeline (lookahead 0): every path degenerates to the
    /// serial per-layer loop.
    pub fn disabled() -> PipelineState {
        PipelineState::default()
    }

    pub fn new(
        lookahead: usize,
        depth: usize,
        transitions: Option<TransitionProfile>,
    ) -> PipelineState {
        PipelineState {
            lookahead,
            depth: depth.max(1),
            transitions,
            ..PipelineState::default()
        }
    }

    /// Arm the adaptive pipeline loops (1 and 3): the per-kind lookahead
    /// controller and the batched-decode skew tracker.  No-op when the
    /// pipeline is disabled — adaptivity never conjures a pipeline the
    /// static config turned off.
    pub fn enable_adaptive(&mut self) {
        if self.lookahead == 0 {
            return;
        }
        self.controller = Some(LookaheadController::new(self.lookahead));
        self.skew = Some(SkewTracker::new());
    }

    /// Loop-1 controller, when adaptive (inspection for tests/summary).
    pub fn controller(&self) -> Option<&LookaheadController> {
        self.controller.as_ref()
    }

    /// Start a forward pass: select this pass's predictor and whether it
    /// feeds the chunk log.
    fn begin_pass(&mut self, n_layers: usize, kind: ForwardKind) {
        if self.lookahead == 0 {
            return;
        }
        self.continuation = kind == ForwardKind::ChunkContinuation;
        self.recording = kind != ForwardKind::Decode;
        self.kind_idx = kind.idx();
        self.last_layer_start = None;
        match kind {
            // A fresh prompt: reset the log; this pass repopulates it.
            ForwardKind::Prefill => self.chunk_routing = vec![None; n_layers],
            ForwardKind::ChunkContinuation => self.chunk_routing.resize(n_layers, None),
            ForwardKind::Decode => {}
        }
    }

    /// Feed one layer-start timestamp into this pass kind's gap EWMA.
    fn observe_layer_start(&mut self, t0: f64) {
        if let Some(prev) = self.last_layer_start {
            if t0 > prev {
                self.gap_ewma[self.kind_idx].observe(t0 - prev);
            }
        }
        self.last_layer_start = Some(t0);
    }

    /// Expected gap between consecutive layer starts for the current pass
    /// kind; 0.0 until the first pass of this kind has produced a sample.
    fn expected_layer_gap(&self) -> f64 {
        self.gap_ewma[self.kind_idx].value_or(0.0)
    }

    /// Largest gap estimate across ALL pass kinds — the adaptive cold-start
    /// fallback: a kind's very first pass has no own-kind sample, and
    /// skipping the whole window there forfeits exactly the early
    /// prefetches the seeded EWMA exists to enable.  Borrowing the largest
    /// cross-kind estimate is optimistic (prefill gaps are longer than
    /// decode's, so the gate sees more lead than reality and issues), but
    /// only for the first pass of a kind — and wasted issues show up in
    /// the very reward signal the controller corrects from.
    fn max_layer_gap_estimate(&self) -> f64 {
        self.gap_ewma
            .iter()
            .filter_map(|e| e.get())
            .fold(0.0, f64::max)
    }

    fn record_routing(&mut self, layer: usize, inp_size: &[usize]) {
        if !self.recording {
            return;
        }
        if let Some(slot) = self.chunk_routing.get_mut(layer) {
            *slot = Some(inp_size.to_vec());
        }
    }

    /// Predicted experts for `layer + d`, best first — observed routing
    /// when this pass continues a prompt the predictor has already seen
    /// (every active expert is a real target), transition-chain scores
    /// otherwise (filtered to clearly-above-uniform mass: a speculative
    /// transfer on a noise-level prediction evicts a slot for nothing).
    fn predict(&self, layer: usize, inp_size: &[usize], d: usize) -> Vec<usize> {
        if self.continuation {
            if let Some(Some(prev)) = self.chunk_routing.get(layer + d) {
                if prev.len() == inp_size.len() && prev.iter().any(|&s| s > 0) {
                    let mut idx: Vec<usize> =
                        (0..prev.len()).filter(|&j| prev[j] > 0).collect();
                    idx.sort_by(|&a, &b| prev[b].cmp(&prev[a]).then(a.cmp(&b)));
                    return idx;
                }
            }
        }
        match &self.transitions {
            Some(t)
                if t.n_experts == inp_size.len() && layer + d < t.n_layers =>
            {
                let mut mass: Vec<f64> =
                    inp_size.iter().map(|&s| s as f64).collect();
                for step in 0..d {
                    mass = t.propagate_mass(layer + step, &mass);
                }
                // Confidence floor scales with chain length: every extra
                // transition step compounds prediction noise, and a
                // speculative transfer on a noise-level target evicts a
                // slot for nothing.
                let floor = (1.0 + 0.5 * d as f64) / t.n_experts as f64;
                let mut idx: Vec<usize> =
                    (0..t.n_experts).filter(|&j| mass[j] >= floor).collect();
                idx.sort_by(|&a, &b| mass[b].total_cmp(&mass[a]).then(a.cmp(&b)));
                idx
            }
            _ => Vec::new(),
        }
    }
}

/// Drive all decoder layers of one forward pass: the single layer loop
/// shared by `prefill`, `prefill_chunk`, and `decode_step`.  `attn` is the
/// path-specific attention stage (executes the right attention
/// executable, appends K/V, charges attention time) — everything else
/// (route → prefetch → dispatch → join) is common pipeline machinery.
pub fn run_layers(
    runner: &ModelRunner,
    cx: &mut ExecContext,
    mut x: Tensor,
    valid: usize,
    kind: ForwardKind,
    attn: &mut dyn FnMut(usize, &Tensor, &mut ExecContext) -> Result<Tensor>,
) -> Result<Tensor> {
    let snap = adaptive_pre_pass(cx, kind, valid);
    cx.pipeline.begin_pass(runner.cfg.n_layers, kind);
    for layer in 0..runner.cfg.n_layers {
        x = attn(layer, &x, cx)?;
        runner.moe_layer(layer, &mut x, valid, cx)?;
    }
    adaptive_post_pass(cx, kind, snap);
    Ok(x)
}

/// Adaptive pre-pass hooks (loops 1 + 3): install this kind's learned
/// lookahead as the effective window and open the skew tracker's decode
/// step.  Returns the counter snapshot the post-pass reward is measured
/// against; `None` when not adaptive (the entire static path).
fn adaptive_pre_pass(
    cx: &mut ExecContext,
    kind: ForwardKind,
    valid: usize,
) -> Option<(u64, u64, u64)> {
    if let Some(sk) = cx.pipeline.skew.as_mut() {
        if kind == ForwardKind::Decode {
            sk.begin_step(valid);
        } else {
            sk.set_inactive();
        }
    }
    let eff = cx
        .pipeline
        .controller
        .as_ref()
        .map(|c| c.lookahead(kind.idx()))?;
    cx.pipeline.lookahead = eff;
    let st = cx.memory.stats();
    Some((cx.events.prefetch_overlapped, st.prefetches, st.prefetch_hits))
}

/// Adaptive post-pass hook (loop 1): feed this pass's counter deltas to
/// the controller and emit `controller_adjusted` when a reward window
/// closes with a move.
fn adaptive_post_pass(cx: &mut ExecContext, kind: ForwardKind, snap: Option<(u64, u64, u64)>) {
    let Some((o0, p0, h0)) = snap else { return };
    let (overlapped, issued, hits) = {
        let st = cx.memory.stats();
        (
            cx.events.prefetch_overlapped.saturating_sub(o0),
            st.prefetches.saturating_sub(p0),
            st.prefetch_hits.saturating_sub(h0),
        )
    };
    let t_us = cx.clock.now_us();
    let adj = cx
        .pipeline
        .controller
        .as_mut()
        .and_then(|c| c.on_pass(kind.idx(), overlapped, hits, issued.saturating_sub(hits)));
    if let Some(a) = adj {
        cx.sink.emit_with(|| crate::events::TraceEvent::ControllerAdjusted {
            t_us,
            pass: crate::control::KIND_LABELS[kind.idx()].to_string(),
            lookahead: a.lookahead,
            reward: a.reward,
            adjustments: a.adjustments,
        });
    }
}

/// The MoE stage of one layer — route → prefetch → dispatch → join — with
/// router outputs already in hand.  THE single implementation; the old
/// `ModelRunner::moe_experts` delegates here.
pub(crate) fn moe_stage(
    runner: &ModelRunner,
    layer: usize,
    h: &mut Tensor,
    probs: &Tensor,
    xn: &Tensor,
    valid: usize,
    cx: &mut ExecContext,
) -> Result<()> {
    let routing = crate::moe::topk::route(
        &probs.data[..valid * runner.cfg.n_experts],
        valid,
        runner.cfg.n_experts,
        runner.cfg.top_k,
    );
    for (e, &s) in routing.inp_size.iter().enumerate() {
        cx.online_profile.record(layer, e, s as u64);
    }

    let t0 = cx.clock.now_us();
    // Clockless cache paths (policy fetch/admit) stamp their trace events
    // with the layer-start time.
    cx.memory.set_time_hint(t0);
    // Snapshot which of this layer's experts have a transfer still in
    // flight BEFORE the policy plans: dynamic-caching policies admit() on
    // their demand-transfer plans, which promotes an in-flight entry to
    // ready and would otherwise hide exactly the residual wait the
    // override exists to price.
    let inflight: Vec<Option<f64>> = if cx.pipeline.lookahead > 0 {
        routing
            .inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    return None;
                }
                cx.memory.ready_at((layer, j)).filter(|&r| r > t0)
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut plans = cx
        .policy
        .plan_layer(layer, &routing.inp_size, &mut cx.memory, &cx.lat, t0);
    // Speculative policies overlap next-layer weight prefetches with
    // this layer's compute.
    cx.policy
        .post_layer(layer, &routing.inp_size, &mut cx.memory, &cx.lat, t0);

    // Pipeline stages beyond the serial loop (lookahead >= 1): issue the
    // cross-layer prefetch window, then let still-in-flight transfers win
    // this layer's plan where waiting them out is cheapest.  `waits[j]` is
    // the residual transfer time charged before expert j's GPU slot.
    let mut waits = vec![0.0f64; plans.len()];
    if cx.pipeline.lookahead > 0 {
        // Loop 3 (--adaptive): log which batch row routed to which expert
        // this decode step — next step's override pricing consults it.
        if let Some(sk) = cx.pipeline.skew.as_mut() {
            if sk.is_active() {
                for (j, rows) in routing.rows_for.iter().enumerate() {
                    for &r in rows {
                        sk.record(r, layer, j);
                    }
                }
            }
        }
        cx.pipeline.observe_layer_start(t0);
        prefetch_window(cx, layer, &routing.inp_size, runner.cfg.n_layers, t0);
        apply_inflight_overrides(
            cx,
            layer,
            &routing.inp_size,
            &routing.rows_for,
            &inflight,
            t0,
            &mut plans,
            &mut waits,
        );
        cx.pipeline.record_routing(layer, &routing.inp_size);
    }

    // Wall-clock execution mirrors the simulated overlap (§3.3): the
    // worker pool chews CPU-planned experts through the dedicated host
    // kernel (§3.4) while this thread runs the GPU-planned experts'
    // executables, and both join at the layer barrier below.  Outputs are
    // stashed per expert and combined afterwards in expert-index order —
    // the same reduction order as the old serial loop, independent of
    // plan, thread count, and completion schedule, so the numerics are
    // unchanged to the bit.
    let host_kernel = crate::cpukernel::host_kernel_enabled();
    let on_pool = |plan: &ExpertPlan| *plan == ExpertPlan::Cpu && host_kernel;

    let mut outputs: Vec<Option<Tensor>> = plans.iter().map(|_| None).collect();
    let mut chunks: Vec<crate::exec::ExpertChunk> = Vec::new();
    for (j, plan) in plans.iter().enumerate() {
        let Some(plan) = plan else { continue };
        if !on_pool(plan) {
            continue;
        }
        let rows = &routing.rows_for[j];
        let s = rows.len();
        outputs[j] = Some(Tensor::zeros(vec![s, runner.cfg.hidden]));
        let w1 = runner.ws.expert_shared(layer, j, "w1");
        let w3 = runner.ws.expert_shared(layer, j, "w3");
        let w2 = runner.ws.expert_shared(layer, j, "w2");
        // Large-s (prefill) experts additionally split across workers.
        for (r0, r1) in crate::exec::partition_rows(s, cx.pool.threads()) {
            chunks.push(crate::exec::ExpertChunk {
                expert: j,
                row0: r0,
                // Exact size, no bucket: the host kernel pads nothing.
                x: xn.gather_rows_padded(&rows[r0..r1], r1 - r0),
                w1: w1.clone(),
                w3: w3.clone(),
                w2: w2.clone(),
            });
        }
    }
    // Dispatch longest-first (per-expert priority; see `exec`).
    let n_chunks = chunks.len();
    let cpu_experts = plans.iter().flatten().filter(|p| on_pool(p)).count();
    let gpu_experts = plans.iter().flatten().filter(|p| !on_pool(p)).count();
    let steal0 = cx.pool.steal_count();
    cx.sink.emit_with(|| crate::events::TraceEvent::ExecDispatch {
        t_us: t0,
        layer,
        chunks: n_chunks,
        cpu_experts,
        gpu_experts,
    });
    let pending = crate::exec::run_expert_chunks(&cx.pool, chunks);

    // GPU-planned experts (and the PJRT fallback for CPU plans when the
    // host kernel is off) execute on this thread, overlapping the pool.
    for (j, plan) in plans.iter().enumerate() {
        let Some(plan) = plan else { continue };
        if on_pool(plan) {
            continue;
        }
        let rows = &routing.rows_for[j];
        let bucket = round_up_bucket(rows.len(), TOKEN_BUCKETS);
        let xe = xn.gather_rows_padded(rows, bucket);
        outputs[j] = Some(runner.expert_gpu(layer, j, &xe, bucket)?);
    }

    // Layer barrier: steal still-queued chunks onto this thread, join the
    // pool, scatter chunk outputs into the per-expert buffers (positional
    // — order-free).
    let hidden = runner.cfg.hidden;
    for c in pending.wait_stealing(&cx.pool) {
        let dst = outputs[c.expert].as_mut().expect("chunk for unplanned expert");
        dst.data[c.row0 * hidden..c.row0 * hidden + c.out.data.len()]
            .copy_from_slice(&c.out.data);
    }
    let stolen = cx.pool.steal_count() - steal0;
    cx.sink
        .emit_with(|| crate::events::TraceEvent::ExecJoin { t_us: t0, layer, stolen });

    // Combine + simulated accounting, in expert-index order.  An
    // overridden expert's GPU slot starts no earlier than its weights'
    // arrival (`t0 + waits[j]`), so overlapped transfers are charged
    // exactly their un-hidden residue.
    for (j, plan) in plans.iter().enumerate() {
        let Some(plan) = plan else { continue };
        let rows = &routing.rows_for[j];
        let s = rows.len();
        let out = outputs[j].as_ref().expect("planned expert without output");
        h.axpy_rows(rows, &routing.weights_for[j], out);

        // Account simulated time + link/memory bookkeeping.
        let cost = cx.policy.expert_cost_us(*plan, s, &cx.lat);
        cx.timeline.schedule(plan.device(), t0 + waits[j], cost);
        match plan {
            ExpertPlan::GpuResident => cx.events.resident += 1,
            // Quantized-resident execution: no PCIe traffic (the copy is
            // already in HBM); the simulated cost carries the dequant
            // overhead via `expert_cost_us`.  Wall-clock still runs the fp
            // executable — the virtual timeline prices the low-bit copy,
            // the numerics stay full-precision (documented limitation).
            ExpertPlan::GpuQuant => cx.events.quant += 1,
            ExpertPlan::GpuTransfer => {
                cx.events.transferred += 1;
                cx.link.weight_transfer();
            }
            ExpertPlan::Cpu => {
                cx.events.cpu += 1;
                cx.link.activation_transfer(s); // out
                cx.link.activation_transfer(s); // back
            }
        }
    }
    // Layer boundary: expert outputs must be combined before the next
    // layer — both device queues join.
    let done = cx.timeline.barrier();
    cx.clock.advance_to_us(done);
    Ok(())
}

/// Issue the asynchronous prefetch window: the top `depth` predicted
/// experts of the NEAREST profitably-reachable lookahead layer, on the
/// cache's serialized PCIe lane, overlapping this layer's compute.
///
/// Speculation gets its own Algorithm 1: a transfer is only issued when
/// its *projected* residual wait at use time — lane position plus one
/// transfer, minus `d` layers of estimated lead
/// ([`PipelineState::expected_layer_gap`]) — still beats the demand paths
/// ([`crate::scheduler::inflight_wins`]).  Distances whose lead cannot
/// hide enough of the transfer are skipped (on fast decode layers `d = 1`
/// often cannot pay while `d = 2` can), and only the minimal profitable
/// distance issues: nearer layers re-evaluate the farther ones next call
/// with better predictions.  Pins are broken lazily, one per needed slot
/// up to the working-set budget, so a workload the gate rejects keeps the
/// full pinned placement and runs exactly like the serial loop.
fn prefetch_window(
    cx: &mut ExecContext,
    layer: usize,
    inp_size: &[usize],
    n_layers: usize,
    now_us: f64,
) {
    let mut gap = cx.pipeline.expected_layer_gap();
    if gap <= 0.0 && cx.pipeline.controller.is_some() {
        // Adaptive cold start: borrow the best cross-kind estimate rather
        // than forfeiting the whole first pass of a fresh kind.
        gap = cx.pipeline.max_layer_gap_estimate();
    }
    if gap <= 0.0 {
        return; // no lead-time estimate yet (first layers of a fresh kind)
    }
    let transfer = cx.lat.transfer_lat();
    let active = inp_size.iter().filter(|&&s| s > 0).count().max(1);
    let s_pred = (inp_size.iter().sum::<usize>() / active).max(1);
    let budget = (2 * cx.pipeline.depth).min(cx.memory.capacity() / 2);
    // Projected residual wait if the next transfer were issued now and
    // consumed `d` layers from now; re-evaluated per issued transfer —
    // each issue pushes the serialized lane one transfer further out, so
    // a distance that paid for its first transfer may not pay for its
    // second.
    let wait_at = |lane_free: f64, d: usize| {
        (lane_free.max(now_us) + transfer - (now_us + d as f64 * gap)).max(0.0)
    };
    for d in 1..=cx.pipeline.lookahead {
        if layer + d >= n_layers {
            break;
        }
        if !crate::scheduler::inflight_wins(wait_at(cx.memory.lane_free_at(), d), s_pred, &cx.lat)
        {
            // Full fp transfers cannot pay for themselves at this
            // distance — but with the tier on, a low-bit copy at bits/16
            // of the lane time still buys cheap coverage for the
            // three-way planner.
            if let Some(bits) = cx.memory.quant_bits() {
                let qx = cx.lat.quant_transfer_lat(bits);
                let targets = cx.pipeline.predict(layer, inp_size, d);
                for j in targets.into_iter().take(cx.pipeline.depth) {
                    let id = (layer + d, j);
                    if cx.memory.is_resident(id) || cx.memory.is_quant_resident(id) {
                        continue;
                    }
                    if cx.memory.admit_quant(id, now_us, qx).is_none() {
                        break; // lane backlogged or tier full
                    }
                }
            }
            continue; // not enough lead at this distance; try farther
        }
        let targets = cx.pipeline.predict(layer, inp_size, d);
        let mut issued = 0;
        for j in targets {
            if issued >= cx.pipeline.depth {
                break;
            }
            if cx.memory.is_resident((layer + d, j)) {
                continue; // pinned, cached, or already in flight
            }
            if cx.memory.is_quant_resident((layer + d, j)) {
                // Predicted and already in HBM at low bits: spend the
                // lead time upgrading the copy to the fp master instead
                // of fetching something colder.
                if cx.memory.promote_async((layer + d, j), now_us, transfer).is_some() {
                    issued += 1;
                }
                continue;
            }
            if !crate::scheduler::inflight_wins(
                wait_at(cx.memory.lane_free_at(), d),
                s_pred,
                &cx.lat,
            ) {
                break; // the lane moved out from under this distance
            }
            match cx.memory.prefetch((layer + d, j), now_us, transfer) {
                Some(ready_us) => {
                    issued += 1;
                    cx.sink.emit_with(|| crate::events::TraceEvent::PrefetchIssued {
                        t_us: now_us,
                        layer,
                        target_layer: layer + d,
                        expert: j,
                        distance: d,
                        ready_us,
                    });
                }
                None => {
                    // Distinguish "lane backlogged" (nothing helps) from
                    // "every slot pinned" (lazily carve one working-set
                    // slot and retry once).
                    let lane_full = cx.memory.lane_free_at()
                        > now_us + cx.memory.max_lane_depth * transfer;
                    if !lane_full
                        && cx.pipeline.released < budget
                        && cx.memory.release_pins(1) == 1
                    {
                        cx.pipeline.released += 1;
                        if let Some(ready_us) =
                            cx.memory.prefetch((layer + d, j), now_us, transfer)
                        {
                            issued += 1;
                            cx.sink.emit_with(|| {
                                crate::events::TraceEvent::PrefetchIssued {
                                    t_us: now_us,
                                    layer,
                                    target_layer: layer + d,
                                    expert: j,
                                    distance: d,
                                    ready_us,
                                }
                            });
                            continue;
                        }
                    }
                    return;
                }
            }
        }
        break; // only the minimal profitable distance issues
    }
}

/// Algorithm 1 extended for in-flight transfers: where the policy planned
/// a demand path (CPU or synchronous transfer) for an expert whose
/// prefetch is still mid-flight, waiting out the residual transfer and
/// running on the GPU wins whenever it undercuts what the policy would
/// actually charge for its own plan.  The comparison prices the kept plan
/// through `expert_cost_us` — NOT the closed-form Algorithm 1 costs
/// ([`crate::scheduler::inflight_wins`] is that pure form) — because
/// policies discount their demand paths (Fiddler streams transfers behind
/// compute, pricing `GpuTransfer` at `max(transfer, gpu)`), and an
/// override that beats the undiscounted price but loses to the
/// discounted one would make the modeled layer *slower*.
fn apply_inflight_overrides(
    cx: &mut ExecContext,
    layer: usize,
    inp_size: &[usize],
    rows_for: &[Vec<usize>],
    inflight: &[Option<f64>],
    t0: f64,
    plans: &mut [Option<ExpertPlan>],
    waits: &mut [f64],
) {
    for (j, plan) in plans.iter_mut().enumerate() {
        let s = inp_size[j];
        if s == 0 {
            continue;
        }
        let cur = match plan {
            Some(p @ (ExpertPlan::Cpu | ExpertPlan::GpuTransfer)) => *p,
            _ => continue,
        };
        // Plan-time snapshot, NOT the current cache state: a dynamic
        // policy's demand admit() may have promoted the entry since.
        let Some(Some(ready)) = inflight.get(j) else { continue };
        let wait = *ready - t0;
        let overridden =
            wait + cx.policy.expert_cost_us(ExpertPlan::GpuResident, s, &cx.lat);
        let mut kept = cx.policy.expert_cost_us(cur, s, &cx.lat);
        // Loop 3 (--adaptive): an expert demanded by a single batch row
        // that did not route here last step is one-off skew — bias the
        // pricing toward riding out the in-flight copy, so the whole
        // batch is not charged a demand admit no other row will reuse.
        if let Some(sk) = &cx.pipeline.skew {
            if sk.is_active()
                && rows_for[j].len() == 1
                && !sk.repeated(rows_for[j][0], layer, j)
            {
                kept *= crate::control::SKEW_OVERRIDE_BIAS;
            }
        }
        if overridden < kept {
            *plan = Some(ExpertPlan::GpuResident);
            waits[j] = wait;
            if cur == ExpertPlan::GpuTransfer
                && cx.memory.ready_at((layer, j)).is_some_and(|r| r <= t0)
            {
                // A dynamic policy demand-admitted the in-flight entry
                // while planning; the override supersedes that transfer —
                // take its charge (and the entry's promotion) back.
                cx.memory.cancel_demand_transfer((layer, j), *ready);
                cx.sink.emit_with(|| crate::events::TraceEvent::PrefetchCancelled {
                    t_us: t0,
                    layer,
                    expert: j,
                });
            }
            // The provisional plan-time miss becomes a (prefetch) hit —
            // the expert is served from the speculative transfer.
            cx.memory.claim_inflight((layer, j));
            cx.events.prefetch_overlapped += 1;
            cx.sink.emit_with(|| crate::events::TraceEvent::PrefetchOverlapped {
                t_us: t0,
                layer,
                expert: j,
                wait_us: wait,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag_transitions(n_layers: usize, e: usize) -> TransitionProfile {
        let mut counts = vec![vec![vec![1u64; e]; e]; n_layers - 1];
        for l in counts.iter_mut() {
            for (i, row) in l.iter_mut().enumerate() {
                row[i] = 1_000;
            }
        }
        TransitionProfile { n_layers, n_experts: e, counts }
    }

    #[test]
    fn disabled_state_records_and_predicts_nothing() {
        let mut st = PipelineState::disabled();
        st.begin_pass(4, ForwardKind::Prefill);
        st.record_routing(0, &[1, 0, 0, 0]);
        assert!(st.chunk_routing.is_empty(), "lookahead 0 must not log routing");
        assert!(st.predict(0, &[1, 0, 0, 0], 1).is_empty());
    }

    #[test]
    fn transition_predictor_follows_the_chain() {
        let mut st = PipelineState::new(2, 2, Some(diag_transitions(4, 4)));
        st.begin_pass(4, ForwardKind::Decode);
        // Diagonal transitions: expert 2 active now predicts expert 2 at
        // every lookahead distance — and the noise-level off-diagonal
        // experts are filtered by the above-uniform mass floor.
        assert_eq!(st.predict(0, &[0, 0, 5, 0], 1), vec![2]);
        assert_eq!(st.predict(0, &[0, 0, 5, 0], 2), vec![2]);
    }

    #[test]
    fn weak_transition_targets_are_filtered() {
        // Uniform transitions put every expert at exactly uniform mass —
        // all below the 1.5x-uniform floor: no prediction is worth a
        // speculative transfer (the no-artifacts fallback profile must
        // not flood the PCIe lane with guesses).
        let uni = TransitionProfile::uniform(3, 4);
        let mut st = PipelineState::new(1, 2, Some(uni));
        st.begin_pass(3, ForwardKind::Decode);
        assert!(st.predict(0, &[1, 1, 0, 0], 1).is_empty());
    }

    #[test]
    fn gap_ewma_is_tracked_per_pass_kind() {
        let mut st = PipelineState::new(1, 2, None);
        st.begin_pass(4, ForwardKind::Decode);
        st.observe_layer_start(0.0);
        st.observe_layer_start(100.0);
        assert!((st.expected_layer_gap() - 100.0).abs() < 1e-9);
        // Chunk passes keep their own (much larger) estimate.
        st.begin_pass(4, ForwardKind::ChunkContinuation);
        assert_eq!(st.expected_layer_gap(), 0.0, "no chunk sample yet");
        st.observe_layer_start(0.0);
        st.observe_layer_start(5_000.0);
        assert!((st.expected_layer_gap() - 5_000.0).abs() < 1e-9);
        // Back to decode: the estimate survives, and the huge inter-pass
        // gap is NOT sampled (begin_pass resets the anchor).
        st.begin_pass(4, ForwardKind::Decode);
        st.observe_layer_start(1e9);
        assert!((st.expected_layer_gap() - 100.0).abs() < 1e-9);
        st.observe_layer_start(1e9 + 200.0);
        let g = st.expected_layer_gap();
        assert!(g > 100.0 && g < 200.0, "EWMA must blend, got {g}");
    }

    #[test]
    fn continuation_reuses_prior_chunk_routing_across_interleaved_decodes() {
        let mut st = PipelineState::new(1, 2, Some(diag_transitions(3, 4)));
        // Chunk 1 of the prompt observed expert 3 dominating layer 1.
        st.begin_pass(3, ForwardKind::Prefill);
        st.record_routing(0, &[1, 0, 0, 0]);
        st.record_routing(1, &[0, 1, 2, 9]);
        // The serve loop interleaves decode steps of OTHER sequences
        // between chunks; their routing must not clobber the predictor.
        st.begin_pass(3, ForwardKind::Decode);
        st.record_routing(1, &[9, 0, 0, 0]);
        // Chunk 2 continues the prompt: layer 0's lookahead into layer 1
        // must rank expert 3 first (observed in chunk 1), not expert 0
        // (the decode pass's routing, or the diagonal transition).
        st.begin_pass(3, ForwardKind::ChunkContinuation);
        let pred = st.predict(0, &[7, 0, 0, 0], 1);
        assert_eq!(pred[0], 3);
        // Idle experts are not predicted at all from observed routing.
        assert!(!pred.contains(&0));
    }

    #[test]
    fn fresh_prompt_clears_the_observed_predictor() {
        let mut st = PipelineState::new(1, 2, None);
        st.begin_pass(3, ForwardKind::Prefill);
        st.record_routing(1, &[0, 9, 0, 0]);
        // Decode passes never consult the chunk log (transitions are None
        // here, so prediction is empty)...
        st.begin_pass(3, ForwardKind::Decode);
        assert!(st.predict(0, &[1, 1, 0, 0], 1).is_empty());
        // ...and a NEW prompt's first chunk resets it: its continuation
        // must not inherit the previous prompt's routing.
        st.begin_pass(3, ForwardKind::Prefill);
        st.begin_pass(3, ForwardKind::ChunkContinuation);
        assert!(st.predict(0, &[1, 1, 0, 0], 1).is_empty());
    }

    #[test]
    fn adaptive_is_not_armed_on_a_disabled_pipeline() {
        let mut st = PipelineState::disabled();
        st.enable_adaptive();
        assert!(st.controller().is_none(), "lookahead 0 must stay serial");
        let mut st = PipelineState::new(2, 2, None);
        st.enable_adaptive();
        assert_eq!(st.controller().unwrap().lookahead(2), 2);
    }

    #[test]
    fn cross_kind_gap_fallback_uses_the_largest_estimate() {
        let mut st = PipelineState::new(1, 2, None);
        st.begin_pass(4, ForwardKind::Decode);
        st.observe_layer_start(0.0);
        st.observe_layer_start(100.0);
        // A fresh kind has no own-kind sample, but the adaptive fallback
        // can borrow decode's.
        st.begin_pass(4, ForwardKind::ChunkContinuation);
        assert_eq!(st.expected_layer_gap(), 0.0);
        assert!((st.max_layer_gap_estimate() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn mismatched_transition_shape_is_skipped() {
        // A transitions profile for a different model (wrong expert
        // count) must be ignored, not panic.
        let mut st = PipelineState::new(1, 2, Some(diag_transitions(3, 8)));
        st.begin_pass(3, ForwardKind::Decode);
        assert!(st.predict(0, &[1, 0, 0, 0], 1).is_empty());
    }
}
