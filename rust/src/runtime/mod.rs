//! Runtime: PJRT CPU client + artifact registry + weights + host tensors.
//!
//! Python never appears here — artifacts were lowered at build time and this
//! module is the only place that touches XLA.

pub mod registry;
pub mod tensor;
pub mod weights;

pub use registry::{OpSpec, Runtime, RuntimeStats};
pub use tensor::{Arg, Tensor, TensorI32};
pub use weights::WeightStore;
