//! Host-side tensors exchanged with the PJRT executables.

use anyhow::{bail, Result};

/// A dense row-major f32 host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("tensor shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Gaussian-random tensor — the synthetic weights/activations used by
    /// calibration ([`crate::latency::calib::measure_pool_expert`]),
    /// benches, and tests.
    pub fn randn(rng: &mut crate::util::rng::Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: (0..n).map(|_| (rng.normal() as f32) * scale).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2, "row() needs rank-2, have {:?}", self.shape);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Copy rows `rows` (by index) into a new [rows.len(), width] tensor,
    /// zero-padded up to `pad_to` rows.
    pub fn gather_rows_padded(&self, rows: &[usize], pad_to: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert!(pad_to >= rows.len());
        let w = self.shape[1];
        let mut out = Tensor::zeros(vec![pad_to, w]);
        for (dst, &src) in rows.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Truncate a rank-2 tensor to its first `n` rows.
    pub fn take_rows(&self, n: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert!(n <= self.shape[0]);
        let w = self.shape[1];
        Tensor { shape: vec![n, w], data: self.data[..n * w].to_vec() }
    }

    /// In-place `self += other` (same shape).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place `self += scale * other` over a row range of rank-2 tensors.
    pub fn axpy_rows(&mut self, rows: &[usize], scales: &[f32], other: &Tensor) {
        assert_eq!(self.rank(), 2);
        assert_eq!(rows.len(), scales.len());
        let w = self.shape[1];
        for (i, (&r, &s)) in rows.iter().zip(scales).enumerate() {
            let dst = self.row_mut(r);
            let src = &other.data[i * w..(i + 1) * w];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += s * v;
            }
        }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// A dense row-major i32 host tensor (token ids, positions).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<TensorI32> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("tensor shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(TensorI32 { shape, data })
    }

    pub fn scalar(v: i32) -> TensorI32 {
        TensorI32 { shape: vec![], data: vec![v] }
    }

    pub fn vec(v: Vec<i32>) -> TensorI32 {
        TensorI32 { shape: vec![v.len()], data: v }
    }
}

/// Argument passed to an executable.
#[derive(Clone, Debug)]
pub enum Arg {
    F32(Tensor),
    I32(TensorI32),
}

impl From<Tensor> for Arg {
    fn from(t: Tensor) -> Arg {
        Arg::F32(t)
    }
}

impl From<TensorI32> for Arg {
    fn from(t: TensorI32) -> Arg {
        Arg::I32(t)
    }
}

impl Arg {
    pub fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => &t.shape,
            Arg::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Arg::F32(_) => "f32",
            Arg::I32(_) => "i32",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn gather_rows_padded_zero_pads() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = t.gather_rows_padded(&[2, 0], 4);
        assert_eq!(g.shape, vec![4, 2]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
        assert_eq!(g.row(2), &[0., 0.]);
        assert_eq!(g.row(3), &[0., 0.]);
    }

    #[test]
    fn axpy_rows_scales_and_scatters() {
        let mut acc = Tensor::zeros(vec![3, 2]);
        let upd = Tensor::new(vec![2, 2], vec![1., 1., 2., 2.]).unwrap();
        acc.axpy_rows(&[2, 0], &[0.5, 2.0], &upd);
        assert_eq!(acc.row(0), &[4., 4.]);
        assert_eq!(acc.row(1), &[0., 0.]);
        assert_eq!(acc.row(2), &[0.5, 0.5]);
    }

    #[test]
    fn take_rows_truncates() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let h = t.take_rows(2);
        assert_eq!(h.shape, vec![2, 2]);
        assert_eq!(h.data, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 2.0]).unwrap();
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
    }
}
