//! Executable registry: lazily compiles `artifacts/<model>/hlo/*.hlo.txt`
//! on the PJRT CPU client and executes them with host tensors.
//!
//! This is the AOT bridge of the three-layer architecture: python lowered
//! each entry point to HLO text once at build time; here we parse the text
//! (`HloModuleProto::from_text_file` — the text parser reassigns the 64-bit
//! instruction ids jax >= 0.5 emits, which xla_extension 0.5.1 would reject
//! in proto form), compile once per (op, shape-bucket), and cache.

use super::tensor::{Arg, Tensor};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

/// Shape/dtype description of one op from artifacts_manifest.json.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub file: String,
    pub params: Vec<(Vec<usize>, &'static str)>,
    pub outputs: Vec<(Vec<usize>, &'static str)>,
}

fn parse_shape_desc(v: &Json) -> Result<(Vec<usize>, &'static str)> {
    let shape = v.get("shape")?.as_usize_vec()?;
    let dtype = match v.get("dtype")?.as_str()? {
        "f32" => "f32",
        "i32" => "i32",
        other => bail!("unsupported dtype {other:?} in manifest"),
    };
    Ok((shape, dtype))
}

/// Cumulative execution statistics (used by the perf pass).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compile_count: u64,
    pub compile_wall_us: u64,
    pub execute_wall_us: u64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    ops: BTreeMap<String, OpSpec>,
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory of one model and connect a CPU PJRT client.
    pub fn open(artifact_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let artifact_dir = artifact_dir.into();
        let manifest = json::load(artifact_dir.join("artifacts_manifest.json"))
            .with_context(|| format!("opening runtime at {}", artifact_dir.display()))?;
        let mut ops = BTreeMap::new();
        for (name, desc) in manifest.get("ops")?.as_obj()? {
            let params = desc
                .get("params")?
                .as_arr()?
                .iter()
                .map(parse_shape_desc)
                .collect::<Result<Vec<_>>>()?;
            let outputs = desc
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(parse_shape_desc)
                .collect::<Result<Vec<_>>>()?;
            ops.insert(
                name.clone(),
                OpSpec { file: desc.get("file")?.as_str()?.to_string(), params, outputs },
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            artifact_dir,
            ops,
            executables: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn op_names(&self) -> Vec<String> {
        self.ops.keys().cloned().collect()
    }

    pub fn has_op(&self, name: &str) -> bool {
        self.ops.contains_key(name)
    }

    pub fn op_spec(&self, name: &str) -> Result<&OpSpec> {
        self.ops
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown op {name:?} in {}", self.artifact_dir.display()))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Compile (or fetch cached) the executable for `op`.
    fn executable(&self, op: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(op) {
            return Ok(exe.clone());
        }
        let spec = self.op_spec(op)?;
        let path = self.artifact_dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {op}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        {
            let mut st = self.stats.lock().unwrap();
            st.compile_count += 1;
            st.compile_wall_us += t0.elapsed().as_micros() as u64;
        }
        let mut cache = self.executables.lock().unwrap();
        Ok(cache.entry(op.to_string()).or_insert(exe).clone())
    }

    /// Pre-compile a set of ops (startup warm-up).
    pub fn warmup(&self, ops: &[&str]) -> Result<()> {
        for op in ops {
            self.executable(op)?;
        }
        Ok(())
    }

    fn literal(arg: &Arg) -> Result<xla::Literal> {
        // Safety: f32/i32 slices reinterpreted as bytes; x86-64 is little
        // endian, matching the on-disk and XLA layouts.
        let (ty, dims, bytes): (xla::ElementType, &[usize], &[u8]) = match arg {
            Arg::F32(t) => (xla::ElementType::F32, &t.shape, unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            }),
            Arg::I32(t) => (xla::ElementType::S32, &t.shape, unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            }),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
            .map_err(|e| anyhow::anyhow!("creating literal: {e:?}"))
    }

    /// Upload a tensor to a device-resident buffer (used to pin weights
    /// once instead of re-serializing them on every call — the L3 perf
    /// optimization recorded in EXPERIMENTS.md §Perf).
    pub fn buffer_from_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading f32 buffer: {e:?}"))
    }

    pub fn buffer_from_i32(&self, t: &crate::runtime::TensorI32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading i32 buffer: {e:?}"))
    }

    /// Execute `op` with pre-uploaded device buffers (weights cached across
    /// calls; activations uploaded per call by the caller).  Shape checking
    /// is the caller's responsibility on this fast path.
    pub fn execute_buffers(
        &self,
        op: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let spec = self.op_spec(op)?;
        if args.len() != spec.params.len() {
            bail!("op {op}: expected {} args, got {}", spec.params.len(), args.len());
        }
        let exe = self.executable(op)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow::anyhow!("executing {op}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {op} result: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {op} result: {e:?}"))?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_wall_us += t0.elapsed().as_micros() as u64;
        }
        if tuple.len() != spec.outputs.len() {
            bail!(
                "op {op}: manifest promises {} outputs, executable returned {}",
                spec.outputs.len(),
                tuple.len()
            );
        }
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, (shape, _)) in tuple.iter().zip(&spec.outputs) {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            lit.copy_raw_to(&mut data)
                .map_err(|e| anyhow::anyhow!("reading {op} output: {e:?}"))?;
            out.push(Tensor::new(shape.clone(), data)?);
        }
        Ok(out)
    }

    /// Execute `op` with `args`; returns the output tensors (all f32 —
    /// every entry point returns f32 tuples).
    pub fn execute(&self, op: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let spec = self.op_spec(op)?;
        if args.len() != spec.params.len() {
            bail!(
                "op {op}: expected {} args, got {}",
                spec.params.len(),
                args.len()
            );
        }
        for (i, (arg, (shape, dtype))) in args.iter().zip(&spec.params).enumerate() {
            if arg.shape() != shape.as_slice() || arg.dtype() != *dtype {
                bail!(
                    "op {op} arg {i}: expected {dtype} {shape:?}, got {} {:?}",
                    arg.dtype(),
                    arg.shape()
                );
            }
        }
        let exe = self.executable(op)?;
        let literals = args.iter().map(Self::literal).collect::<Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {op}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {op} result: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {op} result: {e:?}"))?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_wall_us += t0.elapsed().as_micros() as u64;
        }

        if tuple.len() != spec.outputs.len() {
            bail!(
                "op {op}: manifest promises {} outputs, executable returned {}",
                spec.outputs.len(),
                tuple.len()
            );
        }
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, (shape, _)) in tuple.iter().zip(&spec.outputs) {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading {op} output: {e:?}"))?;
            out.push(Tensor::new(shape.clone(), data)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::runtime::tensor::TensorI32;

    fn rt() -> Runtime {
        Runtime::open(artifacts_root().join("mixtral-tiny")).expect("make artifacts first")
    }

    #[test]
    fn manifest_parses_and_lists_ops() {
        let rt = rt();
        assert!(rt.has_op("expert_b1"));
        assert!(rt.has_op("attn_prefill_s32"));
        assert!(rt.has_op("attn_decode_b1_c128"));
        assert!(rt.has_op("gate_b16"));
        assert!(rt.has_op("lm_head_b1"));
        assert!(!rt.has_op("nonexistent"));
    }

    #[test]
    fn execute_expert_matches_scaling_property() {
        // expert(0) == 0 — zero rows must map to zero rows.
        let rt = rt();
        let spec = rt.op_spec("expert_b2").unwrap().clone();
        let h = spec.params[0].0[1];
        let f = spec.params[1].0[1];
        let x = Tensor::zeros(vec![2, h]);
        let w1 = Tensor::new(vec![h, f], (0..h * f).map(|i| (i % 7) as f32 * 0.01).collect()).unwrap();
        let w3 = w1.clone();
        let w2 = Tensor::new(vec![f, h], (0..h * f).map(|i| (i % 5) as f32 * 0.01).collect()).unwrap();
        let out = rt
            .execute("expert_b2", &[x.into(), w1.into(), w3.into(), w2.into()])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].data.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn execute_rejects_shape_mismatch() {
        let rt = rt();
        let bad = Tensor::zeros(vec![3, 3]);
        let err = rt
            .execute("expert_b1", &[bad.clone().into(), bad.clone().into(), bad.clone().into(), bad.into()])
            .unwrap_err();
        assert!(format!("{err}").contains("expected"));
    }

    #[test]
    fn gate_probs_sum_to_one() {
        let rt = rt();
        let spec = rt.op_spec("gate_b4").unwrap().clone();
        let h = spec.params[0].0[1];
        let e = spec.params[2].0[1];
        let x = Tensor::new(vec![4, h], (0..4 * h).map(|i| (i as f32 * 0.01).sin()).collect()).unwrap();
        let nrm = Tensor::new(vec![h], vec![1.0; h]).unwrap();
        let wg = Tensor::new(vec![h, e], (0..h * e).map(|i| (i as f32 * 0.1).cos() * 0.2).collect()).unwrap();
        let out = rt.execute("gate_b4", &[x.into(), nrm.into(), wg.into()]).unwrap();
        assert_eq!(out.len(), 2);
        let probs = &out[0];
        for r in 0..4 {
            let s: f32 = probs.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn decode_op_accepts_i32_positions() {
        let rt = rt();
        let spec = rt.op_spec("attn_decode_b1_c128").unwrap().clone();
        let h = spec.params[0].0[1];
        let (c, kv, d) = (spec.params[1].0[1], spec.params[1].0[2], spec.params[1].0[3]);
        let qd = spec.params[5].0[1]; // wq: [h, n_heads*head_dim]
        let args: Vec<Arg> = vec![
            Tensor::zeros(vec![1, h]).into(),
            Tensor::zeros(vec![1, c, kv, d]).into(),
            Tensor::zeros(vec![1, c, kv, d]).into(),
            TensorI32::vec(vec![0]).into(),
            Tensor::new(vec![h], vec![1.0; h]).unwrap().into(),
            Tensor::zeros(vec![h, qd]).into(),
            Tensor::zeros(vec![h, kv * d]).into(),
            Tensor::zeros(vec![h, kv * d]).into(),
            Tensor::zeros(vec![qd, h]).into(),
        ];
        let out = rt.execute("attn_decode_b1_c128", &args).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].shape, vec![1, h]);
    }

    #[test]
    fn stats_accumulate() {
        let rt = rt();
        let before = rt.stats().executions;
        let spec = rt.op_spec("lm_head_b1").unwrap().clone();
        let h = spec.params[0].0[1];
        let v = spec.params[2].0[1];
        let args: Vec<Arg> = vec![
            Tensor::zeros(vec![1, h]).into(),
            Tensor::new(vec![h], vec![1.0; h]).unwrap().into(),
            Tensor::zeros(vec![h, v]).into(),
        ];
        rt.execute("lm_head_b1", &args).unwrap();
        let st = rt.stats();
        assert_eq!(st.executions, before + 1);
        assert!(st.compile_count >= 1);
    }
}
