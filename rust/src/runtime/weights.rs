//! Model weight store: loads the flat f32 tensors exported by
//! `python/compile/export_weights.py` according to `weights_manifest.json`.

use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::util::json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// All tensors of one model, keyed by the manifest names
/// (`embed`, `layers.{i}.wq`, `layers.{i}.experts.{e}.w1`, ...).
///
/// Tensors are stored behind `Arc` so the parallel expert executor can
/// hand weight references to worker threads without copying the data
/// (borrowed access through [`WeightStore::get`] is unchanged).
pub struct WeightStore {
    tensors: BTreeMap<String, Arc<Tensor>>,
    pub config: ModelConfig,
}

impl WeightStore {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<WeightStore> {
        let dir = artifact_dir.as_ref();
        let config = ModelConfig::load(dir)?;
        let manifest = json::load(dir.join("weights_manifest.json"))?;
        let mut tensors = BTreeMap::new();
        for (name, desc) in manifest.get("tensors")?.as_obj()? {
            let file = desc.get("file")?.as_str()?;
            let shape = desc.get("shape")?.as_usize_vec()?;
            let path = dir.join(file);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading weight {}", path.display()))?;
            let n: usize = shape.iter().product();
            anyhow::ensure!(
                bytes.len() == 4 * n,
                "weight {name}: file has {} bytes, shape {:?} needs {}",
                bytes.len(),
                shape,
                4 * n
            );
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.insert(name.clone(), Arc::new(Tensor { shape, data }));
        }
        Ok(WeightStore { tensors, config })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .map(|t| t.as_ref())
            .ok_or_else(|| anyhow::anyhow!("missing weight tensor {name:?}"))
    }

    /// Shared handle to a tensor (cheap clone; used to ship weights to the
    /// executor pool's worker threads).
    pub fn get_shared(&self, name: &str) -> Result<Arc<Tensor>> {
        self.tensors
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing weight tensor {name:?}"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    // -- typed accessors -------------------------------------------------

    pub fn embed(&self) -> &Tensor {
        self.get("embed").unwrap()
    }

    pub fn final_norm(&self) -> &Tensor {
        self.get("final_norm").unwrap()
    }

    pub fn lm_head(&self) -> &Tensor {
        self.get("lm_head").unwrap()
    }

    pub fn layer(&self, i: usize, name: &str) -> &Tensor {
        self.get(&format!("layers.{i}.{name}")).unwrap()
    }

    pub fn expert(&self, layer: usize, expert: usize, name: &str) -> &Tensor {
        self.get(&format!("layers.{layer}.experts.{expert}.{name}")).unwrap()
    }

    /// Shared handle to one expert weight matrix (executor pool path).
    pub fn expert_shared(&self, layer: usize, expert: usize, name: &str) -> Arc<Tensor> {
        self.get_shared(&format!("layers.{layer}.experts.{expert}.{name}")).unwrap()
    }

    /// Embedding lookup on the host (the one model op that never touches
    /// the PJRT path — it is a table read, not compute).
    pub fn embed_tokens(&self, tokens: &[u32]) -> Tensor {
        let e = self.embed();
        let h = e.shape[1];
        let mut out = Tensor::zeros(vec![tokens.len(), h]);
        for (i, &t) in tokens.iter().enumerate() {
            assert!((t as usize) < e.shape[0], "token {t} out of vocab");
            out.row_mut(i).copy_from_slice(e.row(t as usize));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> std::path::PathBuf {
        crate::config::model::artifacts_root().join("mixtral-tiny")
    }

    #[test]
    fn loads_all_tensors() {
        let ws = WeightStore::load(art()).expect("run `make artifacts` first");
        // 3 globals + per layer (7 + 3 * n_experts)
        let cfg = &ws.config;
        let expected = 3 + cfg.n_layers * (7 + 3 * cfg.n_experts);
        assert_eq!(ws.len(), expected);
        assert_eq!(ws.embed().shape, vec![cfg.vocab, cfg.hidden]);
        assert_eq!(
            ws.expert(0, 0, "w1").shape,
            vec![cfg.hidden, cfg.ffn]
        );
        assert_eq!(
            ws.expert(cfg.n_layers - 1, cfg.n_experts - 1, "w2").shape,
            vec![cfg.ffn, cfg.hidden]
        );
    }

    #[test]
    fn embed_tokens_matches_rows() {
        let ws = WeightStore::load(art()).unwrap();
        let out = ws.embed_tokens(&[0, 5, 0]);
        assert_eq!(out.shape, vec![3, ws.config.hidden]);
        assert_eq!(out.row(0), ws.embed().row(0));
        assert_eq!(out.row(1), ws.embed().row(5));
        assert_eq!(out.row(0), out.row(2));
    }

    #[test]
    fn weights_not_degenerate() {
        let ws = WeightStore::load(art()).unwrap();
        let w1 = ws.expert(1, 3, "w1");
        let nonzero = w1.data.iter().filter(|v| v.abs() > 1e-8).count();
        assert!(nonzero > w1.numel() / 2);
    }
}
