//! Pluggable eviction policies for the [`ExpertCache`](super::ExpertCache).
//!
//! The cache evicts the unpinned resident expert with the **lowest**
//! retention score; recency ticks are the common substrate, and each
//! policy adds protection on top of it:
//!
//! * [`Lru`] — recency only (what `hardware::memory` inlined and the
//!   Mixtral-Offloading baseline assumes),
//! * [`ScoredPopularity`] — recency plus a popularity bonus from online
//!   routing counts (HybriMoE-style frequency × recency scoring),
//! * [`TransitionAware`] — recency plus a large bonus for experts the
//!   cross-layer transition statistics predict for the next layer
//!   (reusing what [`crate::prefetch::TransitionProfile`] learns offline,
//!   but updated online with exponential decay so it tracks drifting
//!   routing distributions).

use super::ExpertId;
use crate::popularity::Profile;
use crate::prefetch::TransitionProfile;
use std::collections::HashSet;

pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;

    /// Retention score of a resident expert (`last_use` is the cache's
    /// logical tick of its most recent use).  The cache evicts the
    /// unpinned expert with the LOWEST score, ties broken by id.
    fn retention_score(&self, id: ExpertId, last_use: u64) -> f64;

    /// Observe one layer's routed token counts before it is planned, so
    /// stateful policies can track popularity / predicted transitions.
    fn observe_layer(&mut self, _layer: usize, _inp_size: &[usize]) {}
}

// ---------------------------------------------------------------------------

/// Pure recency: classic LRU.
#[derive(Clone, Copy, Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn retention_score(&self, _id: ExpertId, last_use: u64) -> f64 {
        last_use as f64
    }
}

// ---------------------------------------------------------------------------

/// Popularity × recency: a maximally popular expert earns
/// `popularity_weight` extra ticks of protection, so hot experts survive
/// churn from one-off admissions while cold entries age out as in LRU.
pub struct ScoredPopularity {
    counts: Profile,
    max_count: u64,
    /// Recency-tick bonus earned by the most popular expert.
    pub popularity_weight: f64,
}

impl ScoredPopularity {
    /// Cold start: popularity is learned online from `observe_layer`.
    pub fn new(n_layers: usize, n_experts: usize) -> ScoredPopularity {
        Self::from_profile(Profile::new(n_layers, n_experts))
    }

    /// Seed from a build-time popularity profile (calibration counts).
    pub fn from_profile(counts: Profile) -> ScoredPopularity {
        let max_count = counts.counts.iter().flatten().copied().max().unwrap_or(0);
        ScoredPopularity { counts, max_count, popularity_weight: 64.0 }
    }
}

impl EvictionPolicy for ScoredPopularity {
    fn name(&self) -> &'static str {
        "scored"
    }

    fn observe_layer(&mut self, layer: usize, inp_size: &[usize]) {
        if layer >= self.counts.n_layers {
            return;
        }
        for (e, &s) in inp_size.iter().enumerate().take(self.counts.n_experts) {
            if s > 0 {
                self.counts.record(layer, e, s as u64);
                self.max_count = self.max_count.max(self.counts.counts[layer][e]);
            }
        }
    }

    fn retention_score(&self, (l, e): ExpertId, last_use: u64) -> f64 {
        let pop = if self.max_count == 0 || l >= self.counts.n_layers || e >= self.counts.n_experts
        {
            0.0
        } else {
            self.counts.counts[l][e] as f64 / self.max_count as f64
        };
        last_use as f64 + self.popularity_weight * pop
    }
}

// ---------------------------------------------------------------------------

/// Transition-aware: protects the experts most likely needed at the next
/// layer, predicted from exponentially-decayed cross-layer transition
/// mass.  Decode-layer access is cyclic (layer 0..L-1, repeat), the regime
/// where plain LRU evicts exactly the upcoming layer's experts; protecting
/// predicted successors removes that pathology.
pub struct TransitionAware {
    n_layers: usize,
    n_experts: usize,
    /// Decayed transition mass `w[l][i][j]`: expert `i` active at layer
    /// `l` followed by expert `j` at layer `l+1`.
    w: Vec<Vec<Vec<f64>>>,
    /// Per-step retention of old transition mass (decayed once per
    /// observed layer-0 routing, i.e. once per decode step).
    pub decay: f64,
    /// How many predicted next-layer experts to protect.
    pub depth: usize,
    /// Recency-tick bonus for protected experts; large enough to dominate
    /// any realistic recency gap.
    pub protect_bonus: f64,
    protected: HashSet<ExpertId>,
    prev: Option<(usize, Vec<usize>)>,
}

impl TransitionAware {
    /// Cold start: transitions are learned online.
    pub fn new(n_layers: usize, n_experts: usize, depth: usize) -> TransitionAware {
        TransitionAware {
            n_layers,
            n_experts,
            w: vec![vec![vec![0.0; n_experts]; n_experts]; n_layers.saturating_sub(1)],
            decay: 0.95,
            depth,
            protect_bonus: 1e12,
            protected: HashSet::new(),
            prev: None,
        }
    }

    /// Seed the online mass from a build-time transition profile: each
    /// observed (l, i) row contributes `seed_mass` total, split by the
    /// calibration distribution, so cold-start predictions match the
    /// offline predictor and then adapt.
    pub fn from_profile(t: &TransitionProfile, depth: usize) -> TransitionAware {
        let mut p = Self::new(t.n_layers, t.n_experts, depth);
        let seed_mass = 16.0;
        for (l, rows) in t.counts.iter().enumerate() {
            for (i, row) in rows.iter().enumerate() {
                let total: u64 = row.iter().sum();
                if total == 0 {
                    continue;
                }
                for (j, &c) in row.iter().enumerate() {
                    p.w[l][i][j] = seed_mass * c as f64 / total as f64;
                }
            }
        }
        p
    }

    /// Experts currently protected from eviction.
    pub fn protected(&self) -> &HashSet<ExpertId> {
        &self.protected
    }
}

impl EvictionPolicy for TransitionAware {
    fn name(&self) -> &'static str {
        "transition"
    }

    fn observe_layer(&mut self, layer: usize, inp_size: &[usize]) {
        if layer >= self.n_layers || inp_size.len() != self.n_experts {
            return;
        }
        let active: Vec<usize> =
            inp_size.iter().enumerate().filter(|(_, &s)| s > 0).map(|(e, _)| e).collect();

        // Online update: record transitions from the previously observed
        // layer's active set into this one.
        if let Some((pl, prev)) = self.prev.take() {
            if pl + 1 == layer {
                for &i in &prev {
                    for &j in &active {
                        self.w[pl][i][j] += 1.0;
                    }
                }
            }
        }
        // One decay pass per decode step (layer 0 marks a new step) keeps
        // the mass tracking the current phase of a drifting workload; the
        // protection set also resets per step and then accumulates over
        // its layers, so every still-upcoming prediction stays protected.
        if layer == 0 {
            for l in &mut self.w {
                for row in l {
                    for v in row {
                        *v *= self.decay;
                    }
                }
            }
            self.protected.clear();
        }

        // Predict the next layer's experts and protect them.
        if layer + 1 < self.n_layers {
            let t = &self.w[layer];
            let mut score = vec![0.0f64; self.n_experts];
            for &i in &active {
                for (j, sc) in score.iter_mut().enumerate() {
                    *sc += t[i][j];
                }
            }
            let mut idx: Vec<usize> = (0..self.n_experts).collect();
            idx.sort_by(|&a, &b| {
                score[b].partial_cmp(&score[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
            });
            for &j in idx.iter().take(self.depth) {
                self.protected.insert((layer + 1, j));
            }
        }
        self.prev = Some((layer, active));
    }

    fn retention_score(&self, id: ExpertId, last_use: u64) -> f64 {
        let bonus = if self.protected.contains(&id) { self.protect_bonus } else { 0.0 };
        last_use as f64 + bonus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_score_is_recency() {
        let p = Lru;
        assert!(p.retention_score((0, 0), 5) < p.retention_score((0, 0), 9));
    }

    #[test]
    fn scored_popularity_protects_hot_expert() {
        let mut p = ScoredPopularity::new(1, 4);
        for _ in 0..50 {
            p.observe_layer(0, &[3, 0, 0, 0]); // expert 0 hot
        }
        p.observe_layer(0, &[0, 1, 0, 0]);
        // Same recency: the popular expert scores higher.
        assert!(p.retention_score((0, 0), 10) > p.retention_score((0, 1), 10));
        // A much more recent cold expert still wins eventually.
        assert!(p.retention_score((0, 1), 1000) > p.retention_score((0, 0), 10));
    }

    #[test]
    fn scored_popularity_ignores_out_of_range() {
        let mut p = ScoredPopularity::new(1, 2);
        p.observe_layer(7, &[1, 1]); // out-of-range layer: no panic
        assert_eq!(p.retention_score((7, 0), 3), 3.0);
    }

    #[test]
    fn transition_aware_learns_and_protects() {
        let mut p = TransitionAware::new(3, 4, 1);
        // Expert 0 at layer 0 is always followed by expert 2 at layer 1.
        for _ in 0..10 {
            p.observe_layer(0, &[1, 0, 0, 0]);
            p.observe_layer(1, &[0, 0, 1, 0]);
            p.observe_layer(2, &[0, 1, 0, 0]);
        }
        p.observe_layer(0, &[1, 0, 0, 0]);
        assert!(p.protected().contains(&(1, 2)), "learned successor not protected");
        let base = p.retention_score((1, 3), 100);
        let prot = p.retention_score((1, 2), 1);
        assert!(prot > base, "protection must dominate recency");
    }

    #[test]
    fn transition_aware_adapts_after_drift() {
        let mut p = TransitionAware::new(2, 4, 1);
        for _ in 0..30 {
            p.observe_layer(0, &[1, 0, 0, 0]);
            p.observe_layer(1, &[0, 0, 1, 0]); // 0 -> 2
        }
        // Phase shift: 0 -> 3 from now on.  Decay forgets the old mapping.
        for _ in 0..60 {
            p.observe_layer(0, &[1, 0, 0, 0]);
            p.observe_layer(1, &[0, 0, 0, 1]);
        }
        p.observe_layer(0, &[1, 0, 0, 0]);
        assert!(p.protected().contains(&(1, 3)), "did not adapt to the new phase");
        assert!(!p.protected().contains(&(1, 2)));
    }

    #[test]
    fn transition_aware_seeds_from_offline_profile() {
        let e = 4;
        let mut counts = vec![vec![vec![0u64; e]; e]; 1];
        counts[0][1][3] = 100; // 1 at layer 0 predicts 3 at layer 1
        let t = TransitionProfile { n_layers: 2, n_experts: e, counts };
        let mut p = TransitionAware::from_profile(&t, 1);
        p.observe_layer(0, &[0, 2, 0, 0]);
        assert!(p.protected().contains(&(1, 3)));
    }

    #[test]
    fn transition_aware_guards_dim_mismatch() {
        let mut p = TransitionAware::new(2, 4, 1);
        p.observe_layer(0, &[1, 1]); // wrong width: ignored, no panic
        p.observe_layer(9, &[1, 1, 1, 1]); // out-of-range layer: ignored
        assert!(p.protected().is_empty());
    }
}
