//! `fiddler-cached` — the paper's Algorithm 1 over a *dynamically managed*
//! expert cache (serving mode [`crate::config::serving::Policy::FiddlerCached`]).
//!
//! Plain Fiddler fills the whole GPU budget with pinned popular experts, so
//! residency never adapts; under a drifting routing distribution the pinned
//! set decays (the motivation behind HybriMoE / MoE-Lightning — PAPERS.md).
//! This policy pins only a fraction of the capacity by popularity and lets
//! the [`ExpertCache`] manage the rest:
//!
//! * per-expert decisions are exactly Algorithm 1 (resident -> GPU,
//!   otherwise CPU vs transfer by cost),
//! * a demand transfer (prefill regime) admits the expert into the cache,
//! * a CPU-served miss (decode regime) triggers a *background* admission
//!   over the idle, serialized PCIe lane — the expert becomes usable a few
//!   layers later without blocking anything, which is how residency tracks
//!   the workload,
//! * victims are chosen by the installed [`EvictionPolicy`].

use super::eviction::EvictionPolicy;
use crate::config::serving::PlacementStrategy;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::placement::choose_experts;
use crate::popularity::Profile;
use crate::scheduler::policy::ExecPolicy;
use crate::scheduler::{decide_expert, ExpertPlan};

pub struct CachedFiddlerPolicy {
    pub placement: PlacementStrategy,
    /// Fraction of the GPU expert capacity pinned by popularity at init;
    /// the remainder is the dynamic working set.  At least one slot always
    /// stays unpinned so the cache can adapt.
    pub pin_fraction: f64,
    /// Installed into the cache during `init` (before dynamic entries).
    eviction: Option<Box<dyn EvictionPolicy>>,
}

impl CachedFiddlerPolicy {
    pub fn new(
        eviction: Box<dyn EvictionPolicy>,
        placement: PlacementStrategy,
        pin_fraction: f64,
    ) -> CachedFiddlerPolicy {
        assert!((0.0..=1.0).contains(&pin_fraction), "pin_fraction out of [0, 1]");
        CachedFiddlerPolicy { placement, pin_fraction, eviction: Some(eviction) }
    }
}

impl ExecPolicy for CachedFiddlerPolicy {
    fn name(&self) -> &'static str {
        "fiddler-cached"
    }

    fn init(&mut self, memory: &mut ExpertCache, profile: &Profile, seed: u64) {
        if let Some(p) = self.eviction.take() {
            memory.set_policy(p);
        }
        let budget = ((memory.capacity() as f64 * self.pin_fraction).floor() as usize)
            .min(memory.capacity().saturating_sub(1));
        for id in choose_experts(profile, budget, self.placement, seed) {
            memory.pin(id);
        }
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        memory.observe_layer(layer, inp_size);
        inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    return None;
                }
                let id = (layer, j);
                let resident = memory.lookup(id, now_us);
                let plan = decide_expert(resident, s, lat);
                match plan {
                    // The demand transfer just put the weights on the GPU:
                    // keep them (prefill admissions warm the decode phase).
                    Some(ExpertPlan::GpuTransfer) => {
                        memory.admit(id);
                    }
                    // Decode-regime miss: serve on the CPU now, and bring
                    // the expert in over the idle PCIe lane for future
                    // steps.
                    Some(ExpertPlan::Cpu) => {
                        let _ = memory.prefetch(id, now_us, lat.transfer_lat());
                    }
                    _ => {}
                }
                plan
            })
            .collect()
    }

    fn expert_cost_us(&self, plan: ExpertPlan, s: usize, lat: &LatencyModel) -> f64 {
        match plan {
            // Same overlap as Fiddler (§3.2): streaming hides compute.
            ExpertPlan::GpuTransfer => lat.transfer_lat().max(lat.gpu_lat(s)),
            p => p.cost_us(lat, s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::expertcache::eviction::Lru;

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    fn profile() -> Profile {
        let mut p = Profile::new(1, 4);
        p.counts[0] = vec![100, 1, 50, 2];
        p
    }

    #[test]
    fn init_pins_only_a_fraction() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        pol.init(&mut mem, &profile(), 0);
        assert_eq!(mem.resident_count(), 2);
        assert!(mem.is_pinned((0, 0)));
        assert!(mem.is_pinned((0, 2)));
    }

    #[test]
    fn full_pin_fraction_leaves_one_dynamic_slot() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 1.0);
        let mut mem = ExpertCache::with_capacity(3);
        pol.init(&mut mem, &profile(), 0);
        assert_eq!(mem.resident_count(), 2, "one slot must stay unpinned");
    }

    #[test]
    fn decode_miss_prefetches_in_background() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        // Expert 1 misses with one token: CPU now, admitted asynchronously.
        let plans = pol.plan_layer(0, &[0, 1, 0, 0], &mut mem, &lat, 0.0);
        assert_eq!(plans[1], Some(ExpertPlan::Cpu));
        assert!(mem.is_resident((0, 1)), "background admission missing");
        assert!(!mem.is_ready((0, 1), 0.0), "must not be usable instantly");
        // Once the transfer completes it is a straight hit.
        let later = lat.transfer_lat() + 1.0;
        let plans = pol.plan_layer(0, &[0, 1, 0, 0], &mut mem, &lat, later);
        assert_eq!(plans[1], Some(ExpertPlan::GpuResident));
        assert_eq!(mem.stats().prefetch_hits, 1);
    }

    #[test]
    fn prefill_transfer_is_admitted() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        let plans = pol.plan_layer(0, &[0, 900, 0, 0], &mut mem, &lat, 0.0);
        assert_eq!(plans[1], Some(ExpertPlan::GpuTransfer));
        assert!(mem.is_ready((0, 1), 0.0), "demand admission is synchronous");
    }

    #[test]
    fn numerically_identical_plans_to_algorithm_1() {
        // The cached mode may change WHERE costs accrue, never the plan
        // semantics: resident -> GPU, else cost argmin.
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        let plans = pol.plan_layer(0, &[1, 1, 0, 900], &mut mem, &lat, 0.0);
        assert_eq!(plans[0], Some(ExpertPlan::GpuResident));
        assert_eq!(plans[1], Some(ExpertPlan::Cpu));
        assert_eq!(plans[2], None);
        assert_eq!(plans[3], Some(ExpertPlan::GpuTransfer));
    }
}
