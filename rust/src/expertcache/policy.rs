//! `fiddler-cached` — the paper's Algorithm 1 over a *dynamically managed*
//! expert cache (serving mode [`crate::config::serving::Policy::FiddlerCached`]).
//!
//! Plain Fiddler fills the whole GPU budget with pinned popular experts, so
//! residency never adapts; under a drifting routing distribution the pinned
//! set decays (the motivation behind HybriMoE / MoE-Lightning — PAPERS.md).
//! This policy pins only a fraction of the capacity by popularity and lets
//! the [`ExpertCache`] manage the rest:
//!
//! * per-expert decisions are exactly Algorithm 1 (resident -> GPU,
//!   otherwise CPU vs transfer by cost),
//! * a demand transfer (prefill regime) admits the expert into the cache,
//! * a CPU-served miss (decode regime) triggers a *background* admission
//!   over the idle, serialized PCIe lane — the expert becomes usable a few
//!   layers later without blocking anything, which is how residency tracks
//!   the workload,
//! * victims are chosen by the installed [`EvictionPolicy`].

use super::eviction::EvictionPolicy;
use crate::config::serving::PlacementStrategy;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::placement::choose_experts;
use crate::popularity::Profile;
use crate::scheduler::policy::ExecPolicy;
use crate::scheduler::{decide_expert, decide_expert_tiered, ExpertPlan};

pub struct CachedFiddlerPolicy {
    pub placement: PlacementStrategy,
    /// Fraction of the GPU expert capacity pinned by popularity at init;
    /// the remainder is the dynamic working set.  At least one slot always
    /// stays unpinned so the cache can adapt.
    pub pin_fraction: f64,
    /// Installed into the cache during `init` (before dynamic entries).
    eviction: Option<Box<dyn EvictionPolicy>>,
    /// Low-bit resident tier (`--quant-tier on`): bit width of quantized
    /// copies.  `None` (default) plans exactly the two-way Algorithm 1 —
    /// the `--quant-tier off` bit-identity contract.
    quant_bits: Option<u32>,
    /// Quantization error budget, re-armed at every layer-0 planning
    /// call (i.e. per token step — the engine-side approximation of the
    /// per-request budget the serving scheduler enforces).  Each
    /// accepted quantized hit spends its expert's max-abs error; once
    /// exhausted, quantized hits are corrected to fp promotions.
    error_budget: f64,
    budget_left: f64,
    /// `--cache-partition layer`: installed on the cache during `init`.
    partition_layers: Option<usize>,
}

impl CachedFiddlerPolicy {
    pub fn new(
        eviction: Box<dyn EvictionPolicy>,
        placement: PlacementStrategy,
        pin_fraction: f64,
    ) -> CachedFiddlerPolicy {
        assert!((0.0..=1.0).contains(&pin_fraction), "pin_fraction out of [0, 1]");
        CachedFiddlerPolicy {
            placement,
            pin_fraction,
            eviction: Some(eviction),
            quant_bits: None,
            error_budget: 0.0,
            budget_left: 0.0,
            partition_layers: None,
        }
    }

    /// Enable the low-bit resident tier: `init` converts half the cache's
    /// fp capacity into quantized copies and planning becomes the
    /// three-way Algorithm 1 under `error_budget`.
    pub fn with_quant_tier(mut self, bits: u32, error_budget: f64) -> Self {
        assert!(error_budget >= 0.0, "error budget must be non-negative");
        self.quant_bits = Some(bits.clamp(2, 16));
        self.error_budget = error_budget;
        self
    }

    /// Partition the cache's fp capacity evenly across `n_layers`.
    pub fn with_layer_partition(mut self, n_layers: usize) -> Self {
        self.partition_layers = Some(n_layers);
        self
    }
}

impl ExecPolicy for CachedFiddlerPolicy {
    fn name(&self) -> &'static str {
        "fiddler-cached"
    }

    fn init(&mut self, memory: &mut ExpertCache, profile: &Profile, seed: u64) {
        if let Some(p) = self.eviction.take() {
            memory.set_policy(p);
        }
        // Tier split and partition BEFORE pinning, so the popular core is
        // pinned against the (possibly halved) fp capacity.
        if let Some(bits) = self.quant_bits {
            memory.enable_quant_tier(bits);
            self.budget_left = self.error_budget;
        }
        if let Some(n) = self.partition_layers {
            memory.partition_by_layer(n);
        }
        let budget = ((memory.capacity() as f64 * self.pin_fraction).floor() as usize)
            .min(memory.capacity().saturating_sub(1));
        for id in choose_experts(profile, budget, self.placement, seed) {
            memory.pin(id);
        }
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        memory.observe_layer(layer, inp_size);
        // Per-token budget: a fresh layer-0 planning call starts a step.
        if layer == 0 {
            self.budget_left = self.error_budget;
        }
        inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    return None;
                }
                let id = (layer, j);
                let resident = memory.lookup(id, now_us);
                let Some(bits) = self.quant_bits else {
                    // Tier off: exactly the seed two-way Algorithm 1.
                    let plan = decide_expert(resident, s, lat);
                    match plan {
                        // The demand transfer just put the weights on the
                        // GPU: keep them (prefill admissions warm the
                        // decode phase).
                        Some(ExpertPlan::GpuTransfer) => {
                            memory.admit(id);
                        }
                        // Decode-regime miss: serve on the CPU now, and
                        // bring the expert in over the idle PCIe lane for
                        // future steps.
                        Some(ExpertPlan::Cpu) => {
                            let _ = memory.prefetch(id, now_us, lat.transfer_lat());
                        }
                        _ => {}
                    }
                    return plan;
                };
                // Three-way Algorithm 1 over the tier hierarchy.
                let err = crate::quant::synthetic_expert_error(layer, j, bits);
                let quant = memory.lookup_quant(id, now_us, err);
                let mut plan = decide_expert_tiered(resident, quant, s, lat);
                match plan {
                    Some(ExpertPlan::GpuQuant) => {
                        if self.budget_left >= err {
                            self.budget_left -= err;
                        } else {
                            // Budget exhausted: correct — promote the fp
                            // master now and run at full precision.
                            memory.note_quant_corrected(id, now_us);
                            memory.promote(id);
                            plan = Some(ExpertPlan::GpuTransfer);
                        }
                    }
                    Some(ExpertPlan::GpuTransfer) => {
                        memory.admit(id);
                    }
                    Some(ExpertPlan::Cpu) => {
                        // Decode-regime miss: a quantized admit rides the
                        // lane at bits/16 of the fp cost, so residency
                        // tracks the workload sooner; the pipeline may
                        // later promote it to fp.
                        let _ = memory.admit_quant(id, now_us, lat.quant_transfer_lat(bits));
                    }
                    _ => {}
                }
                plan
            })
            .collect()
    }

    fn expert_cost_us(&self, plan: ExpertPlan, s: usize, lat: &LatencyModel) -> f64 {
        match plan {
            // Same overlap as Fiddler (§3.2): streaming hides compute.
            ExpertPlan::GpuTransfer => lat.transfer_lat().max(lat.gpu_lat(s)),
            p => p.cost_us(lat, s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::expertcache::eviction::Lru;

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    fn profile() -> Profile {
        let mut p = Profile::new(1, 4);
        p.counts[0] = vec![100, 1, 50, 2];
        p
    }

    #[test]
    fn init_pins_only_a_fraction() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        pol.init(&mut mem, &profile(), 0);
        assert_eq!(mem.resident_count(), 2);
        assert!(mem.is_pinned((0, 0)));
        assert!(mem.is_pinned((0, 2)));
    }

    #[test]
    fn full_pin_fraction_leaves_one_dynamic_slot() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 1.0);
        let mut mem = ExpertCache::with_capacity(3);
        pol.init(&mut mem, &profile(), 0);
        assert_eq!(mem.resident_count(), 2, "one slot must stay unpinned");
    }

    #[test]
    fn decode_miss_prefetches_in_background() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        // Expert 1 misses with one token: CPU now, admitted asynchronously.
        let plans = pol.plan_layer(0, &[0, 1, 0, 0], &mut mem, &lat, 0.0);
        assert_eq!(plans[1], Some(ExpertPlan::Cpu));
        assert!(mem.is_resident((0, 1)), "background admission missing");
        assert!(!mem.is_ready((0, 1), 0.0), "must not be usable instantly");
        // Once the transfer completes it is a straight hit.
        let later = lat.transfer_lat() + 1.0;
        let plans = pol.plan_layer(0, &[0, 1, 0, 0], &mut mem, &lat, later);
        assert_eq!(plans[1], Some(ExpertPlan::GpuResident));
        assert_eq!(mem.stats().prefetch_hits, 1);
    }

    #[test]
    fn prefill_transfer_is_admitted() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        let plans = pol.plan_layer(0, &[0, 900, 0, 0], &mut mem, &lat, 0.0);
        assert_eq!(plans[1], Some(ExpertPlan::GpuTransfer));
        assert!(mem.is_ready((0, 1), 0.0), "demand admission is synchronous");
    }

    #[test]
    fn quant_tier_serves_demoted_experts_from_the_low_bit_copy() {
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.0)
            .with_quant_tier(8, 10.0); // ample budget: hits are accepted
        let mut mem = ExpertCache::with_capacity(4); // init -> 2 fp + 4 quant
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        assert_eq!(mem.capacity(), 2);
        assert_eq!(mem.quant_capacity(), 4);
        // Fill the fp tier, then demote expert 0 by pressure.
        let _ = pol.plan_layer(0, &[0, 900, 0, 0], &mut mem, &lat, 0.0);
        let _ = pol.plan_layer(0, &[0, 0, 900, 0], &mut mem, &lat, 0.0);
        let _ = pol.plan_layer(0, &[900, 0, 0, 0], &mut mem, &lat, 0.0);
        let _ = pol.plan_layer(0, &[0, 0, 0, 900], &mut mem, &lat, 0.0); // evicts+demotes
        let demoted: Vec<bool> =
            (0..4).map(|e| mem.is_quant_resident((0, e))).collect();
        assert!(demoted.iter().any(|&d| d), "pressure must demote, not discard");
        let victim = demoted.iter().position(|&d| d).unwrap();
        // The demoted expert now serves a single token from the quantized
        // copy (env1: quant beats both CPU and transfer at s=1).
        let mut inp = vec![0usize; 4];
        inp[victim] = 1;
        let plans = pol.plan_layer(0, &inp, &mut mem, &lat, 0.0);
        assert_eq!(plans[victim], Some(ExpertPlan::GpuQuant));
        assert!(mem.stats().quant_hits >= 1);
    }

    #[test]
    fn zero_budget_corrects_every_quantized_hit() {
        // Satellite 4c at the planning layer: error budget 0 never yields
        // a GpuQuant plan — every quantized hit promotes to fp.
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.0)
            .with_quant_tier(8, 0.0);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        let _ = pol.plan_layer(0, &[0, 900, 0, 0], &mut mem, &lat, 0.0);
        let _ = pol.plan_layer(0, &[0, 0, 900, 0], &mut mem, &lat, 0.0);
        let _ = pol.plan_layer(0, &[900, 0, 0, 0], &mut mem, &lat, 0.0);
        let demoted =
            (0..4).find(|&e| mem.is_quant_resident((0, e))).expect("a demotion");
        let mut inp = vec![0usize; 4];
        inp[demoted] = 1;
        let plans = pol.plan_layer(0, &inp, &mut mem, &lat, 0.0);
        assert_eq!(
            plans[demoted],
            Some(ExpertPlan::GpuTransfer),
            "zero budget must correct to an fp promotion"
        );
        assert_eq!(mem.stats().quant_corrected, 1);
        assert_eq!(mem.stats().promotions, 1);
        assert!(mem.is_resident((0, demoted)), "correction leaves the fp master resident");
    }

    #[test]
    fn tier_off_policy_plans_are_unchanged() {
        // The default-constructed policy must not touch any tier state.
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        assert!(!mem.quant_tier_enabled());
        assert_eq!(mem.capacity(), 4, "capacity untouched with the tier off");
        let _ = pol.plan_layer(0, &[1, 1, 900, 0], &mut mem, &lat, 0.0);
        let s = mem.stats();
        assert_eq!((s.quant_hits, s.quant_misses, s.demotions, s.promotions), (0, 0, 0, 0));
    }

    #[test]
    fn numerically_identical_plans_to_algorithm_1() {
        // The cached mode may change WHERE costs accrue, never the plan
        // semantics: resident -> GPU, else cost argmin.
        let mut pol = CachedFiddlerPolicy::new(Box::new(Lru), PlacementStrategy::Popularity, 0.5);
        let mut mem = ExpertCache::with_capacity(4);
        let lat = lat();
        pol.init(&mut mem, &profile(), 0);
        let plans = pol.plan_layer(0, &[1, 1, 0, 900], &mut mem, &lat, 0.0);
        assert_eq!(plans[0], Some(ExpertPlan::GpuResident));
        assert_eq!(plans[1], Some(ExpertPlan::Cpu));
        assert_eq!(plans[2], None);
        assert_eq!(plans[3], Some(ExpertPlan::GpuTransfer));
    }
}
