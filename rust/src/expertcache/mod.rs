//! Dynamic expert-cache subsystem — the single authority for GPU expert
//! residency.
//!
//! The paper pins a static popularity-ranked expert set at initialization
//! (§3.1/§3.4) and models dynamic residency only inside the LRU baseline;
//! follow-up systems (HybriMoE's hybrid cache management, MoE-Lightning's
//! paging — see PAPERS.md) show that score-based *dynamic* caching wins
//! once the routing distribution drifts.  This module factors every form
//! of expert residency the repo models into one substrate:
//!
//! * [`ExpertCache`] — capacity accounting, pinning (initialization-time
//!   placement is a cache with eviction disabled for those entries),
//!   per-expert asynchronous transfer state (an entry inserted by
//!   [`ExpertCache::prefetch`] occupies a slot immediately but only counts
//!   as *ready* once its serialized-PCIe transfer completes), and
//!   hit/miss/eviction/bytes-moved counters ([`CacheStats`]).
//! * [`EvictionPolicy`] ([`eviction`]) — pluggable victim selection:
//!   [`Lru`](eviction::Lru), [`ScoredPopularity`](eviction::ScoredPopularity)
//!   (popularity × recency), and [`TransitionAware`](eviction::TransitionAware)
//!   (protects experts predicted for the next layer from cross-layer
//!   routing transitions).
//! * [`CachedFiddlerPolicy`] ([`policy`]) — the `fiddler-cached` serving
//!   mode: Algorithm 1 planning over a partially pinned, dynamically
//!   managed cache.
//! * [`sim`] — a trace-driven harness that compares eviction policies
//!   under a drifting workload without model artifacts
//!   (`examples/ablation_cache.rs`).
//!
//! All former users of `hardware::memory::GpuMemory` (placement, the
//! scheduler policies, the baselines, prefetching) now route through this
//! type; `GpuMemory` remains as a re-export alias.

pub mod eviction;
pub mod policy;
pub mod sim;

pub use eviction::{EvictionPolicy, Lru, ScoredPopularity, TransitionAware};
pub use policy::CachedFiddlerPolicy;

use crate::config::hardware::PAPER_EXPERT_BYTES;
use crate::config::HardwareConfig;
use crate::util::json::Json;
use std::collections::HashMap;

/// Identifies one expert of one layer.
pub type ExpertId = (usize, usize); // (layer, expert)

/// Residency record of one cached expert.
#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Logical timestamp of the most recent use (recency substrate for
    /// eviction scoring).
    last_use: u64,
    /// Virtual time (µs) at which the expert's weights are usable on the
    /// GPU.  0.0 for pinned entries and synchronous fetches; prefetched
    /// entries carry their transfer-completion timestamp and read as
    /// misses until then.
    ready_us: f64,
    /// Pinned entries are never evicted (initialization-time placement).
    pinned: bool,
    /// Tick at which the entry was pinned (0 = never pinned).  Placement
    /// pins in descending popularity order, so a HIGHER pin tick means a
    /// less popular expert — the release order of
    /// [`ExpertCache::release_pins`].  Unlike `last_use`, never refreshed.
    pin_tick: u64,
    /// Inserted speculatively; the first hit counts as a prefetch hit.
    prefetched: bool,
}

/// Residency record of one low-bit tier entry.  Quantized copies carry
/// no pin state — the tier is purely dynamic — and their recency order
/// is plain LRU (the fp tier keeps the pluggable policy).
#[derive(Clone, Copy, Debug)]
struct QuantEntry {
    last_use: u64,
    /// Transfer-completion time of a lane-admitted copy (0.0 for
    /// demotions, which re-quantize in place on the GPU).
    ready_us: f64,
}

/// Hit/miss/eviction/transfer counters of one cache.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// CPU->GPU weight transfers issued (demand fetches + prefetches,
    /// including transfers that could not be cached because every slot was
    /// pinned).
    pub transfers_in: u64,
    /// Bytes moved over PCIe for those transfers (paper-scale experts).
    pub bytes_in: u64,
    pub prefetches: u64,
    /// Hits whose entry was inserted speculatively.
    pub prefetch_hits: u64,
    /// Low-bit tier lookups (zero whenever the tier is disabled — the
    /// bit-identity contract of `--quant-tier off`).
    pub quant_hits: u64,
    pub quant_misses: u64,
    /// Quantized copies admitted over the PCIe lane (bits/16 of an fp
    /// transfer each).
    pub quant_admits: u64,
    /// Quantized copies promoted to full precision (fp transfer) and fp
    /// evictions re-quantized in place into the low-bit tier.
    pub promotions: u64,
    pub demotions: u64,
    /// Quantized hits the error budget could not absorb: the expert ran
    /// at full precision instead.
    pub quant_corrected: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }

    /// Counters accumulated since `base` was snapshotted (per-request
    /// attribution under continuous batching: snapshot at admission,
    /// delta at completion).  Saturating, so a stale base never underflows.
    pub fn delta_since(&self, base: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(base.hits),
            misses: self.misses.saturating_sub(base.misses),
            evictions: self.evictions.saturating_sub(base.evictions),
            transfers_in: self.transfers_in.saturating_sub(base.transfers_in),
            bytes_in: self.bytes_in.saturating_sub(base.bytes_in),
            prefetches: self.prefetches.saturating_sub(base.prefetches),
            prefetch_hits: self.prefetch_hits.saturating_sub(base.prefetch_hits),
            quant_hits: self.quant_hits.saturating_sub(base.quant_hits),
            quant_misses: self.quant_misses.saturating_sub(base.quant_misses),
            quant_admits: self.quant_admits.saturating_sub(base.quant_admits),
            promotions: self.promotions.saturating_sub(base.promotions),
            demotions: self.demotions.saturating_sub(base.demotions),
            quant_corrected: self.quant_corrected.saturating_sub(base.quant_corrected),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("hits", Json::Num(self.hits as f64));
        o.set("misses", Json::Num(self.misses as f64));
        o.set("hit_rate", Json::Num(self.hit_rate()));
        o.set("evictions", Json::Num(self.evictions as f64));
        o.set("transfers_in", Json::Num(self.transfers_in as f64));
        o.set("bytes_in", Json::Num(self.bytes_in as f64));
        o.set("prefetches", Json::Num(self.prefetches as f64));
        o.set("prefetch_hits", Json::Num(self.prefetch_hits as f64));
        o.set("quant_hits", Json::Num(self.quant_hits as f64));
        o.set("quant_misses", Json::Num(self.quant_misses as f64));
        o.set("quant_admits", Json::Num(self.quant_admits as f64));
        o.set("promotions", Json::Num(self.promotions as f64));
        o.set("demotions", Json::Num(self.demotions as f64));
        o.set("quant_corrected", Json::Num(self.quant_corrected as f64));
        o
    }
}

/// GPU expert-residency cache with pluggable eviction and asynchronous
/// transfer tracking.
pub struct ExpertCache {
    capacity_experts: usize,
    entries: HashMap<ExpertId, Entry>,
    policy: Box<dyn EvictionPolicy>,
    /// Logical clock: bumped on every use/insert (recency ordering).
    tick: u64,
    /// The serialized PCIe lane: time at which the next speculative
    /// transfer can start (generalizes what `prefetch` modeled ad hoc).
    pcie_free_us: f64,
    /// Speculation budget: a prefetch is rejected when the lane is already
    /// backlogged by more than this many transfer times — an entry that
    /// cannot become ready in useful time must not occupy a cache slot.
    pub max_lane_depth: f64,
    /// Bytes charged per expert transfer (paper-scale by default).
    expert_bytes: u64,
    /// Low-bit resident tier (disabled by default — `None` keeps every
    /// path bit-identical to the pre-tier cache).  Enabled, half the fp
    /// slots are converted into `16/bits` quantized copies each at
    /// identical HBM bytes ([`ExpertCache::enable_quant_tier`]).
    quant_bits: Option<u32>,
    quant_capacity: usize,
    quant_entries: HashMap<ExpertId, QuantEntry>,
    /// Per-layer fp slot quota (`--cache-partition layer`): a layer at
    /// its quota evicts within itself even when global capacity is free.
    layer_quota: Option<usize>,
    stats: CacheStats,
    /// Engine-event stream; disabled by default (one branch per event).
    sink: crate::events::EventSink,
    /// Timestamp stamped on events from the *clockless* paths
    /// ([`ExpertCache::fetch`]/[`ExpertCache::admit`] and their
    /// evictions carry no virtual time of their own); callers that know
    /// the current virtual time set it per step
    /// ([`ExpertCache::set_time_hint`]).
    time_hint_us: f64,
    /// Prefetch landing protection (loop 2 of the adaptive control plane,
    /// 0.0 = off): a speculatively inserted entry whose transfer completed
    /// less than this many virtual µs ago — or is still in flight — is
    /// evicted only when no unprotected victim exists, so a just-paid-for
    /// PCIe copy survives until its predicted-use layer arrives.
    landing_protect_us: f64,
}

impl std::fmt::Debug for ExpertCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExpertCache")
            .field("capacity", &self.capacity_experts)
            .field("resident", &self.entries.len())
            .field("policy", &self.policy.name())
            .field("stats", &self.stats)
            .finish()
    }
}

impl ExpertCache {
    pub fn new(hw: &HardwareConfig) -> Self {
        Self::with_capacity(hw.gpu_expert_capacity())
    }

    /// LRU-evicting cache (the default eviction policy).
    pub fn with_capacity(capacity_experts: usize) -> Self {
        Self::with_policy(capacity_experts, Box::new(Lru))
    }

    pub fn with_policy(capacity_experts: usize, policy: Box<dyn EvictionPolicy>) -> Self {
        ExpertCache {
            capacity_experts,
            entries: HashMap::new(),
            policy,
            tick: 0,
            pcie_free_us: 0.0,
            max_lane_depth: 4.0,
            expert_bytes: PAPER_EXPERT_BYTES,
            quant_bits: None,
            quant_capacity: 0,
            quant_entries: HashMap::new(),
            layer_quota: None,
            stats: CacheStats::default(),
            sink: crate::events::EventSink::default(),
            time_hint_us: 0.0,
            landing_protect_us: 0.0,
        }
    }

    /// Attach (or detach, with a disabled sink) the engine-event stream.
    pub fn set_event_sink(&mut self, sink: crate::events::EventSink) {
        self.sink = sink;
    }

    /// Virtual time stamped on events emitted from clockless paths; see
    /// the field docs.
    pub fn set_time_hint(&mut self, now_us: f64) {
        self.time_hint_us = now_us;
    }

    /// Arm prefetch landing protection (see the field docs); 0.0 disables
    /// it, restoring the unprotected victim order bit-for-bit.
    pub fn set_landing_protection(&mut self, window_us: f64) {
        self.landing_protect_us = window_us.max(0.0);
    }

    /// Swap the eviction policy (exec policies install theirs during
    /// `init`, before any dynamic entries exist).
    pub fn set_policy(&mut self, policy: Box<dyn EvictionPolicy>) {
        self.policy = policy;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn capacity(&self) -> usize {
        self.capacity_experts
    }

    pub fn resident_count(&self) -> usize {
        self.entries.len()
    }

    /// Number of pinned (never-evictable) entries — the floor below which
    /// [`ExpertCache::set_capacity`] will not shrink.
    pub fn pinned_count(&self) -> usize {
        self.entries.values().filter(|e| e.pinned).count()
    }

    /// Re-size the cache's expert capacity at runtime (KV-cache/weight
    /// memory arbitration: the serving scheduler converts unpinned expert
    /// slots into KV headroom under memory pressure and returns them when
    /// it subsides).  Shrinking evicts unpinned victims through the
    /// eviction policy; capacity never drops below the pinned count.
    /// Returns the capacity actually in effect.
    pub fn set_capacity(&mut self, capacity_experts: usize) -> usize {
        let n = capacity_experts.max(self.pinned_count());
        while self.entries.len() > n {
            match self.choose_victim_in(None) {
                Some(v) => self.evict_demoting(v),
                None => break, // everything left is pinned
            }
        }
        self.capacity_experts = n;
        n
    }

    /// Convert half the fp expert slots into a low-bit resident tier at
    /// IDENTICAL total HBM bytes: the fp tier keeps `cap/2` slots (at
    /// least one) and the bytes of the converted half hold `16/bits`
    /// quantized copies each (fp weights are 16-bit).  Existing fp
    /// residents beyond the new fp capacity demote rather than evict.
    /// Returns `(fp_capacity, quant_capacity)`.
    pub fn enable_quant_tier(&mut self, bits: u32) -> (usize, usize) {
        let bits = bits.clamp(2, 16);
        let fp = (self.capacity_experts / 2).max(1).min(self.capacity_experts);
        self.quant_capacity = (self.capacity_experts - fp) * 16 / bits as usize;
        self.quant_bits = Some(bits);
        self.set_capacity(fp);
        (self.capacity_experts, self.quant_capacity)
    }

    pub fn quant_tier_enabled(&self) -> bool {
        self.quant_bits.is_some()
    }

    pub fn quant_bits(&self) -> Option<u32> {
        self.quant_bits
    }

    pub fn quant_capacity(&self) -> usize {
        self.quant_capacity
    }

    pub fn quant_resident_count(&self) -> usize {
        self.quant_entries.len()
    }

    /// Partition the fp capacity evenly across `n_layers`
    /// (`--cache-partition layer`): each layer's quota is
    /// `capacity/n_layers` (at least one slot), so one hot layer can no
    /// longer evict every other layer's residents.  Pinned entries count
    /// toward their layer's quota.
    pub fn partition_by_layer(&mut self, n_layers: usize) {
        self.layer_quota = Some((self.capacity_experts / n_layers.max(1)).max(1));
    }

    pub fn layer_quota(&self) -> Option<usize> {
        self.layer_quota
    }

    pub fn is_resident(&self, id: ExpertId) -> bool {
        self.entries.contains_key(&id)
    }

    pub fn is_pinned(&self, id: ExpertId) -> bool {
        self.entries.get(&id).map(|e| e.pinned).unwrap_or(false)
    }

    /// Resident AND its transfer has completed by `now_us`.
    pub fn is_ready(&self, id: ExpertId, now_us: f64) -> bool {
        self.entries.get(&id).map(|e| e.ready_us <= now_us).unwrap_or(false)
    }

    /// Transfer-completion timestamp of a resident entry (0.0 for pinned
    /// entries and synchronous fetches); `None` when the expert occupies
    /// no slot at all.  The pipelined layer executor uses this to price
    /// "wait out the in-flight prefetch" against the demand paths.
    pub fn ready_at(&self, id: ExpertId) -> Option<f64> {
        self.entries.get(&id).map(|e| e.ready_us)
    }

    /// Virtual time at which the serialized PCIe lane can start the next
    /// speculative transfer — the pipeline's issuance gate projects each
    /// candidate prefetch's completion from this.
    pub fn lane_free_at(&self) -> f64 {
        self.pcie_free_us
    }

    /// Reverse the accounting of a demand transfer the pipeline decided
    /// not to perform: a dynamic policy's plan-time `admit` promoted an
    /// in-flight entry (charging a second transfer), but the in-flight
    /// override supersedes it — the expert waits out the original
    /// prefetch instead.  Un-charges one transfer, restores the entry's
    /// transfer-completion time and speculative provenance (so its use
    /// counts as a prefetch hit).  No-op when the expert occupies no slot.
    pub fn cancel_demand_transfer(&mut self, id: ExpertId, ready_us: f64) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.ready_us = ready_us;
            e.prefetched = true;
            self.stats.transfers_in = self.stats.transfers_in.saturating_sub(1);
            self.stats.bytes_in = self.stats.bytes_in.saturating_sub(self.expert_bytes);
        }
    }

    /// Reclassify the plan-time miss of an in-flight entry the pipeline
    /// decided to wait for: the provisional miss becomes a hit (and a
    /// prefetch hit while the entry is still speculative), and the
    /// entry's recency refreshes — the expert IS being served from the
    /// prefetched weights, just a little later.  Keeps `lookups()`
    /// invariant.  No-op when the expert occupies no slot.
    pub fn claim_inflight(&mut self, id: ExpertId) {
        if let Some(e) = self.entries.get_mut(&id) {
            self.tick += 1;
            e.last_use = self.tick;
            if e.prefetched {
                e.prefetched = false;
                self.stats.prefetch_hits += 1;
            }
            self.stats.misses = self.stats.misses.saturating_sub(1);
            self.stats.hits += 1;
        }
    }

    /// Unpin up to `k` pinned entries — most recently pinned first (the
    /// initialization placement pins in descending popularity order, so
    /// these are the least popular) — converting them into ordinary
    /// evictable residents.  This is how the pipelined executor carves a
    /// speculative working set out of a fully pinned cache without
    /// touching its popular core.  Returns how many pins were released.
    pub fn release_pins(&mut self, k: usize) -> usize {
        let mut pinned: Vec<(u64, ExpertId)> = self
            .entries
            .iter()
            .filter(|(_, e)| e.pinned)
            .map(|(&id, e)| (e.pin_tick, id))
            .collect();
        // Newest pin first — by the pin-time tick, which (unlike
        // `last_use`) no amount of traffic refreshes, so the popular core
        // stays protected even on a warm cache.  Ids break (impossible)
        // tick ties for determinism.
        pinned.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut freed = 0;
        for (_, id) in pinned.into_iter().take(k) {
            if let Some(e) = self.entries.get_mut(&id) {
                e.pinned = false;
                freed += 1;
            }
        }
        freed
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Pin `id` at initialization. Panics if capacity would be exceeded —
    /// placement must respect capacity by construction.
    pub fn pin(&mut self, id: ExpertId) {
        assert!(
            self.entries.len() < self.capacity_experts,
            "pin() beyond GPU capacity {}",
            self.capacity_experts
        );
        assert!(!self.is_resident(id), "pin() duplicate {id:?}");
        self.quant_entries.remove(&id); // tiers stay disjoint
        self.tick += 1;
        self.entries.insert(
            id,
            Entry {
                last_use: self.tick,
                ready_us: 0.0,
                pinned: true,
                pin_tick: self.tick,
                prefetched: false,
            },
        );
    }

    /// Mark a use of a resident expert (refreshes its recency stamp).
    pub fn touch(&mut self, id: ExpertId) {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&id) {
            e.last_use = self.tick;
        }
    }

    /// Is `id` usable right now?  Counts a hit (touching the entry) or a
    /// miss; an in-flight prefetch whose transfer has not completed by
    /// `now_us` counts as a miss.
    pub fn lookup(&mut self, id: ExpertId, now_us: f64) -> bool {
        let (hit, prefetch_hit) = match self.entries.get_mut(&id) {
            Some(e) if e.ready_us <= now_us => {
                self.tick += 1;
                e.last_use = self.tick;
                let was_speculative = e.prefetched;
                if was_speculative {
                    e.prefetched = false;
                    self.stats.prefetch_hits += 1;
                }
                self.stats.hits += 1;
                (true, was_speculative)
            }
            _ => {
                self.stats.misses += 1;
                (false, false)
            }
        };
        let t_us = if now_us > 0.0 { now_us } else { self.time_hint_us };
        self.sink.emit_with(|| crate::events::TraceEvent::CacheLookup {
            t_us,
            layer: id.0,
            expert: id.1,
            hit,
            prefetch_hit,
        });
        hit
    }

    /// Insert `id` after a synchronous (demand) weight transfer, evicting
    /// per the policy if full.  An entry whose speculative transfer is
    /// still in flight is *promoted* to ready — the demand transfer just
    /// delivered the weights, so later lookups must not wait for the
    /// original completion time.  Charges the transfer to the stats;
    /// returns false when nothing changed (already ready, or every slot
    /// pinned).
    pub fn admit(&mut self, id: ExpertId) -> bool {
        if let Some(e) = self.entries.get_mut(&id) {
            if e.ready_us == 0.0 {
                return false; // already ready: no transfer needed
            }
            e.ready_us = 0.0;
            e.prefetched = false;
            self.tick += 1;
            e.last_use = self.tick;
            self.stats.transfers_in += 1;
            self.stats.bytes_in += self.expert_bytes;
            self.emit_transfer(id);
            return true;
        }
        self.stats.transfers_in += 1;
        self.stats.bytes_in += self.expert_bytes;
        self.emit_transfer(id);
        self.insert_evicting(id, 0.0, false)
    }

    /// Compatibility demand-fetch (the old `GpuMemory::fetch`, a clockless
    /// synchronous path): ready entry => touch and return false; anything
    /// else — absent OR still in flight — is a miss whose demand transfer
    /// inserts/promotes the entry, returning true.  (Synchronously managed
    /// entries always have `ready_us == 0.0`.)
    pub fn fetch(&mut self, id: ExpertId) -> bool {
        if self.is_ready(id, 0.0) {
            let _ = self.lookup(id, 0.0);
            return false;
        }
        self.stats.misses += 1;
        self.sink.emit_with(|| crate::events::TraceEvent::CacheLookup {
            t_us: self.time_hint_us,
            layer: id.0,
            expert: id.1,
            hit: false,
            prefetch_hit: false,
        });
        self.admit(id);
        true
    }

    /// Issue an asynchronous CPU->GPU transfer for `id` on the serialized
    /// PCIe lane, overlapping ongoing compute.  The entry occupies a slot
    /// immediately but reads as a miss until the returned completion time.
    /// Returns `None` if the expert is already resident or cannot be
    /// cached (all slots pinned).
    pub fn prefetch(&mut self, id: ExpertId, now_us: f64, transfer_us: f64) -> Option<f64> {
        if self.is_resident(id) {
            return None;
        }
        if self.pcie_free_us > now_us + self.max_lane_depth * transfer_us {
            return None; // lane backlogged: speculation would arrive too late
        }
        let start = self.pcie_free_us.max(now_us);
        let ready = start + transfer_us;
        if !self.insert_evicting(id, ready, true) {
            return None;
        }
        self.pcie_free_us = ready;
        self.stats.prefetches += 1;
        self.stats.transfers_in += 1;
        self.stats.bytes_in += self.expert_bytes;
        self.sink.emit_with(|| crate::events::TraceEvent::CachePrefetch {
            t_us: now_us,
            layer: id.0,
            expert: id.1,
            ready_us: ready,
        });
        Some(ready)
    }

    /// Forward one layer's observed routing (token counts per expert) to
    /// the eviction policy so popularity/transition state stays current.
    pub fn observe_layer(&mut self, layer: usize, inp_size: &[usize]) {
        self.policy.observe_layer(layer, inp_size);
    }

    pub fn is_quant_resident(&self, id: ExpertId) -> bool {
        self.quant_entries.contains_key(&id)
    }

    /// Quant-resident AND its (lane) transfer has completed by `now_us`.
    pub fn is_quant_ready(&self, id: ExpertId, now_us: f64) -> bool {
        self.quant_entries.get(&id).map(|e| e.ready_us <= now_us).unwrap_or(false)
    }

    /// Is a quantized copy of `id` usable right now?  Counts a tier hit
    /// (refreshing the copy's recency, emitting a `quant_hit` event
    /// carrying `err` — the expert's precomputed max-abs quantization
    /// error the caller charges against its budget) or a tier miss.
    pub fn lookup_quant(&mut self, id: ExpertId, now_us: f64, err: f64) -> bool {
        let hit = match self.quant_entries.get_mut(&id) {
            Some(e) if e.ready_us <= now_us => {
                self.tick += 1;
                e.last_use = self.tick;
                self.stats.quant_hits += 1;
                true
            }
            _ => {
                self.stats.quant_misses += 1;
                false
            }
        };
        if hit {
            let t_us = if now_us > 0.0 { now_us } else { self.time_hint_us };
            self.sink.emit_with(|| crate::events::TraceEvent::QuantHit {
                t_us,
                layer: id.0,
                expert: id.1,
                err,
            });
        }
        hit
    }

    /// Admit a quantized copy over the serialized PCIe lane — the cheap
    /// speculative admit (`transfer_us` is the *quantized* transfer time,
    /// `bits/16` of an fp transfer; the caller prices it via
    /// [`crate::latency::LatencyModel::quant_transfer_lat`]).  Skipped
    /// when the expert already resides in either tier or the lane is
    /// backlogged past the speculation budget.
    pub fn admit_quant(&mut self, id: ExpertId, now_us: f64, transfer_us: f64) -> Option<f64> {
        let bits = self.quant_bits?;
        if self.quant_capacity == 0 || self.is_resident(id) || self.is_quant_resident(id) {
            return None;
        }
        if self.pcie_free_us > now_us + self.max_lane_depth * transfer_us {
            return None;
        }
        let start = self.pcie_free_us.max(now_us);
        let ready = start + transfer_us;
        self.make_quant_room();
        self.tick += 1;
        self.quant_entries.insert(id, QuantEntry { last_use: self.tick, ready_us: ready });
        self.pcie_free_us = ready;
        self.stats.quant_admits += 1;
        self.stats.transfers_in += 1;
        self.stats.bytes_in += self.expert_bytes * bits as u64 / 16;
        self.sink.emit_with(|| crate::events::TraceEvent::CachePrefetch {
            t_us: now_us,
            layer: id.0,
            expert: id.1,
            ready_us: ready,
        });
        Some(ready)
    }

    /// Promote a quantized copy to full precision via a synchronous
    /// demand transfer (the error-budget correction path): the quant
    /// slot is freed and the expert becomes fp-resident now.  Returns
    /// false when the expert has no quantized copy.
    pub fn promote(&mut self, id: ExpertId) -> bool {
        if self.quant_entries.remove(&id).is_none() {
            return false;
        }
        self.stats.promotions += 1;
        self.sink.emit_with(|| crate::events::TraceEvent::TierPromoted {
            t_us: self.time_hint_us,
            layer: id.0,
            expert: id.1,
            ready_us: 0.0,
        });
        self.admit(id);
        true
    }

    /// Asynchronous promotion over the PCIe lane (prefetch-side): the fp
    /// transfer is issued and the quant slot freed once it lands a slot.
    /// `transfer_us` is the FULL fp transfer time.  Returns the fp
    /// ready time, or `None` when the expert has no quantized copy, the
    /// lane is backlogged, or the fp tier is fully pinned.
    pub fn promote_async(&mut self, id: ExpertId, now_us: f64, transfer_us: f64) -> Option<f64> {
        if !self.is_quant_resident(id) {
            return None;
        }
        let ready = self.prefetch(id, now_us, transfer_us)?;
        // prefetch() -> insert_evicting() already dropped the quant copy
        // to keep the tiers disjoint; count and announce the promotion.
        self.stats.promotions += 1;
        self.sink.emit_with(|| crate::events::TraceEvent::TierPromoted {
            t_us: now_us,
            layer: id.0,
            expert: id.1,
            ready_us: ready,
        });
        Some(ready)
    }

    /// Record a quantized hit the error budget could not absorb (the
    /// caller re-runs the expert at full precision).
    pub fn note_quant_corrected(&mut self, id: ExpertId, now_us: f64) {
        self.stats.quant_corrected += 1;
        let t_us = if now_us > 0.0 { now_us } else { self.time_hint_us };
        self.sink.emit_with(|| crate::events::TraceEvent::QuantCorrected {
            t_us,
            layer: id.0,
            expert: id.1,
        });
    }

    /// All currently resident experts (unordered).
    pub fn resident_experts(&self) -> Vec<ExpertId> {
        self.entries.keys().copied().collect()
    }

    /// Insert with eviction; false when every candidate victim is pinned.
    /// Under `--cache-partition layer` the incoming expert's layer evicts
    /// within its own quota before global capacity is consulted.
    fn insert_evicting(&mut self, id: ExpertId, ready_us: f64, prefetched: bool) -> bool {
        if let Some(q) = self.layer_quota {
            let in_layer = self.entries.keys().filter(|k| k.0 == id.0).count();
            if in_layer >= q {
                match self.choose_victim_in(Some(id.0)) {
                    Some(v) => self.evict_demoting(v),
                    None => return false, // the whole quota is pinned
                }
            }
        }
        if self.entries.len() >= self.capacity_experts {
            match self.choose_victim_in(None) {
                Some(v) => self.evict_demoting(v),
                None => return false,
            }
        }
        // The tiers stay disjoint: an fp insert supersedes any quantized
        // copy (always a no-op while the tier is disabled).
        self.quant_entries.remove(&id);
        self.tick += 1;
        self.entries.insert(
            id,
            Entry { last_use: self.tick, ready_us, pinned: false, pin_tick: 0, prefetched },
        );
        true
    }

    /// Evict `v` from the fp tier; with the quant tier enabled the
    /// victim's weights re-quantize in place (on-GPU, no PCIe traffic)
    /// into a low-bit copy instead of vanishing.
    fn evict_demoting(&mut self, v: ExpertId) {
        self.entries.remove(&v);
        self.stats.evictions += 1;
        self.emit_evict(v);
        if self.quant_bits.is_none() || self.quant_capacity == 0 {
            return;
        }
        if self.quant_entries.contains_key(&v) {
            return; // already has a quantized copy
        }
        self.make_quant_room();
        self.tick += 1;
        self.quant_entries.insert(v, QuantEntry { last_use: self.tick, ready_us: 0.0 });
        self.stats.demotions += 1;
        self.sink.emit_with(|| crate::events::TraceEvent::TierDemoted {
            t_us: self.time_hint_us,
            layer: v.0,
            expert: v.1,
        });
    }

    /// Drop the LRU quantized copy if the tier is at capacity (quant
    /// evictions are silent: the fp master on the host is authoritative,
    /// so nothing is lost and no transfer is charged).
    fn make_quant_room(&mut self) {
        while self.quant_entries.len() >= self.quant_capacity.max(1) {
            let victim = self
                .quant_entries
                .iter()
                .min_by(|(a, ea), (b, eb)| ea.last_use.cmp(&eb.last_use).then(a.cmp(b)))
                .map(|(&id, _)| id);
            match victim {
                Some(v) => {
                    self.quant_entries.remove(&v);
                }
                None => break,
            }
        }
    }

    fn emit_transfer(&self, id: ExpertId) {
        self.sink.emit_with(|| crate::events::TraceEvent::CacheTransfer {
            t_us: self.time_hint_us,
            layer: id.0,
            expert: id.1,
            bytes: self.expert_bytes,
        });
    }

    fn emit_evict(&self, id: ExpertId) {
        self.sink.emit_with(|| crate::events::TraceEvent::CacheEvict {
            t_us: self.time_hint_us,
            layer: id.0,
            expert: id.1,
        });
    }

    /// Unpinned resident expert with the lowest retention score,
    /// optionally restricted to one layer (the `--cache-partition layer`
    /// quota path); ties are broken by id so eviction is deterministic
    /// regardless of hash order.
    fn choose_victim_in(&self, layer: Option<usize>) -> Option<ExpertId> {
        // Landing protection: a prefetched copy still inside its landing
        // window outbids every unprotected entry (finite bonus, so a
        // fully protected cache still yields a deterministic victim).
        let score = |id: ExpertId, e: &Entry| -> f64 {
            let mut s = self.policy.retention_score(id, e.last_use);
            if self.landing_protect_us > 0.0
                && e.prefetched
                && self.time_hint_us < e.ready_us + self.landing_protect_us
            {
                s += 1e15;
            }
            s
        };
        self.entries
            .iter()
            .filter(|(id, e)| !e.pinned && layer.map(|l| id.0 == l).unwrap_or(true))
            .min_by(|(a, ea), (b, eb)| {
                let sa = score(**a, ea);
                let sb = score(**b, eb);
                sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(b))
            })
            .map(|(&id, _)| id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    #[test]
    fn pin_respects_capacity() {
        let mut m = ExpertCache::with_capacity(2);
        m.pin((0, 0));
        m.pin((0, 1));
        assert_eq!(m.resident_count(), 2);
        assert!(m.is_resident((0, 0)));
        assert!(m.is_pinned((0, 1)));
    }

    #[test]
    #[should_panic]
    fn pin_over_capacity_panics() {
        let mut m = ExpertCache::with_capacity(1);
        m.pin((0, 0));
        m.pin((0, 1));
    }

    #[test]
    fn fetch_caches_and_counts() {
        let mut m = ExpertCache::with_capacity(2);
        assert!(m.fetch((0, 0))); // miss
        assert!(!m.fetch((0, 0))); // hit
        assert_eq!(m.stats().transfers_in, 1);
        assert_eq!(m.stats().hits, 1);
        assert_eq!(m.stats().misses, 1);
    }

    #[test]
    fn lru_evicts_oldest_unpinned() {
        let mut m = ExpertCache::with_capacity(2);
        m.fetch((0, 0));
        m.fetch((0, 1));
        m.touch((0, 0)); // 1 is now LRU
        m.fetch((0, 2)); // evicts 1
        assert!(m.is_resident((0, 0)));
        assert!(!m.is_resident((0, 1)));
        assert!(m.is_resident((0, 2)));
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn landing_protection_spares_a_fresh_prefetch() {
        // Unprotected baseline: the speculative copy is the LRU victim.
        let mut u = ExpertCache::with_capacity(2);
        u.prefetch((0, 9), 0.0, 50.0);
        u.fetch((0, 1));
        u.fetch((0, 2));
        assert!(!u.is_resident((0, 9)));

        // Protected: the just-landed copy outbids the older-by-recency
        // demand entry until its landing window expires.
        let mut m = ExpertCache::with_capacity(2);
        m.set_landing_protection(1_000.0);
        m.set_time_hint(0.0);
        m.prefetch((0, 9), 0.0, 50.0); // lands at 50, protected to 1050
        m.fetch((0, 1));
        m.fetch((0, 2)); // victim is (0,1), not the protected prefetch
        assert!(m.is_resident((0, 9)));
        assert!(!m.is_resident((0, 1)));

        // Window elapsed: protection lapses and plain LRU resumes.
        m.set_time_hint(5_000.0);
        m.fetch((0, 3));
        assert!(!m.is_resident((0, 9)));
    }

    #[test]
    fn pinned_never_evicted() {
        let mut m = ExpertCache::with_capacity(2);
        m.pin((9, 9));
        m.fetch((0, 0));
        m.fetch((0, 1)); // evicts (0,0), not the pinned one
        assert!(m.is_resident((9, 9)));
        assert!(!m.is_resident((0, 0)));
    }

    #[test]
    fn all_pinned_full_passthrough() {
        let mut m = ExpertCache::with_capacity(1);
        m.pin((0, 0));
        assert!(m.fetch((1, 1))); // transfer, but no eviction possible
        assert!(!m.is_resident((1, 1)));
        assert_eq!(m.stats().evictions, 0);
        assert_eq!(m.stats().transfers_in, 1);
    }

    #[test]
    fn prefetch_is_miss_until_ready() {
        let mut m = ExpertCache::with_capacity(4);
        let ready = m.prefetch((0, 0), 100.0, 50.0).unwrap();
        assert_eq!(ready, 150.0);
        assert!(m.is_resident((0, 0)));
        assert!(!m.is_ready((0, 0), 120.0));
        assert!(!m.lookup((0, 0), 120.0)); // in flight: miss
        assert!(m.lookup((0, 0), 150.0)); // transfer complete: hit
        assert_eq!(m.stats().prefetch_hits, 1);
        // The second hit on the same entry is no longer a prefetch hit.
        assert!(m.lookup((0, 0), 151.0));
        assert_eq!(m.stats().prefetch_hits, 1);
    }

    #[test]
    fn pcie_lane_serializes_prefetches() {
        let mut m = ExpertCache::with_capacity(4);
        let r0 = m.prefetch((0, 0), 0.0, 100.0).unwrap();
        let r1 = m.prefetch((0, 1), 0.0, 100.0).unwrap();
        assert_eq!(r0, 100.0);
        assert_eq!(r1, 200.0, "second transfer must queue behind the first");
        assert!(m.prefetch((0, 1), 0.0, 100.0).is_none(), "already resident");
    }

    #[test]
    fn demand_admit_promotes_in_flight_prefetch() {
        // A synchronous demand transfer delivers the weights NOW; it must
        // not leave the entry waiting on its older async completion time.
        let mut m = ExpertCache::with_capacity(4);
        m.prefetch((0, 0), 0.0, 1000.0).unwrap(); // ready at 1000
        assert!(!m.lookup((0, 0), 10.0)); // still in flight: miss
        assert!(m.admit((0, 0)), "promotion must count as a transfer");
        assert!(m.lookup((0, 0), 10.0), "promoted entry must be ready");
        assert_eq!(m.stats().transfers_in, 2);
        // Re-admitting a ready entry is a no-op.
        assert!(!m.admit((0, 0)));
        assert_eq!(m.stats().transfers_in, 2);
    }

    #[test]
    fn backlogged_lane_rejects_speculation() {
        let mut m = ExpertCache::with_capacity(64);
        m.max_lane_depth = 2.0;
        assert!(m.prefetch((0, 0), 0.0, 100.0).is_some()); // lane free at 100
        assert!(m.prefetch((0, 1), 0.0, 100.0).is_some()); // 200
        assert!(m.prefetch((0, 2), 0.0, 100.0).is_some()); // 300 > 0 + 2*100 next
        assert!(m.prefetch((0, 3), 0.0, 100.0).is_none(), "backlog must cap");
        // Time advances: the lane drains and speculation resumes.
        assert!(m.prefetch((0, 3), 250.0, 100.0).is_some());
    }

    #[test]
    fn ready_at_reports_transfer_completion() {
        let mut m = ExpertCache::with_capacity(4);
        assert_eq!(m.ready_at((0, 0)), None);
        m.pin((0, 0));
        assert_eq!(m.ready_at((0, 0)), Some(0.0));
        m.prefetch((0, 1), 100.0, 50.0).unwrap();
        assert_eq!(m.ready_at((0, 1)), Some(150.0));
        // Demand promotion zeroes the completion time.
        m.admit((0, 1));
        assert_eq!(m.ready_at((0, 1)), Some(0.0));
    }

    #[test]
    fn cancel_demand_transfer_reverts_admit_over_inflight_prefetch() {
        let mut m = ExpertCache::with_capacity(4);
        m.prefetch((0, 0), 0.0, 100.0).unwrap(); // ready at 100, 1 transfer
        assert!(!m.lookup((0, 0), 10.0)); // plan-time miss
        m.admit((0, 0)); // policy demand-admits: 2nd transfer, promoted
        assert_eq!(m.stats().transfers_in, 2);
        assert!(m.is_ready((0, 0), 10.0));
        // The pipeline overrides to wait out the prefetch instead: the
        // demand transfer is taken back entirely.
        m.cancel_demand_transfer((0, 0), 100.0);
        assert_eq!(m.stats().transfers_in, 1);
        assert!(!m.is_ready((0, 0), 10.0), "completion time restored");
        m.claim_inflight((0, 0));
        assert_eq!(m.stats().prefetch_hits, 1, "speculative provenance restored");
        assert_eq!((m.stats().hits, m.stats().misses), (1, 0));
        // Absent experts are a no-op.
        m.cancel_demand_transfer((9, 9), 0.0);
        assert_eq!(m.stats().transfers_in, 1);
    }

    #[test]
    fn claim_inflight_reclassifies_the_provisional_miss() {
        let mut m = ExpertCache::with_capacity(4);
        m.prefetch((0, 0), 0.0, 100.0).unwrap();
        assert!(!m.lookup((0, 0), 10.0), "in flight: plan-time miss");
        assert_eq!((m.stats().hits, m.stats().misses), (0, 1));
        m.claim_inflight((0, 0));
        assert_eq!((m.stats().hits, m.stats().misses), (1, 0));
        assert_eq!(m.stats().prefetch_hits, 1);
        assert_eq!(m.stats().lookups(), 1, "reclassification, not a new lookup");
        // The speculative flag is consumed: a later ready-time hit is an
        // ordinary hit.
        assert!(m.lookup((0, 0), 200.0));
        assert_eq!(m.stats().prefetch_hits, 1);
        // Absent experts are a no-op.
        m.claim_inflight((9, 9));
        assert_eq!(m.stats().lookups(), 2);
    }

    #[test]
    fn release_pins_frees_newest_pins_first() {
        let mut m = ExpertCache::with_capacity(4);
        m.pin((0, 0)); // oldest pin = most popular under placement order
        m.pin((0, 1));
        m.pin((0, 2));
        // Warm cache: the popular pin gets used constantly.  Recency must
        // NOT make it look like the newest pin — release order follows
        // pin time, not last use.
        m.touch((0, 0));
        m.lookup((0, 0), 0.0);
        assert_eq!(m.release_pins(2), 2);
        assert_eq!(m.pinned_count(), 1);
        assert!(m.is_pinned((0, 0)), "the popular core must stay pinned");
        assert!(m.is_resident((0, 1)) && !m.is_pinned((0, 1)));
        assert!(m.is_resident((0, 2)) && !m.is_pinned((0, 2)));
        // Released entries are now ordinary eviction victims.
        m.fetch((1, 0));
        m.fetch((1, 1)); // cache full: next insert must evict an unpinned one
        m.fetch((1, 2));
        assert!(m.is_pinned((0, 0)));
        assert_eq!(m.resident_count(), 4);
        // Releasing more than exist is clamped.
        assert_eq!(m.release_pins(10), 1);
        assert_eq!(m.pinned_count(), 0);
    }

    #[test]
    fn set_capacity_shrinks_evicting_and_respects_pins() {
        let mut m = ExpertCache::with_capacity(4);
        m.pin((0, 0));
        m.pin((0, 1));
        m.fetch((1, 0));
        m.fetch((1, 1));
        assert_eq!(m.resident_count(), 4);
        // Shrink to 3: one unpinned victim evicted.
        assert_eq!(m.set_capacity(3), 3);
        assert_eq!(m.capacity(), 3);
        assert_eq!(m.resident_count(), 3);
        assert!(m.is_resident((0, 0)) && m.is_resident((0, 1)));
        // Below the pinned floor: clamps to pinned count.
        assert_eq!(m.set_capacity(0), 2);
        assert_eq!(m.resident_count(), 2);
        assert_eq!(m.pinned_count(), 2);
        // Grow back: capacity restored, pins untouched.
        assert_eq!(m.set_capacity(4), 4);
        assert!(m.fetch((2, 2)));
        assert_eq!(m.resident_count(), 3);
    }

    #[test]
    fn stats_delta_since_attributes_per_window() {
        let mut m = ExpertCache::with_capacity(2);
        m.fetch((0, 0)); // miss
        m.fetch((0, 0)); // hit
        let base = m.stats().clone();
        m.fetch((0, 1)); // miss
        m.fetch((0, 1)); // hit
        m.fetch((0, 0)); // hit
        let d = m.stats().delta_since(&base);
        assert_eq!(d.lookups(), 3);
        assert_eq!(d.hits, 2);
        assert_eq!(d.misses, 1);
        assert_eq!(d.transfers_in, 1);
        // A stale (future) base saturates instead of underflowing.
        let z = base.delta_since(m.stats());
        assert_eq!(z.lookups(), 0);
    }

    #[test]
    fn eviction_deterministic_on_ties() {
        // Same-tick scores cannot happen (ticks are unique), but equal
        // policy scores can; id order must break the tie identically on
        // every run.
        struct Constant;
        impl EvictionPolicy for Constant {
            fn name(&self) -> &'static str {
                "const"
            }
            fn retention_score(&self, _id: ExpertId, _last_use: u64) -> f64 {
                1.0
            }
        }
        let mut m = ExpertCache::with_policy(2, Box::new(Constant));
        m.fetch((1, 1));
        m.fetch((0, 3));
        m.fetch((2, 2)); // evicts (0, 3): smallest id among score ties
        assert!(!m.is_resident((0, 3)));
        assert!(m.is_resident((1, 1)));
    }

    #[test]
    fn enable_quant_tier_splits_capacity_at_identical_bytes() {
        // 8 fp slots -> 4 fp + (4 converted * 16/8) = 8 Q8 copies: the
        // converted bytes hold exactly twice as many experts.
        let mut m = ExpertCache::with_capacity(8);
        assert_eq!(m.enable_quant_tier(8), (4, 8));
        assert!(m.quant_tier_enabled());
        // Q4 packs 4x: 12 slots -> 6 fp + 6 * 4 = 24 quant.
        let mut m = ExpertCache::with_capacity(12);
        assert_eq!(m.enable_quant_tier(4), (6, 24));
        // A one-slot cache keeps its fp slot (no bytes left to convert).
        let mut m = ExpertCache::with_capacity(1);
        assert_eq!(m.enable_quant_tier(8), (1, 0));
    }

    #[test]
    fn fp_eviction_demotes_into_quant_tier() {
        let mut m = ExpertCache::with_capacity(4);
        m.enable_quant_tier(8); // 2 fp + 4 quant
        m.fetch((0, 0));
        m.fetch((0, 1));
        m.fetch((0, 2)); // evicts (0,0) -> demoted, not lost
        assert!(!m.is_resident((0, 0)));
        assert!(m.is_quant_resident((0, 0)));
        assert!(m.is_quant_ready((0, 0), 0.0), "requantize-in-place is instant");
        assert_eq!(m.stats().demotions, 1);
        assert_eq!(m.stats().evictions, 1);
        // The demoted copy serves quantized hits.
        assert!(m.lookup_quant((0, 0), 0.0, 0.01));
        assert_eq!(m.stats().quant_hits, 1);
        assert!(!m.lookup_quant((3, 3), 0.0, 0.01));
        assert_eq!(m.stats().quant_misses, 1);
    }

    #[test]
    fn promote_frees_quant_slot_and_charges_fp_transfer() {
        let mut m = ExpertCache::with_capacity(4);
        m.enable_quant_tier(8);
        m.fetch((0, 0));
        m.fetch((0, 1));
        m.fetch((0, 2)); // (0,0) demoted
        let transfers = m.stats().transfers_in;
        assert!(m.promote((0, 0)));
        assert!(m.is_resident((0, 0)), "promotion restores fp residency");
        assert!(!m.is_quant_resident((0, 0)));
        assert_eq!(m.stats().promotions, 1);
        assert_eq!(m.stats().transfers_in, transfers + 1, "fp demand transfer charged");
        // No quant copy -> no promotion.
        assert!(!m.promote((9, 9)));
    }

    #[test]
    fn quant_admit_rides_the_lane_at_reduced_cost() {
        let mut m = ExpertCache::with_capacity(4);
        m.enable_quant_tier(8); // 2 fp + 4 quant
        let ready = m.admit_quant((1, 0), 100.0, 50.0).unwrap();
        assert_eq!(ready, 150.0);
        assert!(!m.is_quant_ready((1, 0), 120.0), "in flight until the lane delivers");
        assert!(m.is_quant_ready((1, 0), 150.0));
        assert_eq!(m.stats().quant_admits, 1);
        // The lane is shared with fp prefetches: the next transfer queues.
        let r2 = m.prefetch((1, 1), 100.0, 100.0).unwrap();
        assert_eq!(r2, 250.0, "quant admit must occupy the serialized lane");
        // Already resident in either tier -> no-op.
        assert!(m.admit_quant((1, 0), 0.0, 50.0).is_none());
        m.fetch((1, 2));
        assert!(m.admit_quant((1, 2), 0.0, 50.0).is_none());
    }

    #[test]
    fn promote_async_is_an_fp_prefetch_plus_tier_move() {
        let mut m = ExpertCache::with_capacity(4);
        m.enable_quant_tier(8);
        m.admit_quant((1, 0), 0.0, 50.0).unwrap();
        let ready = m.promote_async((1, 0), 100.0, 200.0).unwrap();
        assert_eq!(ready, 300.0);
        assert!(m.is_resident((1, 0)), "fp slot occupied while in flight");
        assert!(!m.is_quant_resident((1, 0)), "quant slot freed");
        assert_eq!(m.stats().promotions, 1);
        assert!(m.promote_async((9, 9), 0.0, 100.0).is_none());
    }

    #[test]
    fn disabled_tier_keeps_counters_at_zero() {
        // The bit-identity contract of --quant-tier off: no tier state,
        // no tier counters, demand paths untouched.
        let mut m = ExpertCache::with_capacity(2);
        m.fetch((0, 0));
        m.fetch((0, 1));
        m.fetch((0, 2)); // eviction must NOT demote
        assert!(m.admit_quant((1, 1), 0.0, 50.0).is_none());
        assert!(!m.promote((0, 0)));
        let s = m.stats();
        assert_eq!(
            (s.quant_hits, s.quant_misses, s.quant_admits, s.promotions, s.demotions),
            (0, 0, 0, 0, 0)
        );
        assert_eq!(m.quant_resident_count(), 0);
    }

    #[test]
    fn layer_partition_contains_a_hot_layer() {
        let mut m = ExpertCache::with_capacity(4);
        m.partition_by_layer(2); // quota: 2 slots per layer
        m.fetch((0, 0));
        m.fetch((1, 0));
        m.fetch((1, 1));
        // Layer 1 is at quota: its next insert evicts within layer 1,
        // leaving layer 0's resident alone despite free-looking recency.
        m.fetch((1, 2));
        assert!(m.is_resident((0, 0)), "partition must protect other layers");
        assert_eq!(
            m.resident_experts().iter().filter(|id| id.0 == 1).count(),
            2,
            "layer 1 stays within its quota"
        );
        assert_eq!(m.stats().evictions, 1);
        // Global capacity still binds: layer 0 fills its own quota.
        m.fetch((0, 1));
        assert!(m.resident_count() <= 4);
    }

    #[test]
    fn tier_capacities_never_exceeded_property() {
        // Satellite 4b: across random op mixes with the tier enabled,
        // neither tier overflows its capacity, the tiers stay disjoint,
        // and the layer quota holds when partitioning is on.
        check("quant tier invariants", 96, |g: &mut Gen| {
            let layers = g.usize_in(1..4);
            let experts = g.usize_in(2..8);
            let capacity = g.usize_in(2..10);
            let bits = [4u32, 8][g.usize_in(0..2)];
            let partition = g.usize_in(0..2) == 1;
            let mut cache = ExpertCache::with_capacity(capacity);
            let (fp_cap, quant_cap) = cache.enable_quant_tier(bits);
            assert!(fp_cap >= 1);
            assert_eq!(
                fp_cap + (capacity - fp_cap),
                capacity,
                "conversion accounts for every original slot"
            );
            if partition {
                cache.partition_by_layer(layers);
            }
            let mut now = 0.0;
            for _ in 0..g.usize_in(1..120) {
                let id = (g.usize_in(0..layers), g.usize_in(0..experts));
                match g.usize_in(0..6) {
                    0 => {
                        cache.fetch(id);
                    }
                    1 => {
                        cache.lookup(id, now);
                    }
                    2 => {
                        let _ = cache.prefetch(id, now, g.f64_in(1.0, 200.0));
                    }
                    3 => {
                        let _ = cache.admit_quant(id, now, g.f64_in(1.0, 100.0));
                    }
                    4 => {
                        let _ = cache.promote(id);
                    }
                    _ => {
                        cache.lookup_quant(id, now, 0.01);
                    }
                }
                now += g.f64_in(0.0, 100.0);

                assert!(cache.resident_count() <= fp_cap, "fp tier overflow");
                assert!(cache.quant_resident_count() <= quant_cap, "quant tier overflow");
                for id in cache.resident_experts() {
                    assert!(!cache.is_quant_resident(id), "{id:?} resident in both tiers");
                }
                if partition {
                    let quota = cache.layer_quota().unwrap();
                    for l in 0..layers {
                        let n = cache.resident_experts().iter().filter(|id| id.0 == l).count();
                        assert!(n <= quota, "layer {l} over quota: {n} > {quota}");
                    }
                }
            }
            let s = cache.stats();
            assert!(s.quant_hits + s.quant_misses >= s.quant_hits);
            assert!(s.demotions <= s.evictions, "every demotion rides an fp eviction");
        });
    }

    #[test]
    fn residency_invariants_property() {
        // Pinned experts are never evicted, and the resident count never
        // exceeds capacity, across random op sequences / policies / seeds.
        check("expertcache invariants", 96, |g: &mut Gen| {
            let layers = g.usize_in(1..5);
            let experts = g.usize_in(1..9);
            let capacity = g.usize_in(1..layers * experts + 2);
            let policy: Box<dyn EvictionPolicy> = match g.usize_in(0..3) {
                0 => Box::new(Lru),
                1 => Box::new(ScoredPopularity::new(layers, experts)),
                _ => Box::new(TransitionAware::new(layers, experts, 2)),
            };
            let mut cache = ExpertCache::with_policy(capacity, policy);

            let mut all: Vec<ExpertId> = (0..layers)
                .flat_map(|l| (0..experts).map(move |e| (l, e)))
                .collect();
            g.rng().shuffle(&mut all);
            let n_pin = g.usize_in(0..capacity.min(all.len()) + 1);
            let pinned: Vec<ExpertId> = all[..n_pin].to_vec();
            for &id in &pinned {
                cache.pin(id);
            }

            let mut now = 0.0;
            for _ in 0..g.usize_in(1..150) {
                let id = (g.usize_in(0..layers), g.usize_in(0..experts));
                match g.usize_in(0..5) {
                    0 => {
                        cache.fetch(id);
                    }
                    1 => {
                        cache.lookup(id, now);
                    }
                    2 => {
                        let _ = cache.prefetch(id, now, g.f64_in(1.0, 200.0));
                    }
                    3 => cache.touch(id),
                    _ => {
                        let inp = g.vec_usize(experts..experts + 1, 0..3);
                        cache.observe_layer(g.usize_in(0..layers), &inp);
                    }
                }
                now += g.f64_in(0.0, 100.0);

                assert!(
                    cache.resident_count() <= cache.capacity(),
                    "resident {} > capacity {}",
                    cache.resident_count(),
                    cache.capacity()
                );
                for &id in &pinned {
                    assert!(cache.is_resident(id), "pinned {id:?} evicted");
                    assert!(cache.is_pinned(id));
                }
            }
            // Stats are consistent.
            let s = cache.stats();
            assert_eq!(s.lookups(), s.hits + s.misses);
            assert!(s.prefetch_hits <= s.prefetches);
            assert!((0.0..=1.0).contains(&s.hit_rate()));
        });
    }
}
