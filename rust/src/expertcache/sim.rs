//! Trace-driven cache simulation — compares eviction policies on routing
//! traces without model artifacts or the PJRT runtime.
//!
//! The loop mirrors what [`super::CachedFiddlerPolicy`] does inside the
//! engine: per layer, observe the routing, look each active expert up,
//! apply Algorithm 1 to misses (CPU vs demand transfer by cost), and admit
//! missed experts — synchronously on demand transfers, asynchronously over
//! the serialized PCIe lane on CPU-served decode misses.  Per-layer
//! latency is the max of the two device queues, as in
//! [`crate::scheduler::predict_layer_us`].
//!
//! Used by `examples/ablation_cache.rs` and the cross-policy tests below.

use super::ExpertCache;
use crate::latency::LatencyModel;
use crate::scheduler::{decide_expert, decide_expert_tiered, ExpertPlan};
use crate::util::stats::mean;
use crate::workload::DriftingExpertTrace;

/// Outcome of one simulated serving run.
#[derive(Clone, Debug)]
pub struct CacheSimReport {
    pub policy: &'static str,
    pub hit_rate: f64,
    pub evictions: u64,
    /// Mean simulated latency of one MoE layer (µs).
    pub mean_layer_us: f64,
    /// Mean simulated decode latency of one full step (µs).
    pub mean_step_us: f64,
    pub stats: super::CacheStats,
}

/// Drive `cache` over `steps` decode steps of `trace`.
pub fn run_cache_sim(
    cache: &mut ExpertCache,
    trace: &mut DriftingExpertTrace,
    steps: usize,
    lat: &LatencyModel,
) -> CacheSimReport {
    let mut now = 0.0f64;
    let mut layer_us = Vec::with_capacity(steps * trace.n_layers);
    let mut step_us = Vec::with_capacity(steps);
    for _ in 0..steps {
        let routing = trace.step();
        let t_step = now;
        for (layer, inp) in routing.iter().enumerate() {
            cache.observe_layer(layer, inp);
            let mut gpu = 0.0f64;
            let mut cpu = 0.0f64;
            for (j, &s) in inp.iter().enumerate() {
                if s == 0 {
                    continue;
                }
                let id = (layer, j);
                let resident = cache.lookup(id, now);
                match decide_expert(resident, s, lat) {
                    Some(ExpertPlan::GpuResident) => gpu += lat.gpu_lat(s),
                    Some(ExpertPlan::GpuTransfer) => {
                        cache.admit(id);
                        gpu += lat.transfer_lat().max(lat.gpu_lat(s));
                    }
                    Some(ExpertPlan::Cpu) => {
                        let _ = cache.prefetch(id, now, lat.transfer_lat());
                        cpu += lat.cpu_lat(s);
                    }
                    None => {}
                }
            }
            let t = gpu.max(cpu);
            layer_us.push(t);
            now += t;
        }
        step_us.push(now - t_step);
    }
    CacheSimReport {
        policy: cache.policy_name(),
        hit_rate: cache.stats().hit_rate(),
        evictions: cache.stats().evictions,
        mean_layer_us: mean(&layer_us),
        mean_step_us: mean(&step_us),
        stats: cache.stats().clone(),
    }
}

/// Outcome of one tiered simulated run: the three-way plan mix on top of
/// the base cache report.
#[derive(Clone, Debug)]
pub struct TieredCacheSimReport {
    pub base: CacheSimReport,
    /// Experts served from a ready fp resident.
    pub plan_resident: u64,
    /// Experts served from an accepted quantized resident copy.
    pub plan_quant: u64,
    /// Experts served via an fp demand transfer (including corrected
    /// quantized hits).
    pub plan_transfer: u64,
    /// Experts served on the CPU.
    pub plan_cpu: u64,
    /// Quantized hits the error budget corrected to fp.
    pub corrected: u64,
}

/// Drive a tier-enabled `cache` over `steps` decode steps of `trace`
/// with the three-way Algorithm 1: fp resident -> run now, quantized
/// resident -> argmin(quant-exec, fp transfer, CPU) under `error_budget`
/// (re-armed per step), else the plain two-way decision.  Panics if
/// [`ExpertCache::enable_quant_tier`] has not been called — the caller
/// owns tier sizing so fp-only and tiered runs compare at identical
/// bytes.
pub fn run_cache_sim_tiered(
    cache: &mut ExpertCache,
    trace: &mut DriftingExpertTrace,
    steps: usize,
    lat: &LatencyModel,
    error_budget: f64,
) -> TieredCacheSimReport {
    let bits = cache.quant_bits().expect("run_cache_sim_tiered needs enable_quant_tier");
    let mut now = 0.0f64;
    let mut layer_us = Vec::with_capacity(steps * trace.n_layers);
    let mut step_us = Vec::with_capacity(steps);
    let (mut n_res, mut n_quant, mut n_xfer, mut n_cpu, mut n_corr) = (0u64, 0, 0, 0, 0);
    for _ in 0..steps {
        let routing = trace.step();
        let t_step = now;
        let mut budget = error_budget;
        for (layer, inp) in routing.iter().enumerate() {
            cache.observe_layer(layer, inp);
            let mut gpu = 0.0f64;
            let mut cpu = 0.0f64;
            for (j, &s) in inp.iter().enumerate() {
                if s == 0 {
                    continue;
                }
                let id = (layer, j);
                let fp = cache.lookup(id, now);
                let err = crate::quant::synthetic_expert_error(layer, j, bits);
                let quant = cache.lookup_quant(id, now, err);
                match decide_expert_tiered(fp, quant, s, lat) {
                    Some(ExpertPlan::GpuResident) => {
                        n_res += 1;
                        gpu += lat.gpu_lat(s);
                    }
                    Some(ExpertPlan::GpuQuant) => {
                        if budget >= err {
                            budget -= err;
                            n_quant += 1;
                            gpu += lat.quant_gpu_lat(s);
                        } else {
                            // Correct: promote the fp master and run at
                            // full precision (overlapped like a demand
                            // transfer).
                            cache.note_quant_corrected(id, now);
                            cache.promote(id);
                            n_corr += 1;
                            n_xfer += 1;
                            gpu += lat.transfer_lat().max(lat.gpu_lat(s));
                        }
                    }
                    Some(ExpertPlan::GpuTransfer) => {
                        cache.admit(id);
                        n_xfer += 1;
                        gpu += lat.transfer_lat().max(lat.gpu_lat(s));
                    }
                    Some(ExpertPlan::Cpu) => {
                        let _ = cache.admit_quant(id, now, lat.quant_transfer_lat(bits));
                        n_cpu += 1;
                        cpu += lat.cpu_lat(s);
                    }
                    None => {}
                }
            }
            let t = gpu.max(cpu);
            layer_us.push(t);
            now += t;
        }
        step_us.push(now - t_step);
    }
    TieredCacheSimReport {
        base: CacheSimReport {
            policy: cache.policy_name(),
            hit_rate: cache.stats().hit_rate(),
            evictions: cache.stats().evictions,
            mean_layer_us: mean(&layer_us),
            mean_step_us: mean(&step_us),
            stats: cache.stats().clone(),
        },
        plan_resident: n_res,
        plan_quant: n_quant,
        plan_transfer: n_xfer,
        plan_cpu: n_cpu,
        corrected: n_corr,
    }
}

/// Drive a popularity-pinned cache over a drifting trace — the
/// `cache_pin_fraction` ablation harness.  `pin_fraction` of the
/// capacity is pinned by the popularity observed over a same-parameter
/// warmup trace (at most capacity-1 pins, mirroring
/// [`super::CachedFiddlerPolicy`]); the rest stays dynamic under LRU.
pub fn run_pinned_cache_sim(
    capacity: usize,
    pin_fraction: f64,
    layers: usize,
    experts: usize,
    top_k: usize,
    phase_len: usize,
    seed: u64,
    steps: usize,
    lat: &LatencyModel,
) -> CacheSimReport {
    // Popularity from a warmup pass over the same trace parameters.
    let mut warmup = DriftingExpertTrace::new(layers, experts, top_k, phase_len, seed);
    let mut counts = vec![vec![0u64; experts]; layers];
    for _ in 0..steps.min(100) {
        for (l, inp) in warmup.step().iter().enumerate() {
            for (e, &s) in inp.iter().enumerate() {
                counts[l][e] += s as u64;
            }
        }
    }
    let mut ranked: Vec<(u64, (usize, usize))> = counts
        .iter()
        .enumerate()
        .flat_map(|(l, row)| row.iter().enumerate().map(move |(e, &c)| (c, (l, e))))
        .collect();
    ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let n_pin = ((capacity as f64 * pin_fraction).floor() as usize)
        .min(capacity.saturating_sub(1));
    let mut cache = ExpertCache::with_capacity(capacity);
    for &(_, id) in ranked.iter().take(n_pin) {
        cache.pin(id);
    }
    let mut trace = DriftingExpertTrace::new(layers, experts, top_k, phase_len, seed);
    run_cache_sim(&mut cache, &mut trace, steps, lat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::expertcache::eviction::{Lru, ScoredPopularity, TransitionAware};

    fn report(policy: &str, seed: u64) -> CacheSimReport {
        let (layers, experts, top_k, capacity) = (4usize, 8usize, 2usize, 10usize);
        let mut cache = ExpertCache::with_policy(
            capacity,
            match policy {
                "lru" => Box::new(Lru),
                "scored" => Box::new(ScoredPopularity::new(layers, experts)),
                _ => Box::new(TransitionAware::new(layers, experts, top_k)),
            },
        );
        let mut trace = DriftingExpertTrace::new(layers, experts, top_k, 100, seed);
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        run_cache_sim(&mut cache, &mut trace, 300, &lat)
    }

    #[test]
    fn sim_reports_sane_metrics() {
        let r = report("lru", 1);
        assert!((0.0..=1.0).contains(&r.hit_rate));
        assert!(r.mean_layer_us > 0.0);
        assert!(r.mean_step_us >= r.mean_layer_us);
        assert!(r.stats.lookups() > 0);
    }

    #[test]
    fn transition_aware_beats_lru_on_drifting_trace() {
        // Decode-layer access is cyclic, LRU's pathological case: the
        // least-recent resident expert is exactly one the next layers will
        // ask for.  Protecting predicted successors must not lose (the
        // ablation-example acceptance bar), averaged over seeds.
        let seeds = [1u64, 7, 42, 1234];
        let mean_of = |p: &str| {
            seeds.iter().map(|&s| report(p, s).hit_rate).sum::<f64>() / seeds.len() as f64
        };
        let lru = mean_of("lru");
        let transition = mean_of("transition");
        assert!(
            transition >= lru,
            "transition {transition:.4} < lru {lru:.4} on the drifting trace"
        );
    }

    #[test]
    fn sim_is_deterministic_per_seed() {
        let a = report("scored", 3);
        let b = report("scored", 3);
        assert_eq!(a.stats.hits, b.stats.hits);
        assert_eq!(a.stats.evictions, b.stats.evictions);
    }

    #[test]
    fn tiered_sim_serves_quantized_hits_and_counts_the_mix() {
        let (layers, experts, top_k) = (4usize, 8usize, 2usize);
        let mut cache = ExpertCache::with_capacity(8);
        cache.enable_quant_tier(8);
        let mut trace = DriftingExpertTrace::new(layers, experts, top_k, 100, 7);
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        let r = run_cache_sim_tiered(&mut cache, &mut trace, 300, &lat, 0.05);
        assert!(r.plan_quant > 0, "no quantized hits accepted: {r:?}");
        assert!(r.base.mean_step_us > 0.0);
        let planned = r.plan_resident + r.plan_quant + r.plan_transfer + r.plan_cpu;
        // Every active expert gets exactly one plan.
        assert_eq!(planned, 300 * layers as u64 * top_k as u64);
        assert_eq!(r.base.stats.quant_hits, r.plan_quant + r.corrected);
        assert_eq!(r.base.stats.quant_corrected, r.corrected);
    }

    #[test]
    fn tiered_sim_beats_fp_only_at_identical_hbm_bytes() {
        // The acceptance-criteria shape: at a cache size where fp-only
        // thrashes, splitting the same bytes into fp + Q4 copies buys
        // more coverage and a cheaper step.
        let (layers, experts, top_k, capacity) = (4usize, 8usize, 2usize, 8usize);
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        let mut fp = ExpertCache::with_capacity(capacity);
        let mut t1 = DriftingExpertTrace::new(layers, experts, top_k, 100, 11);
        let base = run_cache_sim(&mut fp, &mut t1, 300, &lat);
        let mut tiered = ExpertCache::with_capacity(capacity);
        tiered.enable_quant_tier(4);
        let mut t2 = DriftingExpertTrace::new(layers, experts, top_k, 100, 11);
        let tier = run_cache_sim_tiered(&mut tiered, &mut t2, 300, &lat, 10.0);
        assert!(
            tier.base.mean_step_us < base.mean_step_us,
            "tiered {:.0}us !< fp-only {:.0}us",
            tier.base.mean_step_us,
            base.mean_step_us
        );
    }

    #[test]
    fn pinned_sim_is_deterministic_and_sane_across_fractions() {
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        for &f in &[0.0, 0.5, 1.0] {
            let a = run_pinned_cache_sim(10, f, 4, 8, 2, 100, 5, 200, &lat);
            let b = run_pinned_cache_sim(10, f, 4, 8, 2, 100, 5, 200, &lat);
            assert!((0.0..=1.0).contains(&a.hit_rate), "fraction {f}");
            assert_eq!(a.stats.hits, b.stats.hits, "fraction {f} not deterministic");
        }
    }
}
