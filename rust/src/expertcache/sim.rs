//! Trace-driven cache simulation — compares eviction policies on routing
//! traces without model artifacts or the PJRT runtime.
//!
//! The loop mirrors what [`super::CachedFiddlerPolicy`] does inside the
//! engine: per layer, observe the routing, look each active expert up,
//! apply Algorithm 1 to misses (CPU vs demand transfer by cost), and admit
//! missed experts — synchronously on demand transfers, asynchronously over
//! the serialized PCIe lane on CPU-served decode misses.  Per-layer
//! latency is the max of the two device queues, as in
//! [`crate::scheduler::predict_layer_us`].
//!
//! Used by `examples/ablation_cache.rs` and the cross-policy tests below.

use super::ExpertCache;
use crate::latency::LatencyModel;
use crate::scheduler::{decide_expert, ExpertPlan};
use crate::util::stats::mean;
use crate::workload::DriftingExpertTrace;

/// Outcome of one simulated serving run.
#[derive(Clone, Debug)]
pub struct CacheSimReport {
    pub policy: &'static str,
    pub hit_rate: f64,
    pub evictions: u64,
    /// Mean simulated latency of one MoE layer (µs).
    pub mean_layer_us: f64,
    /// Mean simulated decode latency of one full step (µs).
    pub mean_step_us: f64,
    pub stats: super::CacheStats,
}

/// Drive `cache` over `steps` decode steps of `trace`.
pub fn run_cache_sim(
    cache: &mut ExpertCache,
    trace: &mut DriftingExpertTrace,
    steps: usize,
    lat: &LatencyModel,
) -> CacheSimReport {
    let mut now = 0.0f64;
    let mut layer_us = Vec::with_capacity(steps * trace.n_layers);
    let mut step_us = Vec::with_capacity(steps);
    for _ in 0..steps {
        let routing = trace.step();
        let t_step = now;
        for (layer, inp) in routing.iter().enumerate() {
            cache.observe_layer(layer, inp);
            let mut gpu = 0.0f64;
            let mut cpu = 0.0f64;
            for (j, &s) in inp.iter().enumerate() {
                if s == 0 {
                    continue;
                }
                let id = (layer, j);
                let resident = cache.lookup(id, now);
                match decide_expert(resident, s, lat) {
                    Some(ExpertPlan::GpuResident) => gpu += lat.gpu_lat(s),
                    Some(ExpertPlan::GpuTransfer) => {
                        cache.admit(id);
                        gpu += lat.transfer_lat().max(lat.gpu_lat(s));
                    }
                    Some(ExpertPlan::Cpu) => {
                        let _ = cache.prefetch(id, now, lat.transfer_lat());
                        cpu += lat.cpu_lat(s);
                    }
                    None => {}
                }
            }
            let t = gpu.max(cpu);
            layer_us.push(t);
            now += t;
        }
        step_us.push(now - t_step);
    }
    CacheSimReport {
        policy: cache.policy_name(),
        hit_rate: cache.stats().hit_rate(),
        evictions: cache.stats().evictions,
        mean_layer_us: mean(&layer_us),
        mean_step_us: mean(&step_us),
        stats: cache.stats().clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::expertcache::eviction::{Lru, ScoredPopularity, TransitionAware};

    fn report(policy: &str, seed: u64) -> CacheSimReport {
        let (layers, experts, top_k, capacity) = (4usize, 8usize, 2usize, 10usize);
        let mut cache = ExpertCache::with_policy(
            capacity,
            match policy {
                "lru" => Box::new(Lru),
                "scored" => Box::new(ScoredPopularity::new(layers, experts)),
                _ => Box::new(TransitionAware::new(layers, experts, top_k)),
            },
        );
        let mut trace = DriftingExpertTrace::new(layers, experts, top_k, 100, seed);
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        run_cache_sim(&mut cache, &mut trace, 300, &lat)
    }

    #[test]
    fn sim_reports_sane_metrics() {
        let r = report("lru", 1);
        assert!((0.0..=1.0).contains(&r.hit_rate));
        assert!(r.mean_layer_us > 0.0);
        assert!(r.mean_step_us >= r.mean_layer_us);
        assert!(r.stats.lookups() > 0);
    }

    #[test]
    fn transition_aware_beats_lru_on_drifting_trace() {
        // Decode-layer access is cyclic, LRU's pathological case: the
        // least-recent resident expert is exactly one the next layers will
        // ask for.  Protecting predicted successors must not lose (the
        // ablation-example acceptance bar), averaged over seeds.
        let seeds = [1u64, 7, 42, 1234];
        let mean_of = |p: &str| {
            seeds.iter().map(|&s| report(p, s).hit_rate).sum::<f64>() / seeds.len() as f64
        };
        let lru = mean_of("lru");
        let transition = mean_of("transition");
        assert!(
            transition >= lru,
            "transition {transition:.4} < lru {lru:.4} on the drifting trace"
        );
    }

    #[test]
    fn sim_is_deterministic_per_seed() {
        let a = report("scored", 3);
        let b = report("scored", 3);
        assert_eq!(a.stats.hits, b.stats.hits);
        assert_eq!(a.stats.evictions, b.stats.evictions);
    }
}
