//! Model configuration, loaded from the artifact `weights_manifest.json`
//! written by `python/compile/export_weights.py` (single source of truth:
//! the Python side owns the dims, the Rust side reads them).

use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    /// Root of this model's artifact directory (hlo/, weights/, ...).
    pub artifact_dir: PathBuf,
}

/// Shape buckets — must match python/compile/configs.py.
pub const PREFILL_BUCKETS: &[usize] = &[32, 64, 128, 256, 512, 1024, 2048, 4096];
pub const DECODE_BATCH_BUCKETS: &[usize] = &[1, 2, 4, 8, 16];
pub const CACHE_BUCKETS: &[usize] = &[128, 512, 1024, 2048, 4096];
pub const TOKEN_BUCKETS: &[usize] =
    &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];
pub const LMHEAD_BUCKETS: &[usize] = &[1, 2, 4, 8, 16];

impl ModelConfig {
    /// Load from `<artifacts>/<model>/weights_manifest.json`.
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<ModelConfig> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = json::load(dir.join("weights_manifest.json"))
            .with_context(|| format!("loading model manifest in {}", dir.display()))?;
        Self::from_manifest(&manifest, dir)
    }

    pub fn from_manifest(manifest: &Json, artifact_dir: PathBuf) -> Result<ModelConfig> {
        let c = manifest.get("config")?;
        Ok(ModelConfig {
            name: manifest.get("model")?.as_str()?.to_string(),
            vocab: c.get("vocab")?.as_usize()?,
            hidden: c.get("hidden")?.as_usize()?,
            ffn: c.get("ffn")?.as_usize()?,
            n_layers: c.get("n_layers")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            n_kv_heads: c.get("n_kv_heads")?.as_usize()?,
            head_dim: c.get("head_dim")?.as_usize()?,
            n_experts: c.get("n_experts")?.as_usize()?,
            top_k: c.get("top_k")?.as_usize()?,
            max_seq: c.get("max_seq")?.as_usize()?,
            rope_theta: c.get("rope_theta")?.as_f64()?,
            rms_eps: c.get("rms_eps")?.as_f64()?,
            artifact_dir,
        })
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total experts across all layers (the paper's "256" for Mixtral-8x7B).
    pub fn total_experts(&self) -> usize {
        self.n_layers * self.n_experts
    }

    /// Parameters of one expert (w1 + w3 + w2) of THIS model.
    pub fn expert_params(&self) -> usize {
        3 * self.hidden * self.ffn
    }

    /// A hard-coded copy of the `mixtral-tiny` dims for tests/benches that
    /// must not depend on artifacts being built.
    pub fn test_tiny() -> ModelConfig {
        ModelConfig {
            name: "test-tiny".into(),
            vocab: 512,
            hidden: 128,
            ffn: 256,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            n_experts: 8,
            top_k: 2,
            max_seq: 4096,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
            artifact_dir: PathBuf::from("artifacts/mixtral-tiny"),
        }
    }
}

/// Locate the artifacts root: $FIDDLER_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("FIDDLER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims() {
        let c = ModelConfig::test_tiny();
        assert_eq!(c.q_dim(), 128);
        assert_eq!(c.kv_dim(), 64);
        assert_eq!(c.total_experts(), 32);
        assert_eq!(c.expert_params(), 3 * 128 * 256);
    }

    #[test]
    fn from_manifest_parses() {
        let text = r#"{
            "model": "m", "config": {
              "vocab": 512, "hidden": 128, "ffn": 256, "n_layers": 4,
              "n_heads": 4, "n_kv_heads": 2, "head_dim": 32, "n_experts": 8,
              "top_k": 2, "max_seq": 4096, "rope_theta": 10000.0,
              "rms_eps": 1e-5 },
            "tensors": {}
        }"#;
        let m = Json::parse(text).unwrap();
        let c = ModelConfig::from_manifest(&m, PathBuf::from("/x")).unwrap();
        assert_eq!(c.name, "m");
        assert_eq!(c.n_experts, 8);
    }

    #[test]
    fn buckets_ascend() {
        for b in [PREFILL_BUCKETS, DECODE_BATCH_BUCKETS, CACHE_BUCKETS, TOKEN_BUCKETS] {
            assert!(b.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
