//! Configuration: model, hardware environment (paper Table 1), serving.

pub mod hardware;
pub mod model;
pub mod serving;

pub use hardware::{DeviceKind, HardwareConfig};
pub use model::ModelConfig;
pub use serving::ServingConfig;
