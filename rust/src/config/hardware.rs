//! Hardware environment configuration — the paper's Table 1, plus the
//! latency-model constants derived from Appendix A (Figure 7).
//!
//! The two named environments:
//!
//! | | Environment 1 | Environment 2 |
//! |---|---|---|
//! | GPU | Quadro RTX 6000 (24 GiB) | RTX 6000 Ada (48 GiB) |
//! | PCIe | Gen3 x16 (32 GB/s) | Gen4 x16 (64 GB/s) |
//! | CPU | Xeon Gold 6126 (48c) | Xeon Platinum 8480+ (112c) |
//! | Experts on GPU | 56 / 256 | 125 / 256 |
//!
//! All timing constants refer to ONE paper-scale expert (Mixtral-8x7B:
//! 3 matrices of 4096x14336 bf16 = 352 MB) so that decisions and reported
//! latencies reproduce the paper's regime, regardless of the tiny model
//! actually executing the numerics (DESIGN.md §2).

use crate::util::json::Json;
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Gpu,
    Cpu,
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceKind::Gpu => write!(f, "gpu"),
            DeviceKind::Cpu => write!(f, "cpu"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct HardwareConfig {
    pub name: String,
    pub gpu_name: String,
    pub cpu_name: String,
    /// GPU memory capacity in bytes.
    pub gpu_mem_bytes: u64,
    /// Achievable PCIe bandwidth in bytes/s (nominal x ~0.7 efficiency).
    pub pcie_bw_bytes_per_s: f64,
    /// Fixed per-transfer PCIe latency in microseconds.
    pub pcie_base_us: f64,
    /// Bytes of one paper-scale expert's weights (16-bit).
    pub expert_weight_bytes: u64,
    /// Bytes reserved on the GPU for non-expert layers + KV cache.
    pub non_expert_reserved_bytes: u64,
    /// GPU latency to execute one expert, weights resident (constant in s).
    pub gpu_expert_compute_us: f64,
    /// Extra GPU overhead for batch size 1 (PyTorch single-batch kernel
    /// dispatch difference observed in the paper's Appendix A, ~10%).
    pub gpu_single_batch_extra_us: f64,
    /// CPU expert latency model: `c0 + c1 * tokens` (affine; c0 = one pass
    /// over the expert's weights from DRAM, c1 = per-token compute).
    pub cpu_expert_base_us: f64,
    pub cpu_expert_per_token_us: f64,
    /// Physical CPU cores (Table 1) — caps the parallel expert executor's
    /// modeled multi-core speedup.
    pub cpu_cores: usize,
    /// GPU->CPU or CPU->GPU activation copy: base + per-byte.
    pub act_copy_base_us: f64,
    pub act_copy_per_byte_us: f64,
    /// Per-layer non-expert (attention + norms + router) GPU latency for a
    /// decode step, and per-token for prefill (amortized, batched).
    pub attn_decode_us: f64,
    pub attn_prefill_per_token_us: f64,
    /// Slowdown of the non-expert (attention) part when executed on the CPU
    /// (llama.cpp-style static split places whole layers there).
    pub attn_cpu_factor: f64,
    /// LM head latency (once per generated token).
    pub lm_head_us: f64,
}

pub const MIB: u64 = 1024 * 1024;
/// One Mixtral-8x7B expert: 3 x 4096 x 14336 params x 2 bytes.
pub const PAPER_EXPERT_BYTES: u64 = 3 * 4096 * 14336 * 2;
/// KV-cache bytes of ONE token at paper scale (Mixtral-8x7B: 32 layers x
/// kv_dim 1024 x 2 (K and V) x 2 bytes bf16 = 128 KiB/token).  The serving
/// scheduler budgets KV memory in these units so it arbitrates coherently
/// against [`PAPER_EXPERT_BYTES`]-sized expert slots (~2.7k tokens of KV
/// per expert slot).
pub const PAPER_KV_BYTES_PER_TOKEN: u64 = 32 * 1024 * 2 * 2;

impl HardwareConfig {
    /// Environment 1: Quadro RTX 6000 24 GiB + Xeon Gold 6126, PCIe Gen3.
    pub fn env1() -> HardwareConfig {
        HardwareConfig {
            name: "env1".into(),
            gpu_name: "Quadro RTX 6000 (24GiB, sim)".into(),
            cpu_name: "Xeon Gold 6126 48c (sim)".into(),
            gpu_mem_bytes: 24_576 * MIB,
            pcie_bw_bytes_per_s: 32.0e9 * 0.70,
            pcie_base_us: 20.0,
            expert_weight_bytes: PAPER_EXPERT_BYTES,
            // Non-expert weights (~1.8 GiB for Mixtral) + KV cache +
            // activations/workspace; sized so exactly 56 experts fit
            // (paper Table 1).
            non_expert_reserved_bytes: 5_500 * MIB,
            gpu_expert_compute_us: 4_000.0,
            gpu_single_batch_extra_us: 400.0,
            cpu_expert_base_us: 5_000.0,
            cpu_expert_per_token_us: 450.0,
            cpu_cores: 48,
            act_copy_base_us: 15.0,
            act_copy_per_byte_us: 0.45e-3 / 8.0, // ~8 GB/s effective D2H small copies
            attn_decode_us: 220.0,
            attn_prefill_per_token_us: 30.0,
            attn_cpu_factor: 3.0,
            lm_head_us: 900.0,
        }
    }

    /// Environment 2: RTX 6000 Ada 48 GiB + Xeon Platinum 8480+, PCIe Gen4.
    pub fn env2() -> HardwareConfig {
        HardwareConfig {
            name: "env2".into(),
            gpu_name: "RTX 6000 Ada (48GiB, sim)".into(),
            cpu_name: "Xeon Platinum 8480+ 112c (sim)".into(),
            gpu_mem_bytes: 49_140 * MIB,
            pcie_bw_bytes_per_s: 64.0e9 * 0.70,
            pcie_base_us: 15.0,
            expert_weight_bytes: PAPER_EXPERT_BYTES,
            // Larger KV/workspace reservation (longer contexts fit this
            // GPU); sized so exactly 125 experts fit (paper Table 1).
            non_expert_reserved_bytes: 7_000 * MIB,
            gpu_expert_compute_us: 2_200.0,
            gpu_single_batch_extra_us: 220.0,
            cpu_expert_base_us: 2_400.0,
            cpu_expert_per_token_us: 180.0,
            cpu_cores: 112,
            act_copy_base_us: 12.0,
            act_copy_per_byte_us: 0.45e-3 / 12.0,
            attn_decode_us: 130.0,
            attn_prefill_per_token_us: 16.0,
            attn_cpu_factor: 3.0,
            lm_head_us: 500.0,
        }
    }

    pub fn by_name(name: &str) -> Result<HardwareConfig> {
        match name {
            "env1" => Ok(Self::env1()),
            "env2" => Ok(Self::env2()),
            other => anyhow::bail!("unknown hardware env {other:?} (have env1, env2)"),
        }
    }

    /// Number of paper-scale experts that fit in GPU memory after the
    /// non-expert reservation — Table 1's "Number of Experts on GPU".
    pub fn gpu_expert_capacity(&self) -> usize {
        let free = self.gpu_mem_bytes.saturating_sub(self.non_expert_reserved_bytes);
        (free / self.expert_weight_bytes) as usize
    }

    /// Latency (µs) to move one expert's weights CPU -> GPU.
    pub fn weight_transfer_us(&self) -> f64 {
        self.pcie_base_us
            + self.expert_weight_bytes as f64 / self.pcie_bw_bytes_per_s * 1e6
    }

    /// Latency (µs) to move `bytes` of activations between devices.
    pub fn act_copy_us(&self, bytes: usize) -> f64 {
        self.act_copy_base_us + self.act_copy_per_byte_us * bytes as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from(self.name.clone()));
        o.set("gpu", Json::from(self.gpu_name.clone()));
        o.set("cpu", Json::from(self.cpu_name.clone()));
        o.set("gpu_mem_bytes", Json::Num(self.gpu_mem_bytes as f64));
        o.set("gpu_expert_capacity", Json::from(self.gpu_expert_capacity()));
        o.set("weight_transfer_us", Json::Num(self.weight_transfer_us()));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_expert_capacity_matches_paper() {
        // Paper Table 1: 56/256 for Env1, 125/256 for Env2.
        assert_eq!(HardwareConfig::env1().gpu_expert_capacity(), 56);
        assert_eq!(HardwareConfig::env2().gpu_expert_capacity(), 125);
    }

    #[test]
    fn transfer_is_2_to_5x_gpu_compute() {
        // Appendix A: "latency for transferring weights ... is about 2-5
        // times longer than the actual computation time".
        for env in [HardwareConfig::env1(), HardwareConfig::env2()] {
            let ratio = env.weight_transfer_us() / env.gpu_expert_compute_us;
            assert!((2.0..=5.0).contains(&ratio), "{}: ratio={ratio}", env.name);
        }
    }

    #[test]
    fn env2_is_uniformly_faster() {
        let e1 = HardwareConfig::env1();
        let e2 = HardwareConfig::env2();
        assert!(e2.weight_transfer_us() < e1.weight_transfer_us());
        assert!(e2.gpu_expert_compute_us < e1.gpu_expert_compute_us);
        assert!(e2.cpu_expert_per_token_us < e1.cpu_expert_per_token_us);
    }

    #[test]
    fn activation_copy_negligible_vs_expert() {
        // Appendix A: activation copy < 1% of single-input CPU latency.
        let env = HardwareConfig::env1();
        let act = env.act_copy_us(4096 * 2); // one token's activation, bf16
        let cpu1 = env.cpu_expert_base_us + env.cpu_expert_per_token_us;
        assert!(act < 0.01 * cpu1, "act={act} cpu1={cpu1}");
    }

    #[test]
    fn kv_and_expert_scales_are_coherent() {
        // One expert slot is worth thousands of KV tokens — the
        // arbitration only makes sense when the units share a scale.
        let tokens_per_slot = PAPER_EXPERT_BYTES / PAPER_KV_BYTES_PER_TOKEN;
        assert!((1_000..10_000).contains(&tokens_per_slot), "{tokens_per_slot}");
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(HardwareConfig::by_name("env1").is_ok());
        assert!(HardwareConfig::by_name("env3").is_err());
    }
}
