//! Serving-engine configuration: policy selection, batching limits,
//! generation parameters.

use crate::util::cli::Args;

/// Which execution policy drives expert placement/execution decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's system: popularity placement + Algorithm 1 dynamic
    /// CPU/GPU decisions + cross-token expert batching.
    Fiddler,
    /// DeepSpeed-MII with ZeRO-Infinity: weights live in CPU memory and are
    /// streamed to the GPU for every use (no expert cache, no CPU compute).
    MiiOffload,
    /// Mixtral-Offloading: LRU expert cache in GPU memory; misses transfer
    /// weights CPU->GPU (never computes on the CPU).
    LruOffload,
    /// llama.cpp: static layer split (`ngl` layers on GPU); computation runs
    /// where the weights live; no cross-beam batching on either device.
    StaticSplit,
    /// Extension: Fiddler + speculative next-layer expert prefetching over
    /// the transition profile (beyond the paper; cf. MoE-Infinity).
    FiddlerPrefetch,
    /// Extension: Algorithm 1 over a dynamically managed expert cache —
    /// a fraction of capacity pinned by popularity, the rest governed by a
    /// pluggable eviction policy (see [`crate::expertcache`]).
    FiddlerCached,
}

impl Policy {
    pub fn by_name(name: &str) -> anyhow::Result<Policy> {
        Ok(match name {
            "fiddler" => Policy::Fiddler,
            "mii" | "deepspeed-mii" => Policy::MiiOffload,
            "lru" | "mixtral-offloading" => Policy::LruOffload,
            "static" | "llama-cpp" | "llamacpp" => Policy::StaticSplit,
            "fiddler-prefetch" | "prefetch" => Policy::FiddlerPrefetch,
            "fiddler-cached" | "cached" => Policy::FiddlerCached,
            other => anyhow::bail!(
                "unknown policy {other:?} (have fiddler, mii, lru, static, \
                 fiddler-prefetch, fiddler-cached)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Fiddler => "Fiddler",
            Policy::MiiOffload => "DeepSpeed-MII*",
            Policy::LruOffload => "Mixtral-Offloading*",
            Policy::StaticSplit => "llama.cpp*",
            Policy::FiddlerPrefetch => "Fiddler+prefetch",
            Policy::FiddlerCached => "Fiddler+cache",
        }
    }
}

/// Which eviction policy the dynamic expert cache runs (used by
/// [`Policy::FiddlerCached`]; see [`crate::expertcache::eviction`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionKind {
    /// Pure recency (LRU).
    Lru,
    /// Popularity x recency (HybriMoE-style scoring).
    ScoredPopularity,
    /// Protect experts predicted for the next layer from cross-layer
    /// routing transitions.
    TransitionAware,
}

impl EvictionKind {
    pub fn by_name(name: &str) -> anyhow::Result<EvictionKind> {
        Ok(match name {
            "lru" => EvictionKind::Lru,
            "scored" | "scored-popularity" => EvictionKind::ScoredPopularity,
            "transition" | "transition-aware" => EvictionKind::TransitionAware,
            other => anyhow::bail!(
                "unknown eviction policy {other:?} (have lru, scored, transition)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictionKind::Lru => "lru",
            EvictionKind::ScoredPopularity => "scored",
            EvictionKind::TransitionAware => "transition",
        }
    }
}

/// Admission/priority policy of the request-lifecycle scheduler
/// ([`crate::server::lifecycle`]): which queued request the serve loop
/// admits next when a batch slot frees up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionKind {
    /// First come, first served (the original demo loop's behavior).
    Fcfs,
    /// Shortest prompt first (MoE-Lens-style: short prefills out of the
    /// way keeps the decode batch full).
    ShortestFirst,
    /// Earliest TTFT deadline first, driven by the virtual clock; the
    /// per-request deadline defaults to `slo_ttft_ms` past enqueue.
    Deadline,
}

impl AdmissionKind {
    pub fn by_name(name: &str) -> anyhow::Result<AdmissionKind> {
        Ok(match name {
            "fcfs" => AdmissionKind::Fcfs,
            "sjf" | "shortest" | "shortest-first" => AdmissionKind::ShortestFirst,
            "slo" | "edf" | "deadline" => AdmissionKind::Deadline,
            other => anyhow::bail!(
                "unknown admission policy {other:?} (have fcfs, sjf, slo)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionKind::Fcfs => "fcfs",
            AdmissionKind::ShortestFirst => "sjf",
            AdmissionKind::Deadline => "slo",
        }
    }
}

/// How the fleet partitions the expert set across engine shards
/// (`--shard-plan`; see [`crate::server::fleet`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPlan {
    /// Contiguous layer ranges per shard: each engine owns every expert
    /// of its layers, so a request's layer walk stays on one engine.
    Layer,
    /// Hash partition of (layer, expert) ids: spreads hot experts across
    /// engines at the cost of cross-shard activation traffic.
    Hash,
    /// Price both candidates against the MoE-Lens bottleneck model and
    /// pick the layout with the lower max-shard step time.
    Auto,
}

impl ShardPlan {
    pub fn by_name(name: &str) -> anyhow::Result<ShardPlan> {
        Ok(match name {
            "layer" => ShardPlan::Layer,
            "hash" => ShardPlan::Hash,
            "auto" => ShardPlan::Auto,
            other => anyhow::bail!("unknown shard plan {other:?} (have layer, hash, auto)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShardPlan::Layer => "layer",
            ShardPlan::Hash => "hash",
            ShardPlan::Auto => "auto",
        }
    }
}

/// How the expert cache's fp capacity is partitioned (`--cache-partition`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePartition {
    /// One global pool: any layer's expert can evict any other layer's
    /// (the seed behavior).
    None,
    /// Slots split evenly across layers: a hot layer evicts within its
    /// own quota instead of flushing every other layer's residents.
    Layer,
}

impl CachePartition {
    pub fn by_name(name: &str) -> anyhow::Result<CachePartition> {
        Ok(match name {
            "none" | "" => CachePartition::None,
            "layer" => CachePartition::Layer,
            other => anyhow::bail!("unknown cache partition {other:?} (have none, layer)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            CachePartition::None => "none",
            CachePartition::Layer => "layer",
        }
    }
}

/// Expert placement strategy at initialization (paper §3.4 + Appendix C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Most popular experts first (the paper's choice).
    Popularity,
    /// Uniform random placement (Appendix C baseline).
    Random,
    /// Least popular first (Appendix C "worst" bound).
    Worst,
}

impl PlacementStrategy {
    pub fn by_name(name: &str) -> anyhow::Result<PlacementStrategy> {
        Ok(match name {
            "popularity" => PlacementStrategy::Popularity,
            "random" => PlacementStrategy::Random,
            "worst" => PlacementStrategy::Worst,
            other => anyhow::bail!("unknown placement {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub policy: Policy,
    pub placement: PlacementStrategy,
    /// llama.cpp-style: number of leading layers fully resident on the GPU
    /// (used by Policy::StaticSplit). Paper: 8 for Env1, 16 for Env2.
    pub ngl: usize,
    /// Max sequences co-scheduled in one decode step.
    pub max_batch: usize,
    /// Max queued requests before admission control rejects.
    pub queue_capacity: usize,
    /// Random seed for sampling.
    pub seed: u64,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f64,
    /// Eviction policy of the dynamic expert cache (FiddlerCached).
    pub cache_eviction: EvictionKind,
    /// Fraction of GPU expert capacity pinned by popularity at init under
    /// FiddlerCached; the rest is the dynamic working set.
    pub cache_pin_fraction: f64,
    /// Worker threads of the parallel CPU expert executor ([`crate::exec`]).
    /// 1 = serial (the pre-parallel engine, bit-for-bit); `--threads 0` on
    /// the CLI resolves to the host's available parallelism.
    pub threads: usize,
    /// Prefill chunk size (tokens) of the request-lifecycle scheduler:
    /// each serve-loop iteration advances an admitted prompt by at most
    /// this many tokens, interleaved with decode steps of the running
    /// sequences so their inter-token latency stays bounded.  0 (default)
    /// = monolithic prefill (the whole prompt in one iteration).
    pub prefill_chunk: usize,
    /// Admission/priority policy of the serve loop.
    pub admission: AdmissionKind,
    /// KV-cache memory budget in MiB at paper scale
    /// ([`crate::config::hardware::PAPER_KV_BYTES_PER_TOKEN`]); admission
    /// reserves each request's worst-case footprint against it and queues
    /// (or rejects outright-infeasible requests) instead of OOMing.  When
    /// the pool runs dry the scheduler borrows headroom by shrinking the
    /// [`crate::expertcache::ExpertCache`]'s unpinned capacity — the
    /// MoE-Lightning-style KV/weight arbitration.  0 = unlimited.
    pub kv_budget_mb: usize,
    /// Default TTFT service-level objective (virtual ms) used to derive a
    /// deadline for requests that carry none (admission `slo` mode).
    pub slo_ttft_ms: f64,
    /// Lookahead window (in layers) of the pipelined layer executor
    /// ([`crate::pipeline`]): while layer `L` runs, asynchronous PCIe
    /// prefetches are issued for the experts predicted at layers
    /// `L+1..L+W`, and still-in-flight transfers may win Algorithm 1 over
    /// the demand paths.  0 (default) = the serial legacy layer loop,
    /// bit-for-bit.
    pub pipeline_lookahead: usize,
    /// Per-iteration prefill token budget (`--prefill-tokens`).  0
    /// (default) = legacy: one chunked prefill in flight at a time, with
    /// admission held until it completes.  `N > 0` is the Sarathi-style
    /// budget: admission stays open while prompts prefill and each serve
    /// iteration advances *several* concurrent prefills, spending at most
    /// `N` prompt tokens across them (the first in-flight prefill always
    /// advances so progress never stalls on a small budget).
    pub prefill_tokens: usize,
    /// Per-request preemption bound (`--max-preemptions`).  0 (default) =
    /// preemption off.  `N > 0` lets admission preempt the decoding
    /// width-1 sequence with the *latest* deadline when a tighter-deadline
    /// arrival would otherwise be rejected by the KV budget; the victim
    /// requeues and recomputes its KV on readmission (Sarathi-style
    /// drop-and-recompute), at most `N` times so no request starves.
    pub max_preemptions: usize,
    /// Deterministic fault-injection spec for the sim backend
    /// (`--faults "stall=P:US,spike=P:US,err=P"`); see
    /// [`crate::server::sim::FailPoints`].  `None` (default) = no faults.
    pub faults: Option<String>,
    /// Seed of the fault-injection RNG stream (`--fault-seed`); kept
    /// separate from `seed` so the same workload can be replayed under
    /// different fault schedules.
    pub fault_seed: u64,
    /// Per-connection read timeout of the TCP front end in wall-clock ms
    /// (`--conn-timeout-ms`); an idle connection gets a typed `error`
    /// line and is closed.  0 (default) = no timeout.
    pub conn_timeout_ms: u64,
    /// Path of the JSONL engine-event log (`--events-out trace.jsonl`):
    /// the serve loop attaches a [`crate::events::EventSink`] writing
    /// every [`crate::events::TraceEvent`] here.  The log is a replayable
    /// trace (`fiddler trace-replay`) and folds into per-request flame
    /// summaries (`fiddler trace-summary`).  `None` (default) = sink
    /// disabled, costing one branch per would-be event.
    pub events_out: Option<String>,
    /// Engine shards of the serving fleet (`--shards N`).  1 (default) =
    /// the single-engine scheduler, token-bit-identical to the
    /// pre-fleet serving stack; `N > 1` fronts N per-shard schedulers
    /// with the [`crate::server::fleet`] router.
    pub shards: usize,
    /// Expert partition layout across shards (`--shard-plan`).
    pub shard_plan: ShardPlan,
    /// Hot-expert replication threshold (`--replicate-hot F`): an expert
    /// whose measured popularity share exceeds `F` gets
    /// `ceil(share / F)` replicas across the fleet (capped at the shard
    /// count).  0 (default) = replication off.
    pub replicate_hot: f64,
    /// Quantized expert tier (`--quant-tier on|off`).  Off (default) =
    /// the two-way Algorithm 1, bit-identical to the pre-tier engine.
    /// On: half the fp expert capacity is converted into a low-bit
    /// resident tier holding `16/quant_bits` copies per converted slot
    /// (identical HBM bytes), and the scheduler prices a third option —
    /// run the quantized resident copy now — against transfer-fp and
    /// run-on-CPU per expert per layer.
    pub quant_tier: bool,
    /// Bit width of quantized resident copies (`--quant-bits`, 2..=16).
    pub quant_bits: u32,
    /// Per-request quantization error budget (`--error-budget`): each
    /// accepted quantized hit spends its expert's max-abs error against
    /// this budget; once exhausted, further quantized hits are
    /// *corrected* — the expert runs at full precision via an fp
    /// promotion instead.  0 forces correction on every quantized hit
    /// (token streams match the fp-only run).
    pub error_budget: f64,
    /// Expert-cache capacity partitioning (`--cache-partition`).
    pub cache_partition: CachePartition,
    /// Adaptive control plane (`--adaptive on|off`; see
    /// [`crate::control`]).  Off (default) = every knob static,
    /// bit-identical to the pre-control-plane engine.  On: the per-kind
    /// lookahead controller, prefetch-aware eviction, skew-aware override
    /// pricing, and measured SLO admission feedback all close their loops
    /// online — from virtual-time counters only, so record→replay stays
    /// bit-identical.
    pub adaptive: bool,
    /// Best-effort core affinity for the executor-pool workers
    /// (`--pin-workers on|off`).  Worker `i` pins to core `i % cores` on
    /// Linux/x86-64; a no-op hint elsewhere.  Off by default.
    pub pin_workers: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            policy: Policy::Fiddler,
            placement: PlacementStrategy::Popularity,
            ngl: 8,
            max_batch: 16,
            queue_capacity: 256,
            seed: 0,
            temperature: 0.0,
            cache_eviction: EvictionKind::Lru,
            cache_pin_fraction: 0.5,
            threads: 1,
            prefill_chunk: 0,
            admission: AdmissionKind::Fcfs,
            kv_budget_mb: 0,
            slo_ttft_ms: 5_000.0,
            prefill_tokens: 0,
            max_preemptions: 0,
            faults: None,
            fault_seed: 0,
            conn_timeout_ms: 0,
            pipeline_lookahead: 0,
            events_out: None,
            shards: 1,
            shard_plan: ShardPlan::Auto,
            replicate_hot: 0.0,
            quant_tier: false,
            quant_bits: 8,
            error_budget: 0.05,
            cache_partition: CachePartition::None,
            adaptive: false,
            pin_workers: false,
        }
    }
}

impl ServingConfig {
    pub fn from_args(args: &Args) -> anyhow::Result<ServingConfig> {
        let mut c = ServingConfig::default();
        if let Some(p) = args.get("policy") {
            c.policy = Policy::by_name(p)?;
        }
        if let Some(p) = args.get("placement") {
            c.placement = PlacementStrategy::by_name(p)?;
        }
        c.ngl = args.usize_or("ngl", c.ngl);
        c.max_batch = args.usize_or("max-batch", c.max_batch);
        c.seed = args.u64_or("seed", c.seed);
        c.temperature = args.f64_or("temperature", c.temperature);
        if let Some(e) = args.get("cache-eviction") {
            c.cache_eviction = EvictionKind::by_name(e)?;
        }
        c.cache_pin_fraction = args.f64_or("cache-pin-fraction", c.cache_pin_fraction);
        anyhow::ensure!(
            (0.0..=1.0).contains(&c.cache_pin_fraction),
            "--cache-pin-fraction must be in [0, 1]"
        );
        c.threads = match args.usize_or("threads", c.threads) {
            // 0 = auto: one executor worker per available core.
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        c.prefill_chunk = args.usize_or("prefill-chunk", c.prefill_chunk);
        if let Some(a) = args.get("admission") {
            c.admission = AdmissionKind::by_name(a)?;
        }
        c.kv_budget_mb = args.usize_or("kv-budget-mb", c.kv_budget_mb);
        c.slo_ttft_ms = args.f64_or("slo-ttft-ms", c.slo_ttft_ms);
        anyhow::ensure!(c.slo_ttft_ms > 0.0, "--slo-ttft-ms must be positive");
        c.prefill_tokens = args.usize_or("prefill-tokens", c.prefill_tokens);
        c.max_preemptions = args.usize_or("max-preemptions", c.max_preemptions);
        c.faults = args.get("faults").map(String::from).filter(|s| !s.is_empty());
        c.fault_seed = args.u64_or("fault-seed", c.fault_seed);
        c.conn_timeout_ms = args.u64_or("conn-timeout-ms", c.conn_timeout_ms);
        c.pipeline_lookahead = args.usize_or("pipeline-lookahead", c.pipeline_lookahead);
        c.events_out = args.get("events-out").map(String::from);
        c.shards = args.usize_or("shards", c.shards);
        anyhow::ensure!(c.shards >= 1, "--shards must be at least 1");
        if let Some(p) = args.get("shard-plan") {
            c.shard_plan = ShardPlan::by_name(p)?;
        }
        c.replicate_hot = args.f64_or("replicate-hot", c.replicate_hot);
        anyhow::ensure!(
            (0.0..=1.0).contains(&c.replicate_hot),
            "--replicate-hot must be in [0, 1]"
        );
        if let Some(q) = args.get("quant-tier") {
            c.quant_tier = match q {
                "on" => true,
                "off" => false,
                other => anyhow::bail!("--quant-tier must be on or off, got {other:?}"),
            };
        }
        c.quant_bits = args.usize_or("quant-bits", c.quant_bits as usize) as u32;
        anyhow::ensure!(
            (2..=16).contains(&c.quant_bits),
            "--quant-bits must be in [2, 16]"
        );
        c.error_budget = args.f64_or("error-budget", c.error_budget);
        anyhow::ensure!(c.error_budget >= 0.0, "--error-budget must be non-negative");
        if let Some(p) = args.get("cache-partition") {
            c.cache_partition = CachePartition::by_name(p)?;
        }
        if let Some(a) = args.get("adaptive") {
            c.adaptive = match a {
                "on" => true,
                "off" => false,
                other => anyhow::bail!("--adaptive must be on or off, got {other:?}"),
            };
        }
        if let Some(p) = args.get("pin-workers") {
            c.pin_workers = match p {
                "on" => true,
                "off" => false,
                other => anyhow::bail!("--pin-workers must be on or off, got {other:?}"),
            };
        }
        Ok(c)
    }

    /// The paper's per-environment `ngl` for the llama.cpp baseline.
    pub fn paper_ngl_for(env_name: &str) -> usize {
        match env_name {
            "env2" => 16,
            _ => 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names() {
        assert_eq!(Policy::by_name("fiddler").unwrap(), Policy::Fiddler);
        assert_eq!(Policy::by_name("llama-cpp").unwrap(), Policy::StaticSplit);
        assert_eq!(Policy::by_name("fiddler-cached").unwrap(), Policy::FiddlerCached);
        assert!(Policy::by_name("vllm").is_err());
    }

    #[test]
    fn eviction_names() {
        assert_eq!(EvictionKind::by_name("lru").unwrap(), EvictionKind::Lru);
        assert_eq!(EvictionKind::by_name("scored").unwrap(), EvictionKind::ScoredPopularity);
        assert_eq!(
            EvictionKind::by_name("transition-aware").unwrap(),
            EvictionKind::TransitionAware
        );
        assert!(EvictionKind::by_name("fifo").is_err());
    }

    #[test]
    fn cache_args_parse_and_validate() {
        let args = Args::parse(
            "--policy cached --cache-eviction transition --cache-pin-fraction 0.25"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.policy, Policy::FiddlerCached);
        assert_eq!(c.cache_eviction, EvictionKind::TransitionAware);
        assert!((c.cache_pin_fraction - 0.25).abs() < 1e-12);

        let bad = Args::parse(
            "--cache-pin-fraction 1.5".split_whitespace().map(String::from),
        );
        assert!(ServingConfig::from_args(&bad).is_err());
    }

    #[test]
    fn threads_flag_parses_and_auto_resolves() {
        assert_eq!(ServingConfig::default().threads, 1);

        let a = Args::parse("--threads 4".split_whitespace().map(String::from));
        assert_eq!(ServingConfig::from_args(&a).unwrap().threads, 4);

        // 0 = auto: resolves to this host's parallelism, never 0.
        let auto = Args::parse("--threads 0".split_whitespace().map(String::from));
        assert!(ServingConfig::from_args(&auto).unwrap().threads >= 1);
    }

    #[test]
    fn admission_names() {
        assert_eq!(AdmissionKind::by_name("fcfs").unwrap(), AdmissionKind::Fcfs);
        assert_eq!(AdmissionKind::by_name("sjf").unwrap(), AdmissionKind::ShortestFirst);
        assert_eq!(AdmissionKind::by_name("slo").unwrap(), AdmissionKind::Deadline);
        assert_eq!(AdmissionKind::by_name("deadline").unwrap(), AdmissionKind::Deadline);
        assert!(AdmissionKind::by_name("lifo").is_err());
    }

    #[test]
    fn lifecycle_args_parse_and_default() {
        let d = ServingConfig::default();
        assert_eq!(d.prefill_chunk, 0, "monolithic prefill by default");
        assert_eq!(d.admission, AdmissionKind::Fcfs);
        assert_eq!(d.kv_budget_mb, 0, "unlimited KV by default");

        let a = Args::parse(
            "--prefill-chunk 64 --admission slo --kv-budget-mb 2048 --slo-ttft-ms 800"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&a).unwrap();
        assert_eq!(c.prefill_chunk, 64);
        assert_eq!(c.admission, AdmissionKind::Deadline);
        assert_eq!(c.kv_budget_mb, 2048);
        assert!((c.slo_ttft_ms - 800.0).abs() < 1e-12);

        let bad =
            Args::parse("--slo-ttft-ms 0".split_whitespace().map(String::from));
        assert!(ServingConfig::from_args(&bad).is_err());
    }

    #[test]
    fn robustness_args_parse_and_default_off() {
        let d = ServingConfig::default();
        assert_eq!(d.prefill_tokens, 0, "legacy one-prefill-at-a-time by default");
        assert_eq!(d.max_preemptions, 0, "preemption off by default");
        assert_eq!(d.faults, None);
        assert_eq!(d.fault_seed, 0);
        assert_eq!(d.conn_timeout_ms, 0, "no read timeout by default");

        let a = Args::parse(
            "--prefill-tokens 128 --max-preemptions 2 \
             --faults stall=0.05:30000,err=0.01 --fault-seed 7 --conn-timeout-ms 250"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&a).unwrap();
        assert_eq!(c.prefill_tokens, 128);
        assert_eq!(c.max_preemptions, 2);
        assert_eq!(c.faults.as_deref(), Some("stall=0.05:30000,err=0.01"));
        assert_eq!(c.fault_seed, 7);
        assert_eq!(c.conn_timeout_ms, 250);
    }

    #[test]
    fn pipeline_lookahead_parses_and_defaults_to_serial() {
        assert_eq!(
            ServingConfig::default().pipeline_lookahead,
            0,
            "lookahead must default to the serial legacy loop"
        );
        let a = Args::parse("--pipeline-lookahead 2".split_whitespace().map(String::from));
        assert_eq!(ServingConfig::from_args(&a).unwrap().pipeline_lookahead, 2);
    }

    #[test]
    fn events_out_parses_and_defaults_off() {
        assert_eq!(ServingConfig::default().events_out, None);
        let a = Args::parse("--events-out trace.jsonl".split_whitespace().map(String::from));
        assert_eq!(
            ServingConfig::from_args(&a).unwrap().events_out.as_deref(),
            Some("trace.jsonl")
        );
    }

    #[test]
    fn shard_plan_names() {
        assert_eq!(ShardPlan::by_name("layer").unwrap(), ShardPlan::Layer);
        assert_eq!(ShardPlan::by_name("hash").unwrap(), ShardPlan::Hash);
        assert_eq!(ShardPlan::by_name("auto").unwrap(), ShardPlan::Auto);
        assert!(ShardPlan::by_name("ring").is_err());
        assert_eq!(ShardPlan::Layer.label(), "layer");
    }

    #[test]
    fn fleet_args_parse_and_default_to_single_engine() {
        let d = ServingConfig::default();
        assert_eq!(d.shards, 1, "single engine by default");
        assert_eq!(d.shard_plan, ShardPlan::Auto);
        assert_eq!(d.replicate_hot, 0.0, "replication off by default");

        let a = Args::parse(
            "--shards 3 --shard-plan hash --replicate-hot 0.25"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&a).unwrap();
        assert_eq!(c.shards, 3);
        assert_eq!(c.shard_plan, ShardPlan::Hash);
        assert!((c.replicate_hot - 0.25).abs() < 1e-12);

        let bad = Args::parse("--shards 0".split_whitespace().map(String::from));
        assert!(ServingConfig::from_args(&bad).is_err());
        let bad = Args::parse("--replicate-hot 1.5".split_whitespace().map(String::from));
        assert!(ServingConfig::from_args(&bad).is_err());
        let bad = Args::parse("--shard-plan ring".split_whitespace().map(String::from));
        assert!(ServingConfig::from_args(&bad).is_err());
    }

    #[test]
    fn quant_tier_args_parse_and_default_off() {
        let d = ServingConfig::default();
        assert!(!d.quant_tier, "quant tier must default off (seed behavior)");
        assert_eq!(d.quant_bits, 8);
        assert!((d.error_budget - 0.05).abs() < 1e-12);
        assert_eq!(d.cache_partition, CachePartition::None);

        let a = Args::parse(
            "--quant-tier on --quant-bits 4 --error-budget 0.02 --cache-partition layer"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&a).unwrap();
        assert!(c.quant_tier);
        assert_eq!(c.quant_bits, 4);
        assert!((c.error_budget - 0.02).abs() < 1e-12);
        assert_eq!(c.cache_partition, CachePartition::Layer);

        let off = Args::parse("--quant-tier off".split_whitespace().map(String::from));
        assert!(!ServingConfig::from_args(&off).unwrap().quant_tier);

        for bad in [
            "--quant-tier maybe",
            "--quant-bits 1",
            "--quant-bits 32",
            "--error-budget -0.5",
            "--cache-partition expert",
        ] {
            let a = Args::parse(bad.split_whitespace().map(String::from));
            assert!(ServingConfig::from_args(&a).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn adaptive_args_parse_and_default_off() {
        let d = ServingConfig::default();
        assert!(!d.adaptive, "adaptive must default off (static pipeline)");
        assert!(!d.pin_workers, "pinning must default off");

        let a = Args::parse(
            "--adaptive on --pin-workers on".split_whitespace().map(String::from),
        );
        let c = ServingConfig::from_args(&a).unwrap();
        assert!(c.adaptive);
        assert!(c.pin_workers);

        let off = Args::parse("--adaptive off".split_whitespace().map(String::from));
        assert!(!ServingConfig::from_args(&off).unwrap().adaptive);

        for bad in ["--adaptive maybe", "--pin-workers yes"] {
            let a = Args::parse(bad.split_whitespace().map(String::from));
            assert!(ServingConfig::from_args(&a).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            "--policy mii --ngl 16 --max-batch 4 --temperature 0.7"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.policy, Policy::MiiOffload);
        assert_eq!(c.ngl, 16);
        assert_eq!(c.max_batch, 4);
        assert!((c.temperature - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_ngl() {
        assert_eq!(ServingConfig::paper_ngl_for("env1"), 8);
        assert_eq!(ServingConfig::paper_ngl_for("env2"), 16);
    }
}
