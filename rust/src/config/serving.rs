//! Serving-engine configuration: policy selection, batching limits,
//! generation parameters.

use crate::util::cli::Args;

/// Which execution policy drives expert placement/execution decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's system: popularity placement + Algorithm 1 dynamic
    /// CPU/GPU decisions + cross-token expert batching.
    Fiddler,
    /// DeepSpeed-MII with ZeRO-Infinity: weights live in CPU memory and are
    /// streamed to the GPU for every use (no expert cache, no CPU compute).
    MiiOffload,
    /// Mixtral-Offloading: LRU expert cache in GPU memory; misses transfer
    /// weights CPU->GPU (never computes on the CPU).
    LruOffload,
    /// llama.cpp: static layer split (`ngl` layers on GPU); computation runs
    /// where the weights live; no cross-beam batching on either device.
    StaticSplit,
    /// Extension: Fiddler + speculative next-layer expert prefetching over
    /// the transition profile (beyond the paper; cf. MoE-Infinity).
    FiddlerPrefetch,
}

impl Policy {
    pub fn by_name(name: &str) -> anyhow::Result<Policy> {
        Ok(match name {
            "fiddler" => Policy::Fiddler,
            "mii" | "deepspeed-mii" => Policy::MiiOffload,
            "lru" | "mixtral-offloading" => Policy::LruOffload,
            "static" | "llama-cpp" | "llamacpp" => Policy::StaticSplit,
            "fiddler-prefetch" | "prefetch" => Policy::FiddlerPrefetch,
            other => anyhow::bail!(
                "unknown policy {other:?} (have fiddler, mii, lru, static, fiddler-prefetch)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Fiddler => "Fiddler",
            Policy::MiiOffload => "DeepSpeed-MII*",
            Policy::LruOffload => "Mixtral-Offloading*",
            Policy::StaticSplit => "llama.cpp*",
            Policy::FiddlerPrefetch => "Fiddler+prefetch",
        }
    }
}

/// Expert placement strategy at initialization (paper §3.4 + Appendix C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Most popular experts first (the paper's choice).
    Popularity,
    /// Uniform random placement (Appendix C baseline).
    Random,
    /// Least popular first (Appendix C "worst" bound).
    Worst,
}

impl PlacementStrategy {
    pub fn by_name(name: &str) -> anyhow::Result<PlacementStrategy> {
        Ok(match name {
            "popularity" => PlacementStrategy::Popularity,
            "random" => PlacementStrategy::Random,
            "worst" => PlacementStrategy::Worst,
            other => anyhow::bail!("unknown placement {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub policy: Policy,
    pub placement: PlacementStrategy,
    /// llama.cpp-style: number of leading layers fully resident on the GPU
    /// (used by Policy::StaticSplit). Paper: 8 for Env1, 16 for Env2.
    pub ngl: usize,
    /// Max sequences co-scheduled in one decode step.
    pub max_batch: usize,
    /// Max queued requests before admission control rejects.
    pub queue_capacity: usize,
    /// Random seed for sampling.
    pub seed: u64,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            policy: Policy::Fiddler,
            placement: PlacementStrategy::Popularity,
            ngl: 8,
            max_batch: 16,
            queue_capacity: 256,
            seed: 0,
            temperature: 0.0,
        }
    }
}

impl ServingConfig {
    pub fn from_args(args: &Args) -> anyhow::Result<ServingConfig> {
        let mut c = ServingConfig::default();
        if let Some(p) = args.get("policy") {
            c.policy = Policy::by_name(p)?;
        }
        if let Some(p) = args.get("placement") {
            c.placement = PlacementStrategy::by_name(p)?;
        }
        c.ngl = args.usize_or("ngl", c.ngl);
        c.max_batch = args.usize_or("max-batch", c.max_batch);
        c.seed = args.u64_or("seed", c.seed);
        c.temperature = args.f64_or("temperature", c.temperature);
        Ok(c)
    }

    /// The paper's per-environment `ngl` for the llama.cpp baseline.
    pub fn paper_ngl_for(env_name: &str) -> usize {
        match env_name {
            "env2" => 16,
            _ => 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names() {
        assert_eq!(Policy::by_name("fiddler").unwrap(), Policy::Fiddler);
        assert_eq!(Policy::by_name("llama-cpp").unwrap(), Policy::StaticSplit);
        assert!(Policy::by_name("vllm").is_err());
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            "--policy mii --ngl 16 --max-batch 4 --temperature 0.7"
                .split_whitespace()
                .map(String::from),
        );
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.policy, Policy::MiiOffload);
        assert_eq!(c.ngl, 16);
        assert_eq!(c.max_batch, 4);
        assert!((c.temperature - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_ngl() {
        assert_eq!(ServingConfig::paper_ngl_for("env1"), 8);
        assert_eq!(ServingConfig::paper_ngl_for("env2"), 16);
    }
}
