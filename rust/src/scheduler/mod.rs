//! Algorithm 1 — the paper's expert execution strategy (§3.3).
//!
//! For each expert `j` of layer `i` with `s = inp_size[j]` input tokens:
//!
//! ```text
//! if s == 0                                 -> skip
//! if is_at_gpu(i, j)                        -> run at GPU (resident)
//! else if cpu_lat(s) > gpu_lat(s) + transfer_lat() -> transfer + run at GPU
//! else                                      -> run at CPU
//! ```
//!
//! The decision consumes only the latency model and the residency set, so
//! it is a pure function — trivially property-testable, and exactly the
//! quantity the paper's contribution lives in.

pub mod policy;

use crate::config::DeviceKind;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;

/// Where and how one expert invocation executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertPlan {
    /// Weights resident on the GPU: execute there, no transfer (Fig. 3a).
    GpuResident,
    /// Copy weights CPU->GPU, then execute on the GPU (Fig. 3b).
    GpuTransfer,
    /// Copy activations GPU->CPU, execute on the CPU, copy back (Fig. 3c).
    Cpu,
}

impl ExpertPlan {
    pub fn device(&self) -> DeviceKind {
        match self {
            ExpertPlan::GpuResident | ExpertPlan::GpuTransfer => DeviceKind::Gpu,
            ExpertPlan::Cpu => DeviceKind::Cpu,
        }
    }

    /// Latency charged to the plan by the model (µs).
    pub fn cost_us(&self, lat: &LatencyModel, s: usize) -> f64 {
        match self {
            ExpertPlan::GpuResident => lat.gpu_lat(s),
            ExpertPlan::GpuTransfer => lat.gpu_lat(s) + lat.transfer_lat(),
            ExpertPlan::Cpu => lat.cpu_lat(s),
        }
    }
}

/// Decide the plan for one expert (the body of Algorithm 1's loop).
pub fn decide_expert(
    resident: bool,
    s: usize,
    lat: &LatencyModel,
) -> Option<ExpertPlan> {
    if s == 0 {
        return None; // line 7-9: skip experts with no input
    }
    if resident {
        return Some(ExpertPlan::GpuResident); // line 10-11
    }
    if lat.cpu_lat(s) > lat.gpu_lat(s) + lat.transfer_lat() {
        Some(ExpertPlan::GpuTransfer) // line 12-13
    } else {
        Some(ExpertPlan::Cpu) // line 14-15
    }
}

/// Plan a whole MoE layer: `inp_size[j]` tokens per expert.
/// Returns `plans[j] = None` for idle experts.
pub fn plan_layer(
    layer: usize,
    inp_size: &[usize],
    memory: &ExpertCache,
    lat: &LatencyModel,
) -> Vec<Option<ExpertPlan>> {
    inp_size
        .iter()
        .enumerate()
        .map(|(j, &s)| decide_expert(memory.is_resident((layer, j)), s, lat))
        .collect()
}

/// Predicted layer latency under a set of plans, with the GPU and CPU
/// queues overlapping (the engine executes both concurrently and joins at
/// the layer boundary, where expert outputs are combined).
pub fn predict_layer_us(
    plans: &[Option<ExpertPlan>],
    inp_size: &[usize],
    lat: &LatencyModel,
) -> f64 {
    let mut gpu = 0.0;
    let mut cpu = 0.0;
    for (plan, &s) in plans.iter().zip(inp_size) {
        match plan {
            Some(p @ (ExpertPlan::GpuResident | ExpertPlan::GpuTransfer)) => {
                gpu += p.cost_us(lat, s)
            }
            Some(p @ ExpertPlan::Cpu) => cpu += p.cost_us(lat, s),
            None => {}
        }
    }
    gpu.max(cpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::testkit::{check, Gen};

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    #[test]
    fn idle_expert_skipped() {
        assert_eq!(decide_expert(false, 0, &lat()), None);
        assert_eq!(decide_expert(true, 0, &lat()), None);
    }

    #[test]
    fn resident_always_gpu() {
        let lat = lat();
        for s in [1, 2, 100, 4096] {
            assert_eq!(decide_expert(true, s, &lat), Some(ExpertPlan::GpuResident));
        }
    }

    #[test]
    fn decode_prefers_cpu_prefill_prefers_gpu() {
        // The paper's headline behaviour: small s -> CPU (avoid the weight
        // transfer), large s -> transfer + GPU.
        let lat = lat();
        assert_eq!(decide_expert(false, 1, &lat), Some(ExpertPlan::Cpu));
        assert_eq!(decide_expert(false, 2, &lat), Some(ExpertPlan::Cpu));
        assert_eq!(decide_expert(false, 512, &lat), Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn decision_is_cost_argmin_property() {
        // Algorithm 1 must pick the cheaper of the two non-resident options.
        check("algorithm1 argmin", 256, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(100.0, 10_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 1_000.0),
                cpu_base_us: g.f64_in(0.0, 10_000.0),
                cpu_per_token_us: g.f64_in(1.0, 2_000.0),
                transfer_us: g.f64_in(100.0, 50_000.0),
                act_roundtrip_per_token_us: g.f64_in(0.0, 5.0),
            };
            let s = g.usize_in(1..4096);
            let plan = decide_expert(false, s, &lat).unwrap();
            let cpu = ExpertPlan::Cpu.cost_us(&lat, s);
            let gpu = ExpertPlan::GpuTransfer.cost_us(&lat, s);
            let chosen = plan.cost_us(&lat, s);
            assert!(chosen <= cpu.min(gpu) + 1e-9, "chose {plan:?} ({chosen}) over min({cpu}, {gpu})");
        });
    }

    #[test]
    fn decision_monotone_in_s_property() {
        // If GPU wins at s, it must win at every s' > s (CPU cost strictly
        // increases, GPU cost non-increasing) — the crossover is unique.
        check("algorithm1 monotone", 128, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(500.0, 8_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 500.0),
                cpu_base_us: g.f64_in(0.0, 8_000.0),
                cpu_per_token_us: g.f64_in(10.0, 1_500.0),
                transfer_us: g.f64_in(1_000.0, 30_000.0),
                act_roundtrip_per_token_us: 0.0,
            };
            let s = g.usize_in(2..2048);
            if decide_expert(false, s, &lat) == Some(ExpertPlan::GpuTransfer) {
                for s2 in [s * 2, s * 4] {
                    assert_eq!(
                        decide_expert(false, s2, &lat),
                        Some(ExpertPlan::GpuTransfer),
                        "GPU at {s} but not at {s2}"
                    );
                }
            }
        });
    }

    #[test]
    fn plan_layer_uses_residency() {
        let lat = lat();
        let mut mem = ExpertCache::with_capacity(4);
        mem.pin((0, 1));
        let plans = plan_layer(0, &[1, 1, 0, 700], &mem, &lat);
        assert_eq!(plans[0], Some(ExpertPlan::Cpu));
        assert_eq!(plans[1], Some(ExpertPlan::GpuResident));
        assert_eq!(plans[2], None);
        assert_eq!(plans[3], Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn predict_layer_overlaps_devices() {
        let lat = lat();
        let plans = vec![Some(ExpertPlan::Cpu), Some(ExpertPlan::GpuResident)];
        let sizes = vec![1, 1];
        let t = predict_layer_us(&plans, &sizes, &lat);
        let cpu = lat.cpu_lat(1);
        let gpu = lat.gpu_lat(1);
        assert!((t - cpu.max(gpu)).abs() < 1e-9);
    }
}
