//! Algorithm 1 — the paper's expert execution strategy (§3.3).
//!
//! For each expert `j` of layer `i` with `s = inp_size[j]` input tokens:
//!
//! ```text
//! if s == 0                                 -> skip
//! if is_at_gpu(i, j)                        -> run at GPU (resident)
//! else if cpu_lat(s) > gpu_lat(s) + transfer_lat() -> transfer + run at GPU
//! else                                      -> run at CPU
//! ```
//!
//! The decision consumes only the latency model and the residency set, so
//! it is a pure function — trivially property-testable, and exactly the
//! quantity the paper's contribution lives in.

pub mod policy;

use crate::config::DeviceKind;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;

/// Where and how one expert invocation executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertPlan {
    /// Weights resident on the GPU: execute there, no transfer (Fig. 3a).
    GpuResident,
    /// A LOW-BIT copy is resident in the quantized tier: execute it on the
    /// GPU with the on-the-fly dequant overhead — the third priced option
    /// of the tiered Algorithm 1 (no PCIe traffic, bounded error).
    GpuQuant,
    /// Copy weights CPU->GPU, then execute on the GPU (Fig. 3b).
    GpuTransfer,
    /// Copy activations GPU->CPU, execute on the CPU, copy back (Fig. 3c).
    Cpu,
}

impl ExpertPlan {
    pub fn device(&self) -> DeviceKind {
        match self {
            ExpertPlan::GpuResident | ExpertPlan::GpuQuant | ExpertPlan::GpuTransfer => {
                DeviceKind::Gpu
            }
            ExpertPlan::Cpu => DeviceKind::Cpu,
        }
    }

    /// Latency charged to the plan by the model (µs).
    pub fn cost_us(&self, lat: &LatencyModel, s: usize) -> f64 {
        match self {
            ExpertPlan::GpuResident => lat.gpu_lat(s),
            ExpertPlan::GpuQuant => lat.quant_gpu_lat(s),
            ExpertPlan::GpuTransfer => lat.gpu_lat(s) + lat.transfer_lat(),
            ExpertPlan::Cpu => lat.cpu_lat(s),
        }
    }
}

/// Decide the plan for one expert (the body of Algorithm 1's loop).
pub fn decide_expert(
    resident: bool,
    s: usize,
    lat: &LatencyModel,
) -> Option<ExpertPlan> {
    if s == 0 {
        return None; // line 7-9: skip experts with no input
    }
    if resident {
        return Some(ExpertPlan::GpuResident); // line 10-11
    }
    if lat.cpu_lat(s) > lat.gpu_lat(s) + lat.transfer_lat() {
        Some(ExpertPlan::GpuTransfer) // line 12-13
    } else {
        Some(ExpertPlan::Cpu) // line 14-15
    }
}

/// Algorithm 1 extended with the quantized resident tier: a full-precision
/// resident copy still short-circuits (it is both exact AND the cheapest),
/// but an expert whose only on-GPU copy is low-bit prices THREE options —
/// run the quantized copy now (`quant_gpu_lat`), transfer fp and run on
/// the GPU, or run fp on the CPU — and takes the argmin.  Whether a
/// chosen `GpuQuant` is *accepted* or must be *corrected* is the error
/// budget's call, made by the caller ([`policy`] / the serving sim): this
/// function only prices latency.  With `quant_resident == false` it is
/// exactly [`decide_expert`] — the `--quant-tier off` bit-identity
/// property rests on that.
pub fn decide_expert_tiered(
    fp_resident: bool,
    quant_resident: bool,
    s: usize,
    lat: &LatencyModel,
) -> Option<ExpertPlan> {
    if s == 0 {
        return None;
    }
    if fp_resident {
        return Some(ExpertPlan::GpuResident);
    }
    if !quant_resident {
        return decide_expert(false, s, lat);
    }
    let quant = lat.quant_gpu_lat(s);
    let xfer = lat.gpu_lat(s) + lat.transfer_lat();
    let cpu = lat.cpu_lat(s);
    if quant <= xfer && quant <= cpu {
        Some(ExpertPlan::GpuQuant)
    } else if xfer < cpu {
        Some(ExpertPlan::GpuTransfer)
    } else {
        Some(ExpertPlan::Cpu)
    }
}

/// Algorithm 1 extended for the pipelined layer executor: the expert's
/// weights are already mid-flight on the PCIe lane, arriving `wait_us`
/// from now.  Waiting the transfer out and running on the GPU wins when
/// the residual wait plus the GPU run undercuts BOTH demand options (CPU
/// execution, or a fresh synchronous transfer).  A prefetch that the
/// previous layers' compute fully hid has `wait_us == 0` — its transfer
/// is free.
pub fn inflight_wins(wait_us: f64, s: usize, lat: &LatencyModel) -> bool {
    debug_assert!(s > 0);
    wait_us + lat.gpu_lat(s) < lat.cpu_lat(s).min(lat.gpu_lat(s) + lat.transfer_lat())
}

/// Plan a whole MoE layer: `inp_size[j]` tokens per expert.
/// Returns `plans[j] = None` for idle experts.
pub fn plan_layer(
    layer: usize,
    inp_size: &[usize],
    memory: &ExpertCache,
    lat: &LatencyModel,
) -> Vec<Option<ExpertPlan>> {
    inp_size
        .iter()
        .enumerate()
        .map(|(j, &s)| decide_expert(memory.is_resident((layer, j)), s, lat))
        .collect()
}

/// Predicted layer latency under a set of plans, with the GPU and CPU
/// queues overlapping (the engine executes both concurrently and joins at
/// the layer boundary, where expert outputs are combined).
pub fn predict_layer_us(
    plans: &[Option<ExpertPlan>],
    inp_size: &[usize],
    lat: &LatencyModel,
) -> f64 {
    let mut gpu = 0.0;
    let mut cpu = 0.0;
    for (plan, &s) in plans.iter().zip(inp_size) {
        match plan {
            Some(p) if p.device() == DeviceKind::Gpu => gpu += p.cost_us(lat, s),
            Some(p) => cpu += p.cost_us(lat, s),
            None => {}
        }
    }
    gpu.max(cpu)
}

/// [`predict_layer_us`] with per-expert GPU ready offsets: `waits[j]` is
/// how long after layer start expert `j`'s weights arrive (0 = already
/// there).  GPU-planned experts serialize in expert-index order, each
/// starting no earlier than its arrival — so a prefetch-hidden transfer
/// costs only its un-hidden residue, never a full `transfer_lat()`.  With
/// all-zero waits this is exactly [`predict_layer_us`].
pub fn predict_layer_us_with_waits(
    plans: &[Option<ExpertPlan>],
    inp_size: &[usize],
    waits: &[f64],
    lat: &LatencyModel,
) -> f64 {
    assert_eq!(plans.len(), waits.len());
    let mut gpu = 0.0f64;
    let mut cpu = 0.0f64;
    for ((plan, &s), &w) in plans.iter().zip(inp_size).zip(waits) {
        match plan {
            Some(p) if p.device() == DeviceKind::Gpu => gpu = gpu.max(w) + p.cost_us(lat, s),
            Some(p) => cpu += p.cost_us(lat, s),
            None => {}
        }
    }
    gpu.max(cpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::testkit::{check, Gen};

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    #[test]
    fn idle_expert_skipped() {
        assert_eq!(decide_expert(false, 0, &lat()), None);
        assert_eq!(decide_expert(true, 0, &lat()), None);
    }

    #[test]
    fn resident_always_gpu() {
        let lat = lat();
        for s in [1, 2, 100, 4096] {
            assert_eq!(decide_expert(true, s, &lat), Some(ExpertPlan::GpuResident));
        }
    }

    #[test]
    fn decode_prefers_cpu_prefill_prefers_gpu() {
        // The paper's headline behaviour: small s -> CPU (avoid the weight
        // transfer), large s -> transfer + GPU.
        let lat = lat();
        assert_eq!(decide_expert(false, 1, &lat), Some(ExpertPlan::Cpu));
        assert_eq!(decide_expert(false, 2, &lat), Some(ExpertPlan::Cpu));
        assert_eq!(decide_expert(false, 512, &lat), Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn decision_is_cost_argmin_property() {
        // Algorithm 1 must pick the cheaper of the two non-resident options.
        check("algorithm1 argmin", 256, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(100.0, 10_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 1_000.0),
                cpu_base_us: g.f64_in(0.0, 10_000.0),
                cpu_per_token_us: g.f64_in(1.0, 2_000.0),
                transfer_us: g.f64_in(100.0, 50_000.0),
                act_roundtrip_per_token_us: g.f64_in(0.0, 5.0),
            };
            let s = g.usize_in(1..4096);
            let plan = decide_expert(false, s, &lat).unwrap();
            let cpu = ExpertPlan::Cpu.cost_us(&lat, s);
            let gpu = ExpertPlan::GpuTransfer.cost_us(&lat, s);
            let chosen = plan.cost_us(&lat, s);
            assert!(chosen <= cpu.min(gpu) + 1e-9, "chose {plan:?} ({chosen}) over min({cpu}, {gpu})");
        });
    }

    #[test]
    fn decision_monotone_in_s_property() {
        // If GPU wins at s, it must win at every s' > s (CPU cost strictly
        // increases, GPU cost non-increasing) — the crossover is unique.
        check("algorithm1 monotone", 128, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(500.0, 8_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 500.0),
                cpu_base_us: g.f64_in(0.0, 8_000.0),
                cpu_per_token_us: g.f64_in(10.0, 1_500.0),
                transfer_us: g.f64_in(1_000.0, 30_000.0),
                act_roundtrip_per_token_us: 0.0,
            };
            let s = g.usize_in(2..2048);
            if decide_expert(false, s, &lat) == Some(ExpertPlan::GpuTransfer) {
                for s2 in [s * 2, s * 4] {
                    assert_eq!(
                        decide_expert(false, s2, &lat),
                        Some(ExpertPlan::GpuTransfer),
                        "GPU at {s} but not at {s2}"
                    );
                }
            }
        });
    }

    #[test]
    fn tiered_decision_is_three_way_argmin_property() {
        // The tiered Algorithm 1 must pick the cheapest of quantized-hit /
        // fp-transfer / fp-CPU whenever only the low-bit copy is resident.
        check("tiered argmin", 256, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(100.0, 10_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 1_000.0),
                cpu_base_us: g.f64_in(0.0, 10_000.0),
                cpu_per_token_us: g.f64_in(1.0, 2_000.0),
                transfer_us: g.f64_in(100.0, 50_000.0),
                act_roundtrip_per_token_us: g.f64_in(0.0, 5.0),
            };
            let s = g.usize_in(1..4096);
            let plan = decide_expert_tiered(false, true, s, &lat).unwrap();
            let chosen = plan.cost_us(&lat, s);
            let best = lat
                .quant_gpu_lat(s)
                .min(lat.gpu_lat(s) + lat.transfer_lat())
                .min(lat.cpu_lat(s));
            assert!(chosen <= best + 1e-9, "chose {plan:?} ({chosen}) over {best}");
            // An fp resident copy dominates everything, including quant.
            assert_eq!(decide_expert_tiered(true, true, s, &lat), Some(ExpertPlan::GpuResident));
        });
    }

    #[test]
    fn tiered_decision_without_quant_copy_is_plain_algorithm1_property() {
        // `--quant-tier off` bit-identity at the decision level: with no
        // quant-resident copy the tiered decision IS Algorithm 1.
        check("tiered off-identity", 256, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(100.0, 10_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 1_000.0),
                cpu_base_us: g.f64_in(0.0, 10_000.0),
                cpu_per_token_us: g.f64_in(1.0, 2_000.0),
                transfer_us: g.f64_in(100.0, 50_000.0),
                act_roundtrip_per_token_us: g.f64_in(0.0, 5.0),
            };
            let s = g.usize_in(0..4096);
            for resident in [false, true] {
                assert_eq!(
                    decide_expert_tiered(resident, false, s, &lat),
                    decide_expert(resident, s, &lat)
                );
            }
        });
    }

    #[test]
    fn quant_plan_runs_on_gpu_queue() {
        let lat = lat();
        assert_eq!(ExpertPlan::GpuQuant.device(), crate::config::DeviceKind::Gpu);
        // Prediction folds a quant hit into the GPU queue at its dequant-
        // loaded cost.
        let t = predict_layer_us(&[Some(ExpertPlan::GpuQuant)], &[1], &lat);
        assert!((t - lat.quant_gpu_lat(1)).abs() < 1e-9);
        let tw =
            predict_layer_us_with_waits(&[Some(ExpertPlan::GpuQuant)], &[1], &[500.0], &lat);
        assert!((tw - (500.0 + lat.quant_gpu_lat(1))).abs() < 1e-9);
    }

    #[test]
    fn inflight_decision_is_cost_argmin_property() {
        // Waiting out an in-flight transfer must be chosen exactly when it
        // is the cheapest of the three options.
        check("inflight argmin", 256, |g: &mut Gen| {
            let lat = LatencyModel {
                gpu_const_us: g.f64_in(100.0, 10_000.0),
                gpu_single_extra_us: g.f64_in(0.0, 1_000.0),
                cpu_base_us: g.f64_in(0.0, 10_000.0),
                cpu_per_token_us: g.f64_in(1.0, 2_000.0),
                transfer_us: g.f64_in(100.0, 50_000.0),
                act_roundtrip_per_token_us: g.f64_in(0.0, 5.0),
            };
            let s = g.usize_in(1..4096);
            let wait = g.f64_in(0.0, 60_000.0);
            let win = inflight_wins(wait, s, &lat);
            let waited = wait + lat.gpu_lat(s);
            let demand = lat.cpu_lat(s).min(lat.gpu_lat(s) + lat.transfer_lat());
            assert_eq!(win, waited < demand);
            // A fully hidden transfer (wait 0) always beats a fresh one.
            assert!(
                inflight_wins(0.0, s, &lat)
                    || lat.cpu_lat(s) <= lat.gpu_lat(s),
                "free weights must win unless the CPU is faster than resident GPU"
            );
        });
    }

    #[test]
    fn zero_waits_match_plain_prediction() {
        let lat = lat();
        let plans = vec![
            Some(ExpertPlan::Cpu),
            Some(ExpertPlan::GpuResident),
            None,
            Some(ExpertPlan::GpuTransfer),
        ];
        let sizes = vec![1, 2, 0, 700];
        let waits = vec![0.0; 4];
        assert_eq!(
            predict_layer_us_with_waits(&plans, &sizes, &waits, &lat),
            predict_layer_us(&plans, &sizes, &lat)
        );
    }

    #[test]
    fn hidden_transfer_beats_demand_transfer_in_prediction() {
        // The pipeline's accounting claim: an expert whose transfer was
        // prefetch-hidden (GpuResident + small wait) costs the layer less
        // than the same expert on the demand-transfer path.
        let lat = lat();
        let sizes = vec![512];
        let demand = predict_layer_us(&[Some(ExpertPlan::GpuTransfer)], &sizes, &lat);
        for wait_frac in [0.0, 0.25, 0.5] {
            let wait = lat.transfer_lat() * wait_frac;
            let hidden = predict_layer_us_with_waits(
                &[Some(ExpertPlan::GpuResident)],
                &sizes,
                &[wait],
                &lat,
            );
            assert!(
                hidden < demand,
                "wait {wait}: hidden {hidden} not below demand {demand}"
            );
        }
        // And the wait is not free: prediction is monotone in it.
        let a = predict_layer_us_with_waits(&[Some(ExpertPlan::GpuResident)], &sizes, &[0.0], &lat);
        let b = predict_layer_us_with_waits(
            &[Some(ExpertPlan::GpuResident)],
            &sizes,
            &[1_000.0],
            &lat,
        );
        assert!(b > a);
    }

    #[test]
    fn plan_layer_uses_residency() {
        let lat = lat();
        let mut mem = ExpertCache::with_capacity(4);
        mem.pin((0, 1));
        let plans = plan_layer(0, &[1, 1, 0, 700], &mem, &lat);
        assert_eq!(plans[0], Some(ExpertPlan::Cpu));
        assert_eq!(plans[1], Some(ExpertPlan::GpuResident));
        assert_eq!(plans[2], None);
        assert_eq!(plans[3], Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn predict_layer_overlaps_devices() {
        let lat = lat();
        let plans = vec![Some(ExpertPlan::Cpu), Some(ExpertPlan::GpuResident)];
        let sizes = vec![1, 1];
        let t = predict_layer_us(&plans, &sizes, &lat);
        let cpu = lat.cpu_lat(1);
        let gpu = lat.gpu_lat(1);
        assert!((t - cpu.max(gpu)).abs() < 1e-9);
    }
}
