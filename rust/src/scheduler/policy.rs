//! Execution-policy abstraction.
//!
//! All four systems the paper evaluates (Fiddler + three baselines) are
//! policies over the SAME substrate: they differ only in (a) which experts
//! are resident/pinned, (b) where a non-resident expert executes, (c) how
//! costs accrue (e.g. ZeRO-Infinity overlaps weight streaming with
//! compute), and (d) whether beams are batched.  The engine consults the
//! policy; numerics are identical across policies by construction.

use super::{decide_expert, ExpertPlan};
use crate::config::DeviceKind;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::placement;
use crate::popularity::Profile;

pub trait ExecPolicy: Send {
    fn name(&self) -> &'static str;

    /// Initialization-phase placement (paper Fig. 2a). Default: nothing.
    fn init(&mut self, _memory: &mut ExpertCache, _profile: &Profile, _seed: u64) {}

    /// Plan one MoE layer given per-expert input sizes. May mutate the
    /// cache (dynamic caching policies do).  `now_us` is the virtual time
    /// at the start of the layer (async transfers only count as resident
    /// once their completion timestamp has passed).
    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>>;

    /// Hook after a layer's routing is known and its experts are queued —
    /// speculative policies issue next-layer weight prefetches here,
    /// overlapping PCIe transfers with the layer's compute.
    fn post_layer(
        &mut self,
        _layer: usize,
        _inp_size: &[usize],
        _memory: &mut ExpertCache,
        _lat: &LatencyModel,
        _now_us: f64,
    ) {
    }

    /// Cost (µs) charged for executing one expert under `plan` with `s`
    /// tokens. Default: the latency model's straightforward cost.
    fn expert_cost_us(&self, plan: ExpertPlan, s: usize, lat: &LatencyModel) -> f64 {
        plan.cost_us(lat, s)
    }

    /// Whether beam-search beams are processed as one batch (Fiddler) or
    /// sequentially per beam (llama.cpp b2956's beam path).
    fn batches_beams(&self) -> bool {
        true
    }

    /// Device that runs the non-expert part (attention) of `layer`.
    fn attn_device(&self, _layer: usize) -> DeviceKind {
        DeviceKind::Gpu
    }
}

/// The paper's system: popularity placement + Algorithm 1.
pub struct FiddlerPolicy {
    pub placement: crate::config::serving::PlacementStrategy,
}

impl Default for FiddlerPolicy {
    fn default() -> Self {
        FiddlerPolicy { placement: crate::config::serving::PlacementStrategy::Popularity }
    }
}

impl ExecPolicy for FiddlerPolicy {
    fn name(&self) -> &'static str {
        "fiddler"
    }

    fn init(&mut self, memory: &mut ExpertCache, profile: &Profile, seed: u64) {
        placement::place(memory, profile, self.placement, seed);
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        // Algorithm 1 per expert; lookups record hit/miss stats and
        // refresh recency stamps for resident experts we actually use.
        inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    return None;
                }
                decide_expert(memory.lookup((layer, j), now_us), s, lat)
            })
            .collect()
    }

    fn expert_cost_us(&self, plan: ExpertPlan, s: usize, lat: &LatencyModel) -> f64 {
        match plan {
            // Fiddler streams the next expert's weights while the GPU
            // computes (§3.2: the transfer dominates; compute hides under
            // it), so the GPU-queue occupancy is max(transfer, compute).
            ExpertPlan::GpuTransfer => lat.transfer_lat().max(lat.gpu_lat(s)),
            p => p.cost_us(lat, s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    #[test]
    fn fiddler_pins_popular_and_decides() {
        let hw = HardwareConfig::env1();
        let lat = LatencyModel::from_hardware(&hw);
        let mut mem = ExpertCache::with_capacity(2);
        let mut prof = Profile::new(1, 4);
        prof.counts[0] = vec![100, 1, 50, 2];
        let mut pol = FiddlerPolicy::default();
        pol.init(&mut mem, &prof, 0);
        assert!(mem.is_resident((0, 0)));
        assert!(mem.is_resident((0, 2)));

        let plans = pol.plan_layer(0, &[1, 1, 0, 900], &mut mem, &lat, 0.0);
        assert_eq!(plans[0], Some(ExpertPlan::GpuResident));
        assert_eq!(plans[1], Some(ExpertPlan::Cpu));
        assert_eq!(plans[2], None);
        assert_eq!(plans[3], Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn fiddler_overlaps_transfer_with_compute() {
        let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
        let pol = FiddlerPolicy::default();
        let c = pol.expert_cost_us(ExpertPlan::GpuTransfer, 512, &lat);
        assert!((c - lat.transfer_lat().max(lat.gpu_lat(512))).abs() < 1e-9);
        assert!(c < ExpertPlan::GpuTransfer.cost_us(&lat, 512));
    }
}
