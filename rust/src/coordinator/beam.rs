//! Beam-search decoding (paper scenario c).
//!
//! Under Fiddler the beams form ONE decode batch: every MoE layer sees up
//! to `width` tokens, so per-expert input sizes grow and the cross-token
//! batching of the CPU path (affine latency, base amortized) pays off.
//!
//! Under the llama.cpp baseline (`batches_beams() == false`) beams are
//! decoded one at a time, AND — matching the llama.cpp b2956 beam-search
//! implementation the paper benchmarks — the KV cache holds only the
//! *common prefix* of all beams: each step, every beam re-evaluates its
//! divergent suffix token by token.  We compute the true common prefix
//! from beam ancestry and charge the re-evaluation at the measured
//! single-token step cost (numerics still come from the forked caches —
//! identical results, faithfully slower clock).  This asymmetry is the
//! source of the paper's 11.57x beam-search speedup (Fig. 6).

use super::engine::{log_softmax, Engine};
use crate::kvcache::SequenceCache;
use crate::metrics::GenMetrics;
use crate::util::rank_key;
use anyhow::Result;

pub struct BeamOutput {
    /// Best beam's generated tokens (length = max_new).
    pub tokens: Vec<u32>,
    pub score: f32,
    pub metrics: GenMetrics,
}

#[derive(Clone)]
struct Beam {
    cache: SequenceCache,
    tokens: Vec<u32>,
    last: u32,
    score: f32,
}

/// Indices of the `k` largest entries of `vals`, descending, NaN-safe;
/// ties break toward the lower index (matching the stable sort the beam
/// update always used).
pub fn top_indices_desc(vals: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| rank_key(vals[b]).total_cmp(&rank_key(vals[a])).then(a.cmp(&b)));
    idx.truncate(k.min(vals.len()));
    idx
}

/// Select the `width` best (score, parent, token) continuations from the
/// per-beam log-softmax rows — pure, property-tested beam-update kernel.
pub fn select_candidates(
    scores: &[f32],
    all_lsm: &[Vec<f32>],
    width: usize,
) -> Vec<(f32, usize, usize)> {
    assert_eq!(scores.len(), all_lsm.len());
    let mut cands: Vec<(f32, usize, usize)> = Vec::with_capacity(scores.len() * width);
    for (bi, lsm) in all_lsm.iter().enumerate() {
        // Only the per-beam top `width` tokens can survive globally.
        for t in top_indices_desc(lsm, width) {
            cands.push((scores[bi] + lsm[t], bi, t));
        }
    }
    cands.sort_by(|a, b| rank_key(b.0).total_cmp(&rank_key(a.0)));
    cands.truncate(width);
    cands
}

/// Longest common prefix length of all beams' generated tokens (the part
/// llama.cpp keeps in its shared KV cache).
fn common_prefix_len(beams: &[Beam]) -> usize {
    let first = &beams[0].tokens;
    let mut n = first.len();
    for b in &beams[1..] {
        let mut i = 0;
        while i < n && i < b.tokens.len() && b.tokens[i] == first[i] {
            i += 1;
        }
        n = i;
    }
    // The freshly appended token always differs in evaluation order —
    // never count the final position as common work to skip.
    n.min(first.len().saturating_sub(1))
}

impl Engine {
    /// Beam search with `width` beams for `max_new` tokens.
    pub fn beam_search(
        &mut self,
        prompt: &[u32],
        width: usize,
        max_new: usize,
    ) -> Result<BeamOutput> {
        assert!(width >= 1 && width <= 16, "width {width} out of range");
        let mut metrics = GenMetrics {
            enqueue_us: self.cx.clock.now_us(),
            prompt_tokens: prompt.len(),
            ..Default::default()
        };

        // Prefill once; expand into `width` beams from the top-width tokens.
        let mut cache0 = SequenceCache::new(&self.runner.cfg);
        let h = self.runner.prefill(prompt, &mut cache0, &mut self.cx)?;
        let logits = self.runner.lm_head(&h, &mut self.cx)?;
        let lsm = log_softmax(logits.row(0));
        let first = top_indices_desc(&lsm, width);
        let mut beams: Vec<Beam> = first
            .iter()
            .map(|&t| Beam {
                cache: cache0.fork(),
                tokens: vec![t as u32],
                last: t as u32,
                score: lsm[t],
            })
            .collect();
        metrics.first_token_us = self.cx.clock.now_us();
        metrics.token_done_us.push(metrics.first_token_us);

        for _ in 1..max_new {
            let batched = self.cx.policy.batches_beams();
            // Decode all beams (one batch, or serially per beam).
            let mut all_lsm: Vec<Vec<f32>> = Vec::with_capacity(width);
            if batched {
                let last: Vec<u32> = beams.iter().map(|b| b.last).collect();
                let xs = self.runner.ws.embed_tokens(&last);
                let mut caches: Vec<&mut SequenceCache> =
                    beams.iter_mut().map(|b| &mut b.cache).collect();
                let h = self.runner.decode_step(&xs, &mut caches, &mut self.cx)?;
                let logits = self.runner.lm_head(&h, &mut self.cx)?;
                for r in 0..width {
                    all_lsm.push(log_softmax(logits.row(r)));
                }
            } else {
                // llama.cpp-style: serial per beam, with per-beam suffix
                // re-evaluation beyond the beams' common prefix.
                let common = common_prefix_len(&beams);
                for b in beams.iter_mut() {
                    let divergent = b.tokens.len() - common; // >= 1 (the new token)
                    let t0 = self.cx.clock.now_us();
                    let xs = self.runner.ws.embed_tokens(&[b.last]);
                    let mut caches = [&mut b.cache];
                    let h = self.runner.decode_step(&xs, &mut caches, &mut self.cx)?;
                    let logits = self.runner.lm_head(&h, &mut self.cx)?;
                    all_lsm.push(log_softmax(logits.row(0)));
                    // Charge the re-evaluated suffix tokens at the measured
                    // per-token cost of this beam's step.
                    if divergent > 1 {
                        let step_cost = self.cx.clock.now_us() - t0;
                        self.cx.clock.advance_us(step_cost * (divergent - 1) as f64);
                        let t = self.cx.clock.now_us();
                        self.cx.timeline.reset_to(t);
                    }
                }
            }

            // Candidate selection: top `width` over (beam, token).
            let scores: Vec<f32> = beams.iter().map(|b| b.score).collect();
            let cands = select_candidates(&scores, &all_lsm, width);

            let mut next: Vec<Beam> = Vec::with_capacity(width);
            for &(score, bi, t) in &cands {
                let parent = &beams[bi];
                let mut tokens = parent.tokens.clone();
                tokens.push(t as u32);
                next.push(Beam {
                    cache: parent.cache.fork(),
                    tokens,
                    last: t as u32,
                    score,
                });
            }
            beams = next;
            metrics.token_done_us.push(self.cx.clock.now_us());
        }

        let best = beams
            .into_iter()
            .max_by(|a, b| rank_key(a.score).total_cmp(&rank_key(b.score)))
            .unwrap();
        metrics.cache = Some(self.cx.memory.stats().clone());
        Ok(BeamOutput { tokens: best.tokens, score: best.score, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    #[test]
    fn select_picks_global_top() {
        let scores = [0.0f32, -1.0];
        let lsm = vec![vec![-0.1f32, -5.0, -3.0], vec![-0.2, -0.3, -4.0]];
        let c = select_candidates(&scores, &lsm, 2);
        assert_eq!(c.len(), 2);
        assert_eq!((c[0].1, c[0].2), (0, 0)); // 0.0 - 0.1
        assert_eq!((c[1].1, c[1].2), (1, 0)); // -1.0 - 0.2
    }

    #[test]
    fn select_candidates_properties() {
        check("beam candidate selection", 128, |g: &mut Gen| {
            let width = g.usize_in(1..9);
            let vocab = g.usize_in(width..width + 40);
            let scores: Vec<f32> = (0..width).map(|_| g.f32_in(-20.0, 0.0)).collect();
            let lsm: Vec<Vec<f32>> = (0..width)
                .map(|_| (0..vocab).map(|_| g.f32_in(-10.0, 0.0)).collect())
                .collect();
            let c = select_candidates(&scores, &lsm, width);
            assert_eq!(c.len(), width);
            // Sorted descending.
            assert!(c.windows(2).all(|w| w[0].0 >= w[1].0));
            // Valid parents/tokens, scores consistent.
            for &(s, bi, t) in &c {
                assert!(bi < width && t < vocab);
                assert!((s - (scores[bi] + lsm[bi][t])).abs() < 1e-5);
            }
            // Optimality: nothing outside the selection beats the last pick.
            let worst = c.last().unwrap().0;
            for bi in 0..width {
                for t in 0..vocab {
                    let cand = scores[bi] + lsm[bi][t];
                    if cand > worst + 1e-5 {
                        assert!(
                            c.iter().any(|&(_, b2, t2)| b2 == bi && t2 == t),
                            "missed better candidate ({bi},{t})"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn nan_logit_never_panics_or_wins() {
        // Regression: the old partial_cmp(..).unwrap() sorts panicked on a
        // NaN logit; now NaN ranks last and is never selected while finite
        // candidates remain.
        let scores = [0.0f32, -1.0];
        let lsm = vec![
            vec![f32::NAN, -0.5, -3.0],
            vec![-0.2, f32::NAN, -4.0],
        ];
        let c = select_candidates(&scores, &lsm, 2);
        assert_eq!(c.len(), 2);
        for &(s, bi, t) in &c {
            assert!(s.is_finite(), "NaN candidate selected");
            assert!(!lsm[bi][t].is_nan());
        }
        assert_eq!((c[0].1, c[0].2), (0, 1)); // 0.0 - 0.5
        assert_eq!((c[1].1, c[1].2), (1, 0)); // -1.0 - 0.2

        // All-NaN rows still terminate with the full width, NaNs last.
        let all_nan = vec![vec![f32::NAN; 3], vec![f32::NAN; 3]];
        assert_eq!(select_candidates(&scores, &all_nan, 2).len(), 2);

        // The shared ranking helper keeps ties stable and NaN last.
        assert_eq!(top_indices_desc(&[1.0, f32::NAN, 2.0, 1.0], 4), vec![2, 0, 3, 1]);
    }

    #[test]
    fn common_prefix_examples() {
        let mk = |ts: &[&[u32]]| -> Vec<Beam> {
            ts.iter()
                .map(|t| Beam {
                    cache: crate::kvcache::SequenceCache::new(
                        &crate::config::ModelConfig::test_tiny(),
                    ),
                    tokens: t.to_vec(),
                    last: *t.last().unwrap(),
                    score: 0.0,
                })
                .collect()
        };
        // Divergent at the last position only.
        let b = mk(&[&[1, 2, 3], &[1, 2, 4]]);
        assert_eq!(common_prefix_len(&b), 2);
        // Fully divergent.
        let b = mk(&[&[1, 2, 3], &[9, 2, 3]]);
        assert_eq!(common_prefix_len(&b), 0);
        // Identical beams: final position never counted as common.
        let b = mk(&[&[1, 2, 3], &[1, 2, 3]]);
        assert_eq!(common_prefix_len(&b), 2);
    }
}
