//! The serving engine: policy construction, single-request generation,
//! batched decode — all timing in virtual µs from the simulated substrate.

use crate::baselines::{LruOffloadPolicy, MiiOffloadPolicy, StaticSplitPolicy};
use crate::config::serving::{EvictionKind, Policy, ServingConfig};
use crate::config::{HardwareConfig, ModelConfig};
use crate::expertcache::eviction::{EvictionPolicy, Lru, ScoredPopularity, TransitionAware};
use crate::expertcache::CachedFiddlerPolicy;
use crate::kvcache::SequenceCache;
use crate::metrics::GenMetrics;
use crate::moe::{ExecContext, ModelRunner};
use crate::popularity::Profile;
use crate::scheduler::policy::{ExecPolicy, FiddlerPolicy};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// The model's cross-layer transition profile, or the uniform fallback
/// when no calibration artifacts exist.
fn load_transitions(cfg: &ModelConfig) -> crate::prefetch::TransitionProfile {
    crate::prefetch::TransitionProfile::load(cfg.artifact_dir.join("analysis/analysis.json"))
        .unwrap_or_else(|_| {
            crate::prefetch::TransitionProfile::uniform(cfg.n_layers, cfg.n_experts)
        })
}

/// Build the eviction policy the dynamic expert cache runs, seeded from
/// build-time calibration artifacts when they exist.
pub fn make_eviction(kind: EvictionKind, cfg: &ModelConfig) -> Box<dyn EvictionPolicy> {
    match kind {
        EvictionKind::Lru => Box::new(Lru),
        EvictionKind::ScoredPopularity => Box::new(match load_profile(cfg) {
            Ok(p) => ScoredPopularity::from_profile(p),
            Err(_) => ScoredPopularity::new(cfg.n_layers, cfg.n_experts),
        }),
        EvictionKind::TransitionAware => {
            Box::new(TransitionAware::from_profile(&load_transitions(cfg), cfg.top_k))
        }
    }
}

/// Build the policy object for a serving config + model.
pub fn make_policy(serving: &ServingConfig, cfg: &ModelConfig) -> Box<dyn ExecPolicy> {
    match serving.policy {
        Policy::Fiddler => Box::new(FiddlerPolicy { placement: serving.placement }),
        Policy::MiiOffload => Box::new(MiiOffloadPolicy),
        Policy::LruOffload => Box::new(LruOffloadPolicy::default()),
        Policy::StaticSplit => {
            // serving.ngl is paper-scale (out of 32 layers); rescale.
            let scaled = ((serving.ngl * cfg.n_layers + 31) / 32).max(1).min(cfg.n_layers);
            Box::new(StaticSplitPolicy::new(scaled, cfg.n_experts))
        }
        Policy::FiddlerPrefetch => {
            Box::new(crate::prefetch::PrefetchingFiddlerPolicy::new(load_transitions(cfg), 2))
        }
        Policy::FiddlerCached => {
            let mut p = CachedFiddlerPolicy::new(
                make_eviction(serving.cache_eviction, cfg),
                serving.placement,
                serving.cache_pin_fraction,
            );
            if serving.quant_tier {
                p = p.with_quant_tier(serving.quant_bits, serving.error_budget);
            }
            if serving.cache_partition == crate::config::serving::CachePartition::Layer {
                p = p.with_layer_partition(cfg.n_layers);
            }
            Box::new(p)
        }
    }
}

/// Load the build-time popularity profile for a model.
pub fn load_profile(cfg: &ModelConfig) -> Result<Profile> {
    Profile::load(cfg.artifact_dir.join("analysis/analysis.json"))
}

pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub metrics: GenMetrics,
}

/// One model + one policy + one simulated environment.
pub struct Engine {
    pub runner: ModelRunner,
    pub cx: ExecContext,
    pub serving: ServingConfig,
    rng: Rng,
}

impl Engine {
    pub fn new(
        artifact_dir: impl AsRef<Path>,
        hw: &HardwareConfig,
        serving: ServingConfig,
    ) -> Result<Engine> {
        let runner = ModelRunner::load(artifact_dir.as_ref().to_path_buf())?;
        let profile = load_profile(&runner.cfg)?;
        let policy = make_policy(&serving, &runner.cfg);
        // serving.threads sizes the parallel expert executor AND selects
        // the multi-core latency calibration Algorithm 1 decides against.
        let mut cx = ExecContext::with_threads_opts(
            policy,
            hw,
            &runner.cfg,
            &profile,
            serving.seed,
            serving.threads,
            serving.pin_workers,
        );
        // serving.pipeline_lookahead opens the pipelined layer executor's
        // cross-layer prefetch window (0 = serial legacy loop): transition
        // predictions feed decode/prefill lookahead, observed routing
        // feeds chunked-prefill continuation.
        if serving.pipeline_lookahead > 0 {
            cx.enable_pipeline(crate::pipeline::PipelineState::new(
                serving.pipeline_lookahead,
                runner.cfg.top_k.max(2),
                Some(load_transitions(&runner.cfg)),
            ));
            // --adaptive arms loops 1+3 (per-phase lookahead learning and
            // routing-skew override pricing) inside the pipeline.
            if serving.adaptive {
                cx.pipeline.enable_adaptive();
            }
        }
        // --adaptive arms loop 2 regardless of lookahead: a landed
        // prefetch is protected for a few transfer times so the copy
        // survives until its predicted-use layer.
        if serving.adaptive {
            let window = 4.0 * cx.lat.transfer_lat();
            cx.memory.set_landing_protection(window);
        }
        let rng = Rng::new(serving.seed ^ 0xC0FFEE);
        Ok(Engine { runner, cx, serving, rng })
    }

    pub fn model(&self) -> &ModelConfig {
        &self.runner.cfg
    }

    /// Install a trace-event sink on the execution context and its expert
    /// cache: every generation path (engine-level and serve-loop) then
    /// streams typed [`crate::events::TraceEvent`]s through it.
    pub fn set_event_sink(&mut self, sink: crate::events::EventSink) {
        self.cx.memory.set_event_sink(sink.clone());
        self.cx.sink = sink;
    }

    /// Sample the next token from logits (greedy at temperature 0).
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        sample_token(logits, self.serving.temperature, &mut self.rng)
    }

    /// Generate `max_new` tokens for a single prompt (paper scenario a).
    pub fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenOutput> {
        let mut metrics = GenMetrics {
            enqueue_us: self.cx.clock.now_us(),
            prompt_tokens: prompt.len(),
            ..Default::default()
        };
        let mut cache = SequenceCache::new(&self.runner.cfg);
        let h = self.runner.prefill(prompt, &mut cache, &mut self.cx)?;
        let logits = self.runner.lm_head(&h, &mut self.cx)?;
        let mut tok = self.sample(logits.row(0));
        metrics.first_token_us = self.cx.clock.now_us();
        metrics.token_done_us.push(metrics.first_token_us);
        let mut tokens = vec![tok];

        for _ in 1..max_new {
            let xs = self.runner.ws.embed_tokens(&[tok]);
            let mut caches = [&mut cache];
            let h = self.runner.decode_step(&xs, &mut caches, &mut self.cx)?;
            let logits = self.runner.lm_head(&h, &mut self.cx)?;
            tok = self.sample(logits.row(0));
            tokens.push(tok);
            metrics.token_done_us.push(self.cx.clock.now_us());
        }
        metrics.cache = Some(self.cx.memory.stats().clone());
        metrics.experts = Some(self.cx.events.clone());
        Ok(GenOutput { tokens, metrics })
    }

    /// Prefill only (paper scenario b: TTFT for long prompts).  Returns
    /// the first generated token and its TTFT in virtual µs.
    pub fn prefill_ttft(&mut self, prompt: &[u32]) -> Result<(u32, f64)> {
        let t0 = self.cx.clock.now_us();
        let mut cache = SequenceCache::new(&self.runner.cfg);
        let h = self.runner.prefill(prompt, &mut cache, &mut self.cx)?;
        let logits = self.runner.lm_head(&h, &mut self.cx)?;
        let tok = self.sample(logits.row(0));
        Ok((tok, self.cx.clock.now_us() - t0))
    }

    /// One batched decode step, reducing each sequence's logits row to `T`
    /// in batch order through `f` — the shared core of
    /// [`Engine::decode_batch_step`] (samples in place, no row copies) and
    /// [`Engine::decode_batch_logits`] (owned rows for the lifecycle
    /// scheduler's beam groups).  Batches larger than the biggest decode
    /// bucket are split transparently.
    fn decode_batch_with<T>(
        &mut self,
        last_tokens: &[u32],
        caches: &mut [&mut SequenceCache],
        mut f: impl FnMut(&[f32], &mut Rng) -> T,
    ) -> Result<Vec<T>> {
        assert_eq!(last_tokens.len(), caches.len());
        let max_b = *crate::config::model::DECODE_BATCH_BUCKETS.last().unwrap();
        let mut out = Vec::with_capacity(last_tokens.len());
        let mut i = 0;
        while i < last_tokens.len() {
            let j = (i + max_b).min(last_tokens.len());
            let xs = self.runner.ws.embed_tokens(&last_tokens[i..j]);
            let mut chunk: Vec<&mut SequenceCache> = Vec::with_capacity(j - i);
            // Split the mutable slice chunk-wise.
            let (_, rest) = caches.split_at_mut(i);
            let (take, _) = rest.split_at_mut(j - i);
            for c in take {
                chunk.push(&mut **c);
            }
            let h = self.runner.decode_step(&xs, &mut chunk, &mut self.cx)?;
            let logits = self.runner.lm_head(&h, &mut self.cx)?;
            for r in 0..(j - i) {
                out.push(f(logits.row(r), &mut self.rng));
            }
            i = j;
        }
        Ok(out)
    }

    /// Batched decode returning each sequence's next-token logits row
    /// (owned — the lifecycle scheduler's beam groups score and fork from
    /// them after the call).
    pub fn decode_batch_logits(
        &mut self,
        last_tokens: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.decode_batch_with(last_tokens, caches, |row, _| row.to_vec())
    }

    /// Batched decode + sampling, fused: samples straight from each logits
    /// row with zero copies, in batch order (the RNG stream is unchanged
    /// from the pre-refactor loop).
    pub fn decode_batch_step(
        &mut self,
        last_tokens: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<u32>> {
        let temperature = self.serving.temperature;
        self.decode_batch_with(last_tokens, caches, |row, rng| {
            sample_token(row, temperature, rng)
        })
    }
}

/// Temperature sampling (0 = greedy argmax, ties to lowest index).
pub fn sample_token(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best as u32;
    }
    let inv_t = 1.0 / temperature as f32;
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> =
        logits.iter().map(|&l| (((l - m) * inv_t) as f64).exp()).collect();
    rng.weighted(&weights) as u32
}

/// Numerically-stable log-softmax (used by beam search).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = logits.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
    logits.iter().map(|&l| l - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        assert_eq!(sample_token(&[0.1, 3.0, 2.0], 0.0, &mut rng), 1);
        // tie -> lowest index
        assert_eq!(sample_token(&[5.0, 5.0, 1.0], 0.0, &mut rng), 0);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(1);
        let mut seen = [false; 3];
        for _ in 0..500 {
            seen[sample_token(&[1.0, 1.1, 0.9], 5.0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let sum: f32 = ls.iter().map(|l| l.exp()).sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn log_softmax_stable_for_huge_logits() {
        let ls = log_softmax(&[1000.0, 999.0]);
        assert!(ls.iter().all(|v| v.is_finite()));
    }
}
