//! The serving coordinator (L3): request lifecycle, generation loops,
//! beam search, continuous batching.

pub mod beam;
pub mod engine;

pub use beam::BeamOutput;
pub use engine::{Engine, GenOutput};
