//! MoE model runner: composes the per-op HLO executables into decoder
//! steps, with the execution policy deciding where each expert runs and the
//! simulated substrate accounting the time (DESIGN.md §2/§3).
//!
//! Since PR 5 every forward path — `prefill`, `prefill_chunk`,
//! `decode_step` — drives its layers through the single
//! [`crate::pipeline::run_layers`] loop: this module keeps the
//! path-specific *attention stages* (which executable runs, how K/V
//! append, what attention time costs) and the op plumbing; the shared
//! route → prefetch → dispatch → join machinery lives in
//! [`crate::pipeline`].

pub mod topk;

use crate::config::model::{
    CACHE_BUCKETS, DECODE_BATCH_BUCKETS, LMHEAD_BUCKETS, PREFILL_BUCKETS, TOKEN_BUCKETS,
};
use crate::config::{DeviceKind, HardwareConfig, ModelConfig};
use crate::expertcache::ExpertCache;
use crate::hardware::{DeviceTimeline, PcieLink, VirtualClock};
use crate::kvcache::{gather_batch_padded, SequenceCache};
use crate::latency::LatencyModel;
use crate::pipeline::{ForwardKind, PipelineState};
use crate::popularity::Profile;
use crate::runtime::{Runtime, Tensor, TensorI32, WeightStore};
use crate::scheduler::policy::ExecPolicy;
use crate::util::round_up_bucket;
use anyhow::{bail, Result};

/// Counters over expert executions (hit-rate metrics, Fig. 8 analysis).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExpertEvents {
    pub resident: u64,
    pub transferred: u64,
    pub cpu: u64,
    /// Executions served from an accepted low-bit resident copy
    /// (`--quant-tier on`; 0 with the tier off).
    pub quant: u64,
    /// Resident executions that waited out a still-in-flight pipeline
    /// prefetch instead of taking a demand path (subset of `resident`).
    pub prefetch_overlapped: u64,
}

impl ExpertEvents {
    pub fn total(&self) -> u64 {
        self.resident + self.transferred + self.cpu + self.quant
    }

    /// Fraction of executions served from HBM without a demand transfer —
    /// either fp tier or an accepted quantized copy.
    pub fn hit_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            (self.resident + self.quant) as f64 / t as f64
        }
    }

    /// Counters accumulated since `base` was snapshotted (per-window
    /// attribution, like [`crate::expertcache::CacheStats::delta_since`]).
    /// Saturating, so a stale base never underflows.
    pub fn delta_since(&self, base: &ExpertEvents) -> ExpertEvents {
        ExpertEvents {
            resident: self.resident.saturating_sub(base.resident),
            transferred: self.transferred.saturating_sub(base.transferred),
            cpu: self.cpu.saturating_sub(base.cpu),
            quant: self.quant.saturating_sub(base.quant),
            prefetch_overlapped: self
                .prefetch_overlapped
                .saturating_sub(base.prefetch_overlapped),
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("resident", crate::util::json::Json::Num(self.resident as f64));
        o.set("transferred", crate::util::json::Json::Num(self.transferred as f64));
        o.set("cpu", crate::util::json::Json::Num(self.cpu as f64));
        o.set("quant", crate::util::json::Json::Num(self.quant as f64));
        o.set(
            "prefetch_overlapped",
            crate::util::json::Json::Num(self.prefetch_overlapped as f64),
        );
        o.set("hit_rate", crate::util::json::Json::Num(self.hit_rate()));
        o
    }
}

/// Mutable execution state threaded through a serving session: the policy,
/// the simulated memory/link/clock, online profiling, the wall-clock
/// worker pool executing CPU-planned experts, and the layer pipeline's
/// lookahead state.
pub struct ExecContext {
    pub policy: Box<dyn ExecPolicy>,
    pub memory: ExpertCache,
    pub link: PcieLink,
    pub lat: LatencyModel,
    pub hw: HardwareConfig,
    pub timeline: DeviceTimeline,
    pub clock: VirtualClock,
    pub online_profile: Profile,
    pub events: ExpertEvents,
    /// CPU worker threads of the parallel expert executor; 1 = serial
    /// (the pre-parallel engine behavior, bit-for-bit).
    pub threads: usize,
    /// Persistent worker pool for CPU-planned experts (see [`crate::exec`]).
    pub pool: crate::exec::ExecutorPool,
    /// Cross-layer lookahead state of the pipelined layer executor
    /// ([`crate::pipeline`]); disabled (lookahead 0) by default.
    pub pipeline: PipelineState,
    /// Engine-event stream ([`crate::events`]); disabled by default (one
    /// branch per would-be event).  The serve loop attaches a live sink
    /// via [`crate::server::ServeBackend::set_event_sink`].
    pub sink: crate::events::EventSink,
}

impl ExecContext {
    /// Build a context: runs the policy's initialization-time placement
    /// against `profile` (the build-time calibration profile).  Serial
    /// executor (`threads = 1`); see [`ExecContext::with_threads`].
    pub fn new(
        policy: Box<dyn ExecPolicy>,
        hw: &HardwareConfig,
        cfg: &ModelConfig,
        profile: &Profile,
        seed: u64,
    ) -> ExecContext {
        Self::with_threads(policy, hw, cfg, profile, seed, 1)
    }

    /// Build a context with a `threads`-wide parallel expert executor.
    /// When the host kernel is enabled (the only path the pool
    /// accelerates), the latency model switches to the multi-core CPU
    /// curve, so Algorithm 1's crossover reflects the executor's actual
    /// throughput (a faster CPU keeps more experts off the PCIe link).
    /// With the host kernel off the single-core model is kept — the
    /// engine must never plan against a speedup it does not realize.
    ///
    /// The multi-core curve is analytic by default
    /// ([`LatencyModel::from_hardware_threaded`]); with
    /// `FIDDLER_MEASURED_CALIB=1` it is instead *measured* on this host by
    /// timing the host expert kernel through real executor pools
    /// ([`crate::latency::calib::calibrate_multicore_measured`]).
    pub fn with_threads(
        policy: Box<dyn ExecPolicy>,
        hw: &HardwareConfig,
        cfg: &ModelConfig,
        profile: &Profile,
        seed: u64,
        threads: usize,
    ) -> ExecContext {
        Self::with_threads_opts(policy, hw, cfg, profile, seed, threads, false)
    }

    /// [`ExecContext::with_threads`] plus worker placement: `pin_workers`
    /// requests best-effort core affinity on the executor pool's threads
    /// (`--pin-workers`; a no-op on platforms without `sched_setaffinity`).
    /// Pinning never changes planning or virtual time — only wall-clock
    /// dispatch jitter.
    #[allow(clippy::too_many_arguments)]
    pub fn with_threads_opts(
        mut policy: Box<dyn ExecPolicy>,
        hw: &HardwareConfig,
        cfg: &ModelConfig,
        profile: &Profile,
        seed: u64,
        threads: usize,
        pin_workers: bool,
    ) -> ExecContext {
        let threads = threads.max(1);
        let lat_threads =
            if crate::cpukernel::host_kernel_enabled() { threads } else { 1 };
        let measured = lat_threads > 1
            && std::env::var("FIDDLER_MEASURED_CALIB").map(|v| v == "1").unwrap_or(false);
        let lat = if measured {
            crate::latency::calib::calibrate_multicore_measured(hw, lat_threads, seed)
        } else {
            LatencyModel::from_hardware_threaded(hw, lat_threads)
        };
        // Scale the paper-environment expert capacity to this model's
        // expert count (capacity fractions are what transfer: 56/256 and
        // 125/256 in the paper).
        let frac = hw.gpu_expert_capacity() as f64 / 256.0;
        let capacity = ((cfg.total_experts() as f64 * frac).round() as usize)
            .min(cfg.total_experts());
        let mut memory = ExpertCache::with_capacity(capacity);
        policy.init(&mut memory, profile, seed);
        ExecContext {
            policy,
            memory,
            link: PcieLink::new(hw),
            lat,
            hw: hw.clone(),
            timeline: DeviceTimeline::new(),
            clock: VirtualClock::new(),
            online_profile: Profile::new(cfg.n_layers, cfg.n_experts),
            events: ExpertEvents::default(),
            threads,
            pool: crate::exec::ExecutorPool::with_affinity(threads, pin_workers),
            pipeline: PipelineState::disabled(),
            sink: crate::events::EventSink::default(),
        }
    }

    /// Install the layer pipeline's lookahead state.  Speculative
    /// prefetches need unpinned cache slots, but initialization placement
    /// pins the full capacity — the pipeline releases the least popular
    /// pins *lazily*, one per slot a gated-profitable prefetch actually
    /// needs (capped at half the cache), so workloads where the window
    /// never pays keep the full pinned placement and run exactly like the
    /// serial loop.
    pub fn enable_pipeline(&mut self, state: PipelineState) {
        self.pipeline = state;
    }

    /// Charge serial (blocking) work on one device: the clock advances to
    /// its completion.
    fn charge_serial(&mut self, device: DeviceKind, us: f64) {
        let done = self.timeline.schedule(device, self.clock.now_us(), us);
        self.clock.advance_to_us(done);
        self.timeline.reset_to(done);
    }
}

/// One op argument on the fast execution path: per-call activations
/// (uploaded fresh) or a named weight (served from the device cache).
enum MixedArg<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
    Weight(&'a str),
}

/// The model runner (stateless w.r.t. requests; owns runtime + weights).
pub struct ModelRunner {
    pub rt: Runtime,
    pub ws: WeightStore,
    pub cfg: ModelConfig,
    /// Weights pinned as device-resident PJRT buffers, uploaded once on
    /// first use (perf: avoids re-serializing hundreds of KB per op call —
    /// see EXPERIMENTS.md §Perf).  Single-threaded engine => RefCell.
    wbuf: std::cell::RefCell<std::collections::HashMap<String, xla::PjRtBuffer>>,
}

impl ModelRunner {
    pub fn load(artifact_dir: impl Into<std::path::PathBuf>) -> Result<ModelRunner> {
        let dir = artifact_dir.into();
        let rt = Runtime::open(dir.clone())?;
        let ws = WeightStore::load(&dir)?;
        let cfg = ws.config.clone();
        Ok(ModelRunner { rt, ws, cfg, wbuf: Default::default() })
    }

    /// Make sure every named weight tensor has a cached device buffer.
    fn ensure_wbufs(&self, names: &[String]) -> Result<()> {
        let mut map = self.wbuf.borrow_mut();
        for name in names {
            if !map.contains_key(name) {
                let t = self.ws.get(name)?;
                map.insert(name.clone(), self.rt.buffer_from_tensor(t)?);
            }
        }
        Ok(())
    }

    /// Execute `op` with a mix of per-call activation tensors and cached
    /// weight buffers. `args` lists the op parameters in order.
    fn execute_mixed(&self, op: &str, args: &[MixedArg<'_>]) -> Result<Vec<Tensor>> {
        let weight_names: Vec<String> = args
            .iter()
            .filter_map(|a| match a {
                MixedArg::Weight(n) => Some(n.to_string()),
                _ => None,
            })
            .collect();
        self.ensure_wbufs(&weight_names)?;
        // Upload per-call activations.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for a in args {
            match a {
                MixedArg::F32(t) => owned.push(self.rt.buffer_from_tensor(t)?),
                MixedArg::I32(t) => owned.push(self.rt.buffer_from_i32(t)?),
                MixedArg::Weight(_) => {}
            }
        }
        let map = self.wbuf.borrow();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut oi = 0;
        for a in args {
            match a {
                MixedArg::Weight(n) => refs.push(map.get(*n).expect("ensured")),
                _ => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        self.rt.execute_buffers(op, &refs)
    }

    fn attn_weight_names(&self, layer: usize) -> [String; 5] {
        [
            format!("layers.{layer}.attn_norm"),
            format!("layers.{layer}.wq"),
            format!("layers.{layer}.wk"),
            format!("layers.{layer}.wv"),
            format!("layers.{layer}.wo"),
        ]
    }

    /// Router half of an MoE layer: fused norm + gate over `h`
    /// (`[n, hidden]`), returning `(probs, xn)`.
    pub(crate) fn gate_outputs(&self, layer: usize, h: &Tensor) -> Result<(Tensor, Tensor)> {
        let n = h.shape[0];
        let gate_op = format!("gate_b{n}");
        let ffn_norm = format!("layers.{layer}.ffn_norm");
        let gate_w = format!("layers.{layer}.gate");
        let mut out = self.execute_mixed(
            &gate_op,
            &[
                MixedArg::F32(h),
                MixedArg::Weight(&ffn_norm),
                MixedArg::Weight(&gate_w),
            ],
        )?;
        let xn = out.swap_remove(1);
        let probs = out.swap_remove(0);
        Ok((probs, xn))
    }

    /// One expert's PJRT executable over gathered input `xe`
    /// (`[bucket, hidden]`), returning its `[bucket, hidden]` output.
    pub(crate) fn expert_gpu(
        &self,
        layer: usize,
        j: usize,
        xe: &Tensor,
        bucket: usize,
    ) -> Result<Tensor> {
        let w1 = format!("layers.{layer}.experts.{j}.w1");
        let w3 = format!("layers.{layer}.experts.{j}.w3");
        let w2 = format!("layers.{layer}.experts.{j}.w2");
        let mut out = self.execute_mixed(
            &format!("expert_b{bucket}"),
            &[
                MixedArg::F32(xe),
                MixedArg::Weight(&w1),
                MixedArg::Weight(&w3),
                MixedArg::Weight(&w2),
            ],
        )?;
        Ok(out.swap_remove(0))
    }

    /// One MoE (expert) layer over `h` (`[n, hidden]`, rows >= `valid`
    /// are padding): router + top-k + per-expert dispatch per the policy,
    /// combining outputs back into `h` (residual add included).
    pub fn moe_layer(
        &self,
        layer: usize,
        h: &mut Tensor,
        valid: usize,
        cx: &mut ExecContext,
    ) -> Result<()> {
        let (probs, xn) = self.gate_outputs(layer, h)?;
        self.moe_experts(layer, h, &probs, &xn, valid, cx)
    }

    /// Expert dispatch half of an MoE layer, with router outputs already
    /// in hand.  Delegates to the pipelined layer executor's MoE stage —
    /// THE single implementation shared by all forward paths
    /// ([`crate::pipeline::run_layers`]).
    pub fn moe_experts(
        &self,
        layer: usize,
        h: &mut Tensor,
        probs: &Tensor,
        xn: &Tensor,
        valid: usize,
        cx: &mut ExecContext,
    ) -> Result<()> {
        crate::pipeline::moe_stage(self, layer, h, probs, xn, valid, cx)
    }

    /// Prefill a prompt into `cache`; returns the last token's hidden state
    /// (`[1, hidden]`).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut SequenceCache,
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty prompt");
        }
        let max_bucket = *PREFILL_BUCKETS.last().unwrap();
        if n > max_bucket {
            bail!("prompt of {n} tokens exceeds max prefill bucket {max_bucket}");
        }
        let s = round_up_bucket(n, PREFILL_BUCKETS);
        let mut x = Tensor::zeros(vec![s, self.cfg.hidden]);
        let emb = self.ws.embed_tokens(tokens);
        x.data[..n * self.cfg.hidden].copy_from_slice(&emb.data);

        let kvd = self.cfg.kv_dim();
        let x = crate::pipeline::run_layers(
            self,
            cx,
            x,
            n,
            ForwardKind::Prefill,
            // Attention stage: the monolithic prefill executable (separate
            // from the router — the fused attn+gate variant measured
            // SLOWER under XLA-CPU; see the perf_ab_fused ablation and
            // EXPERIMENTS.md §Perf).
            &mut |layer, x, cx| {
                let valid = TensorI32::scalar(n as i32);
                let wn = self.attn_weight_names(layer);
                let out = self.execute_mixed(
                    &format!("attn_prefill_s{s}"),
                    &[
                        MixedArg::F32(x),
                        MixedArg::I32(&valid),
                        MixedArg::Weight(&wn[0]),
                        MixedArg::Weight(&wn[1]),
                        MixedArg::Weight(&wn[2]),
                        MixedArg::Weight(&wn[3]),
                        MixedArg::Weight(&wn[4]),
                    ],
                )?;
                let (h_attn, k, v) = (&out[0], &out[1], &out[2]);
                cache.layers[layer].extend(n, &k.data[..n * kvd], &v.data[..n * kvd]);

                let attn_dev = cx.policy.attn_device(layer);
                let mut attn_us = cx.hw.attn_prefill_per_token_us * n as f64;
                if attn_dev == DeviceKind::Cpu {
                    attn_us *= cx.hw.attn_cpu_factor;
                }
                cx.charge_serial(attn_dev, attn_us);
                Ok(h_attn.clone())
            },
        )?;
        // Last valid row only.
        Ok(x.gather_rows_padded(&[n - 1], 1))
    }

    /// Continue a prefill: process `tokens` — the next chunk of a prompt
    /// whose preceding prefix is already in `cache` — and return the
    /// chunk's last hidden state (`[1, hidden]`).  With an empty cache
    /// this is exactly [`ModelRunner::prefill`].
    ///
    /// The AOT op set has no cache-consuming chunk-attention executable,
    /// so a continuation chunk's attention runs token-by-token through the
    /// decode executable (numerics within kernel tolerance of the
    /// monolithic prefill executable), while the MoE half runs
    /// chunk-batched: routing and expert dispatch see all of the chunk's
    /// rows at once, preserving the cross-token expert batching the
    /// paper's CPU path relies on.  Virtual time charges attention at the
    /// prefill per-token rate (the simulated testbed's chunk-attention
    /// kernel) and the experts through the normal per-layer accounting, so
    /// chunked prefill pays the honest price of chunking — one expert-base
    /// amortization per chunk instead of one per prompt.
    pub fn prefill_chunk(
        &self,
        tokens: &[u32],
        cache: &mut SequenceCache,
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        if cache.is_empty() {
            return self.prefill(tokens, cache, cx);
        }
        let m = tokens.len();
        if m == 0 {
            bail!("empty prefill chunk");
        }
        let max_c = *CACHE_BUCKETS.last().unwrap();
        if cache.len() + m > max_c {
            bail!("sequence of {} tokens exceeds max cache bucket {max_c}", cache.len() + m);
        }
        // Gate executables exist for every power-of-two token bucket.
        let bucket = round_up_bucket(m, TOKEN_BUCKETS);
        let mut x = Tensor::zeros(vec![bucket, self.cfg.hidden]);
        let emb = self.ws.embed_tokens(tokens);
        x.data[..m * self.cfg.hidden].copy_from_slice(&emb.data);

        let kvd = self.cfg.kv_dim();
        let (kvh, hd) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        let x = crate::pipeline::run_layers(
            self,
            cx,
            x,
            m,
            // Continuation: the previous pass of this prompt already
            // observed the per-layer routing — the pipeline's lookahead
            // prefetch reuses it as the predictor.
            ForwardKind::ChunkContinuation,
            &mut |layer, x, cx| {
                let wn = self.attn_weight_names(layer);
                let mut h_attn = Tensor::zeros(vec![bucket, self.cfg.hidden]);
                for t in 0..m {
                    let pos = cache.layers[layer].len;
                    let c = round_up_bucket(pos + 1, CACHE_BUCKETS);
                    let (mut kcb, mut vcb) = {
                        let seq: &SequenceCache = cache;
                        gather_batch_padded(&[seq], layer, 1, c, kvd)
                    };
                    kcb.shape = vec![1, c, kvh, hd];
                    vcb.shape = vec![1, c, kvh, hd];
                    let xt = x.gather_rows_padded(&[t], 1);
                    let pos_t = TensorI32::vec(vec![pos as i32]);
                    let out = self.execute_mixed(
                        &format!("attn_decode_b1_c{c}"),
                        &[
                            MixedArg::F32(&xt),
                            MixedArg::F32(&kcb),
                            MixedArg::F32(&vcb),
                            MixedArg::I32(&pos_t),
                            MixedArg::Weight(&wn[0]),
                            MixedArg::Weight(&wn[1]),
                            MixedArg::Weight(&wn[2]),
                            MixedArg::Weight(&wn[3]),
                            MixedArg::Weight(&wn[4]),
                        ],
                    )?;
                    h_attn.row_mut(t).copy_from_slice(out[0].row(0));
                    cache.layers[layer].append(&out[1].data[..kvd], &out[2].data[..kvd]);
                }

                let attn_dev = cx.policy.attn_device(layer);
                let mut attn_us = cx.hw.attn_prefill_per_token_us * m as f64;
                if attn_dev == DeviceKind::Cpu {
                    attn_us *= cx.hw.attn_cpu_factor;
                }
                cx.charge_serial(attn_dev, attn_us);
                Ok(h_attn)
            },
        )?;
        Ok(x.gather_rows_padded(&[m - 1], 1))
    }

    /// One decode step for a batch of sequences: `xs` is `[b, hidden]`
    /// (embedded last tokens), caches/positions parallel arrays.
    /// Returns the new hidden states `[b, hidden]` and appends K/V.
    pub fn decode_step(
        &self,
        xs: &Tensor,
        caches: &mut [&mut SequenceCache],
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        let b = caches.len();
        assert_eq!(xs.shape, vec![b, self.cfg.hidden]);
        let bb = round_up_bucket(b, DECODE_BATCH_BUCKETS);
        if b > *DECODE_BATCH_BUCKETS.last().unwrap() {
            bail!("decode batch {b} exceeds max bucket");
        }
        let c = caches
            .iter()
            .map(|s| s.decode_bucket())
            .max()
            .unwrap_or(CACHE_BUCKETS[0]);

        // Pad inputs and positions to the batch bucket.
        let mut x = Tensor::zeros(vec![bb, self.cfg.hidden]);
        x.data[..b * self.cfg.hidden].copy_from_slice(&xs.data);
        let mut pos = vec![0i32; bb];
        for (i, s) in caches.iter().enumerate() {
            pos[i] = s.len() as i32;
        }

        let kvd = self.cfg.kv_dim();
        let (kvh, hd) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        let x = crate::pipeline::run_layers(
            self,
            cx,
            x,
            b,
            ForwardKind::Decode,
            &mut |layer, x, cx| {
                let refs: Vec<&SequenceCache> = caches.iter().map(|c| &**c).collect();
                // Single-copy gather straight into the padded [bb, c, kv, d]
                // layout (perf iteration 2 — EXPERIMENTS.md §Perf).
                let (mut kcb, mut vcb) = gather_batch_padded(&refs, layer, bb, c, kvd);
                kcb.shape = vec![bb, c, kvh, hd];
                vcb.shape = vec![bb, c, kvh, hd];

                let pos_t = TensorI32::vec(pos.clone());
                let wn = self.attn_weight_names(layer);
                let out = self.execute_mixed(
                    &format!("attn_decode_b{bb}_c{c}"),
                    &[
                        MixedArg::F32(x),
                        MixedArg::F32(&kcb),
                        MixedArg::F32(&vcb),
                        MixedArg::I32(&pos_t),
                        MixedArg::Weight(&wn[0]),
                        MixedArg::Weight(&wn[1]),
                        MixedArg::Weight(&wn[2]),
                        MixedArg::Weight(&wn[3]),
                        MixedArg::Weight(&wn[4]),
                    ],
                )?;
                let (h_attn, k_new, v_new) = (&out[0], &out[1], &out[2]);
                for (i, cache) in caches.iter_mut().enumerate() {
                    cache.layers[layer].append(
                        &k_new.data[i * kvd..(i + 1) * kvd],
                        &v_new.data[i * kvd..(i + 1) * kvd],
                    );
                }

                let attn_dev = cx.policy.attn_device(layer);
                let mut attn_us = cx.hw.attn_decode_us;
                if attn_dev == DeviceKind::Cpu {
                    attn_us *= cx.hw.attn_cpu_factor;
                }
                cx.charge_serial(attn_dev, attn_us);
                Ok(h_attn.clone())
            },
        )?;
        Ok(x.take_rows(b))
    }

    /// Final norm + LM head over `[n, hidden]` hidden states (n <= 16).
    pub fn lm_head(&self, h: &Tensor, cx: &mut ExecContext) -> Result<Tensor> {
        let n = h.shape[0];
        let bucket = round_up_bucket(n, LMHEAD_BUCKETS);
        let mut x = Tensor::zeros(vec![bucket, self.cfg.hidden]);
        x.data[..n * self.cfg.hidden].copy_from_slice(&h.data);
        let out = self.execute_mixed(
            &format!("lm_head_b{bucket}"),
            &[
                MixedArg::F32(&x),
                MixedArg::Weight("final_norm"),
                MixedArg::Weight("lm_head"),
            ],
        )?;
        cx.charge_serial(DeviceKind::Gpu, cx.hw.lm_head_us);
        Ok(out[0].take_rows(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::scheduler::policy::FiddlerPolicy;

    fn runner() -> ModelRunner {
        ModelRunner::load(artifacts_root().join("mixtral-tiny")).expect("make artifacts")
    }

    fn cx(runner: &ModelRunner) -> ExecContext {
        let hw = HardwareConfig::env1();
        let profile = Profile::load(
            runner.cfg.artifact_dir.join("analysis/analysis.json"),
        )
        .expect("analysis profile");
        ExecContext::new(Box::new(FiddlerPolicy::default()), &hw, &runner.cfg, &profile, 0)
    }

    #[test]
    fn prefill_fills_cache_and_advances_clock() {
        let r = runner();
        let mut cx = cx(&r);
        let mut cache = SequenceCache::new(&r.cfg);
        let tokens: Vec<u32> = (1..20).collect();
        let h = r.prefill(&tokens, &mut cache, &mut cx).unwrap();
        assert_eq!(h.shape, vec![1, r.cfg.hidden]);
        assert_eq!(cache.len(), 19);
        assert!(cx.clock.now_us() > 0.0);
        assert!(cx.events.total() > 0);
    }

    #[test]
    fn decode_step_appends_and_matches_shapes() {
        let r = runner();
        let mut cx = cx(&r);
        let mut cache = SequenceCache::new(&r.cfg);
        let tokens: Vec<u32> = (1..9).collect();
        r.prefill(&tokens, &mut cache, &mut cx).unwrap();
        let xs = r.ws.embed_tokens(&[42]);
        let mut caches = [&mut cache];
        let h = r.decode_step(&xs, &mut caches, &mut cx).unwrap();
        assert_eq!(h.shape, vec![1, r.cfg.hidden]);
        assert_eq!(caches[0].len(), 9);
    }

    #[test]
    fn lm_head_shapes() {
        let r = runner();
        let mut cx = cx(&r);
        let h = Tensor::zeros(vec![3, r.cfg.hidden]);
        let logits = r.lm_head(&h, &mut cx).unwrap();
        assert_eq!(logits.shape, vec![3, r.cfg.vocab]);
    }
}
