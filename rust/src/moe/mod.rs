//! MoE model runner: composes the per-op HLO executables into decoder
//! steps, with the execution policy deciding where each expert runs and the
//! simulated substrate accounting the time (DESIGN.md §2/§3).

pub mod topk;

use crate::config::model::{
    CACHE_BUCKETS, DECODE_BATCH_BUCKETS, LMHEAD_BUCKETS, PREFILL_BUCKETS, TOKEN_BUCKETS,
};
use crate::config::{DeviceKind, HardwareConfig, ModelConfig};
use crate::expertcache::ExpertCache;
use crate::hardware::{DeviceTimeline, PcieLink, VirtualClock};
use crate::kvcache::{gather_batch_padded, SequenceCache};
use crate::latency::LatencyModel;
use crate::popularity::Profile;
use crate::runtime::{Runtime, Tensor, TensorI32, WeightStore};
use crate::scheduler::policy::ExecPolicy;
use crate::scheduler::ExpertPlan;
use crate::util::round_up_bucket;
use anyhow::{bail, Result};

/// Counters over expert executions (hit-rate metrics, Fig. 8 analysis).
#[derive(Clone, Debug, Default)]
pub struct ExpertEvents {
    pub resident: u64,
    pub transferred: u64,
    pub cpu: u64,
}

impl ExpertEvents {
    pub fn total(&self) -> u64 {
        self.resident + self.transferred + self.cpu
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.resident as f64 / t as f64
        }
    }
}

/// Mutable execution state threaded through a serving session: the policy,
/// the simulated memory/link/clock, online profiling, and the wall-clock
/// worker pool executing CPU-planned experts.
pub struct ExecContext {
    pub policy: Box<dyn ExecPolicy>,
    pub memory: ExpertCache,
    pub link: PcieLink,
    pub lat: LatencyModel,
    pub hw: HardwareConfig,
    pub timeline: DeviceTimeline,
    pub clock: VirtualClock,
    pub online_profile: Profile,
    pub events: ExpertEvents,
    /// CPU worker threads of the parallel expert executor; 1 = serial
    /// (the pre-parallel engine behavior, bit-for-bit).
    pub threads: usize,
    /// Persistent worker pool for CPU-planned experts (see [`crate::exec`]).
    pub pool: crate::exec::ExecutorPool,
}

impl ExecContext {
    /// Build a context: runs the policy's initialization-time placement
    /// against `profile` (the build-time calibration profile).  Serial
    /// executor (`threads = 1`); see [`ExecContext::with_threads`].
    pub fn new(
        policy: Box<dyn ExecPolicy>,
        hw: &HardwareConfig,
        cfg: &ModelConfig,
        profile: &Profile,
        seed: u64,
    ) -> ExecContext {
        Self::with_threads(policy, hw, cfg, profile, seed, 1)
    }

    /// Build a context with a `threads`-wide parallel expert executor.
    /// When the host kernel is enabled (the only path the pool
    /// accelerates), the latency model switches to the multi-core CPU
    /// curve, so Algorithm 1's crossover reflects the executor's actual
    /// throughput (a faster CPU keeps more experts off the PCIe link).
    /// With the host kernel off the single-core model is kept — the
    /// engine must never plan against a speedup it does not realize.
    pub fn with_threads(
        mut policy: Box<dyn ExecPolicy>,
        hw: &HardwareConfig,
        cfg: &ModelConfig,
        profile: &Profile,
        seed: u64,
        threads: usize,
    ) -> ExecContext {
        let threads = threads.max(1);
        let lat_threads =
            if crate::cpukernel::host_kernel_enabled() { threads } else { 1 };
        // Scale the paper-environment expert capacity to this model's
        // expert count (capacity fractions are what transfer: 56/256 and
        // 125/256 in the paper).
        let frac = hw.gpu_expert_capacity() as f64 / 256.0;
        let capacity = ((cfg.total_experts() as f64 * frac).round() as usize)
            .min(cfg.total_experts());
        let mut memory = ExpertCache::with_capacity(capacity);
        policy.init(&mut memory, profile, seed);
        ExecContext {
            policy,
            memory,
            link: PcieLink::new(hw),
            lat: LatencyModel::from_hardware_threaded(hw, lat_threads),
            hw: hw.clone(),
            timeline: DeviceTimeline::new(),
            clock: VirtualClock::new(),
            online_profile: Profile::new(cfg.n_layers, cfg.n_experts),
            events: ExpertEvents::default(),
            threads,
            pool: crate::exec::ExecutorPool::new(threads),
        }
    }

    /// Charge serial (blocking) work on one device: the clock advances to
    /// its completion.
    fn charge_serial(&mut self, device: DeviceKind, us: f64) {
        let done = self.timeline.schedule(device, self.clock.now_us(), us);
        self.clock.advance_to_us(done);
        self.timeline.reset_to(done);
    }
}

/// One op argument on the fast execution path: per-call activations
/// (uploaded fresh) or a named weight (served from the device cache).
enum MixedArg<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
    Weight(&'a str),
}

/// The model runner (stateless w.r.t. requests; owns runtime + weights).
pub struct ModelRunner {
    pub rt: Runtime,
    pub ws: WeightStore,
    pub cfg: ModelConfig,
    /// Weights pinned as device-resident PJRT buffers, uploaded once on
    /// first use (perf: avoids re-serializing hundreds of KB per op call —
    /// see EXPERIMENTS.md §Perf).  Single-threaded engine => RefCell.
    wbuf: std::cell::RefCell<std::collections::HashMap<String, xla::PjRtBuffer>>,
}

impl ModelRunner {
    pub fn load(artifact_dir: impl Into<std::path::PathBuf>) -> Result<ModelRunner> {
        let dir = artifact_dir.into();
        let rt = Runtime::open(dir.clone())?;
        let ws = WeightStore::load(&dir)?;
        let cfg = ws.config.clone();
        Ok(ModelRunner { rt, ws, cfg, wbuf: Default::default() })
    }

    /// Make sure every named weight tensor has a cached device buffer.
    fn ensure_wbufs(&self, names: &[String]) -> Result<()> {
        let mut map = self.wbuf.borrow_mut();
        for name in names {
            if !map.contains_key(name) {
                let t = self.ws.get(name)?;
                map.insert(name.clone(), self.rt.buffer_from_tensor(t)?);
            }
        }
        Ok(())
    }

    /// Execute `op` with a mix of per-call activation tensors and cached
    /// weight buffers. `args` lists the op parameters in order.
    fn execute_mixed(&self, op: &str, args: &[MixedArg<'_>]) -> Result<Vec<Tensor>> {
        let weight_names: Vec<String> = args
            .iter()
            .filter_map(|a| match a {
                MixedArg::Weight(n) => Some(n.to_string()),
                _ => None,
            })
            .collect();
        self.ensure_wbufs(&weight_names)?;
        // Upload per-call activations.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for a in args {
            match a {
                MixedArg::F32(t) => owned.push(self.rt.buffer_from_tensor(t)?),
                MixedArg::I32(t) => owned.push(self.rt.buffer_from_i32(t)?),
                MixedArg::Weight(_) => {}
            }
        }
        let map = self.wbuf.borrow();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut oi = 0;
        for a in args {
            match a {
                MixedArg::Weight(n) => refs.push(map.get(*n).expect("ensured")),
                _ => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        self.rt.execute_buffers(op, &refs)
    }

    fn attn_weight_names(&self, layer: usize) -> [String; 5] {
        [
            format!("layers.{layer}.attn_norm"),
            format!("layers.{layer}.wq"),
            format!("layers.{layer}.wk"),
            format!("layers.{layer}.wv"),
            format!("layers.{layer}.wo"),
        ]
    }

    /// One MoE (expert) layer over `h` (`[n, hidden]`, rows >= `valid`
    /// are padding): router + top-k + per-expert dispatch per the policy,
    /// combining outputs back into `h` (residual add included).
    pub fn moe_layer(
        &self,
        layer: usize,
        h: &mut Tensor,
        valid: usize,
        cx: &mut ExecContext,
    ) -> Result<()> {
        let n = h.shape[0];
        let gate_op = format!("gate_b{n}");
        let ffn_norm = format!("layers.{layer}.ffn_norm");
        let gate_w = format!("layers.{layer}.gate");
        let out = self.execute_mixed(
            &gate_op,
            &[
                MixedArg::F32(h),
                MixedArg::Weight(&ffn_norm),
                MixedArg::Weight(&gate_w),
            ],
        )?;
        let (probs, xn) = (&out[0], &out[1]);
        self.moe_experts(layer, h, probs, xn, valid, cx)
    }

    /// Expert dispatch half of an MoE layer, with router outputs already
    /// in hand (the fused attention+gate executables produce them — see
    /// EXPERIMENTS.md §Perf, L2 fusion).
    pub fn moe_experts(
        &self,
        layer: usize,
        h: &mut Tensor,
        probs: &Tensor,
        xn: &Tensor,
        valid: usize,
        cx: &mut ExecContext,
    ) -> Result<()> {
        let routing =
            topk::route(&probs.data[..valid * self.cfg.n_experts], valid, self.cfg.n_experts, self.cfg.top_k);
        for (e, &s) in routing.inp_size.iter().enumerate() {
            cx.online_profile.record(layer, e, s as u64);
        }

        let t0 = cx.clock.now_us();
        let plans = cx
            .policy
            .plan_layer(layer, &routing.inp_size, &mut cx.memory, &cx.lat, t0);
        // Speculative policies overlap next-layer weight prefetches with
        // this layer's compute.
        cx.policy
            .post_layer(layer, &routing.inp_size, &mut cx.memory, &cx.lat, t0);

        // Wall-clock execution now mirrors the simulated overlap (§3.3):
        // the worker pool chews CPU-planned experts through the dedicated
        // host kernel (§3.4) while this thread runs the GPU-planned
        // experts' executables, and both join at the layer barrier below.
        // Outputs are stashed per expert and combined afterwards in
        // expert-index order — the same reduction order as the old serial
        // loop, independent of plan, thread count, and completion
        // schedule, so the numerics are unchanged to the bit.
        let host_kernel = crate::cpukernel::host_kernel_enabled();
        let on_pool = |plan: &ExpertPlan| *plan == ExpertPlan::Cpu && host_kernel;

        let mut outputs: Vec<Option<Tensor>> = plans.iter().map(|_| None).collect();
        let mut chunks: Vec<crate::exec::ExpertChunk> = Vec::new();
        for (j, plan) in plans.iter().enumerate() {
            let Some(plan) = plan else { continue };
            if !on_pool(plan) {
                continue;
            }
            let rows = &routing.rows_for[j];
            let s = rows.len();
            outputs[j] = Some(Tensor::zeros(vec![s, self.cfg.hidden]));
            let w1 = self.ws.expert_shared(layer, j, "w1");
            let w3 = self.ws.expert_shared(layer, j, "w3");
            let w2 = self.ws.expert_shared(layer, j, "w2");
            // Large-s (prefill) experts additionally split across workers.
            for (r0, r1) in crate::exec::partition_rows(s, cx.pool.threads()) {
                chunks.push(crate::exec::ExpertChunk {
                    expert: j,
                    row0: r0,
                    // Exact size, no bucket: the host kernel pads nothing.
                    x: xn.gather_rows_padded(&rows[r0..r1], r1 - r0),
                    w1: w1.clone(),
                    w3: w3.clone(),
                    w2: w2.clone(),
                });
            }
        }
        let pending = crate::exec::run_expert_chunks(&cx.pool, chunks);

        // GPU-planned experts (and the PJRT fallback for CPU plans when the
        // host kernel is off) execute on this thread, overlapping the pool.
        for (j, plan) in plans.iter().enumerate() {
            let Some(plan) = plan else { continue };
            if on_pool(plan) {
                continue;
            }
            let rows = &routing.rows_for[j];
            let s = rows.len();
            let bucket = round_up_bucket(s, TOKEN_BUCKETS);
            let xe = xn.gather_rows_padded(rows, bucket);
            let w1 = format!("layers.{layer}.experts.{j}.w1");
            let w3 = format!("layers.{layer}.experts.{j}.w3");
            let w2 = format!("layers.{layer}.experts.{j}.w2");
            let mut expert_out = self.execute_mixed(
                &format!("expert_b{bucket}"),
                &[
                    MixedArg::F32(&xe),
                    MixedArg::Weight(&w1),
                    MixedArg::Weight(&w3),
                    MixedArg::Weight(&w2),
                ],
            )?;
            outputs[j] = Some(expert_out.swap_remove(0));
        }

        // Layer barrier: join the pool, scatter chunk outputs into the
        // per-expert buffers (positional — order-free).
        let hidden = self.cfg.hidden;
        for c in pending.wait() {
            let dst = outputs[c.expert].as_mut().expect("chunk for unplanned expert");
            dst.data[c.row0 * hidden..c.row0 * hidden + c.out.data.len()]
                .copy_from_slice(&c.out.data);
        }

        // Combine + simulated accounting, in expert-index order.
        for (j, plan) in plans.iter().enumerate() {
            let Some(plan) = plan else { continue };
            let rows = &routing.rows_for[j];
            let s = rows.len();
            let out = outputs[j].as_ref().expect("planned expert without output");
            h.axpy_rows(rows, &routing.weights_for[j], out);

            // Account simulated time + link/memory bookkeeping.
            let cost = cx.policy.expert_cost_us(*plan, s, &cx.lat);
            cx.timeline.schedule(plan.device(), t0, cost);
            match plan {
                ExpertPlan::GpuResident => cx.events.resident += 1,
                ExpertPlan::GpuTransfer => {
                    cx.events.transferred += 1;
                    cx.link.weight_transfer();
                }
                ExpertPlan::Cpu => {
                    cx.events.cpu += 1;
                    cx.link.activation_transfer(s); // out
                    cx.link.activation_transfer(s); // back
                }
            }
        }
        // Layer boundary: expert outputs must be combined before the next
        // layer — both device queues join.
        let done = cx.timeline.barrier();
        cx.clock.advance_to_us(done);
        Ok(())
    }

    /// Prefill a prompt into `cache`; returns the last token's hidden state
    /// (`[1, hidden]`).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut SequenceCache,
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty prompt");
        }
        let max_bucket = *PREFILL_BUCKETS.last().unwrap();
        if n > max_bucket {
            bail!("prompt of {n} tokens exceeds max prefill bucket {max_bucket}");
        }
        let s = round_up_bucket(n, PREFILL_BUCKETS);
        let mut x = Tensor::zeros(vec![s, self.cfg.hidden]);
        let emb = self.ws.embed_tokens(tokens);
        x.data[..n * self.cfg.hidden].copy_from_slice(&emb.data);

        for layer in 0..self.cfg.n_layers {
            // Attention, then router (separate executables: the fused
            // attn+gate variant measured SLOWER under XLA-CPU — see the
            // perf_ab_fused ablation and EXPERIMENTS.md §Perf).
            let valid = TensorI32::scalar(n as i32);
            let wn = self.attn_weight_names(layer);
            let out = self.execute_mixed(
                &format!("attn_prefill_s{s}"),
                &[
                    MixedArg::F32(&x),
                    MixedArg::I32(&valid),
                    MixedArg::Weight(&wn[0]),
                    MixedArg::Weight(&wn[1]),
                    MixedArg::Weight(&wn[2]),
                    MixedArg::Weight(&wn[3]),
                    MixedArg::Weight(&wn[4]),
                ],
            )?;
            let (h_attn, k, v) = (&out[0], &out[1], &out[2]);
            let kvd = self.cfg.kv_dim();
            cache.layers[layer].extend(n, &k.data[..n * kvd], &v.data[..n * kvd]);

            let attn_dev = cx.policy.attn_device(layer);
            let mut attn_us = cx.hw.attn_prefill_per_token_us * n as f64;
            if attn_dev == DeviceKind::Cpu {
                attn_us *= cx.hw.attn_cpu_factor;
            }
            cx.charge_serial(attn_dev, attn_us);

            x = h_attn.clone();
            self.moe_layer(layer, &mut x, n, cx)?;
        }
        // Last valid row only.
        Ok(x.gather_rows_padded(&[n - 1], 1))
    }

    /// Continue a prefill: process `tokens` — the next chunk of a prompt
    /// whose preceding prefix is already in `cache` — and return the
    /// chunk's last hidden state (`[1, hidden]`).  With an empty cache
    /// this is exactly [`ModelRunner::prefill`].
    ///
    /// The AOT op set has no cache-consuming chunk-attention executable,
    /// so a continuation chunk's attention runs token-by-token through the
    /// decode executable (numerics within kernel tolerance of the
    /// monolithic prefill executable), while the MoE half runs
    /// chunk-batched: routing and expert dispatch see all of the chunk's
    /// rows at once, preserving the cross-token expert batching the
    /// paper's CPU path relies on.  Virtual time charges attention at the
    /// prefill per-token rate (the simulated testbed's chunk-attention
    /// kernel) and the experts through the normal per-layer accounting, so
    /// chunked prefill pays the honest price of chunking — one expert-base
    /// amortization per chunk instead of one per prompt.
    pub fn prefill_chunk(
        &self,
        tokens: &[u32],
        cache: &mut SequenceCache,
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        if cache.is_empty() {
            return self.prefill(tokens, cache, cx);
        }
        let m = tokens.len();
        if m == 0 {
            bail!("empty prefill chunk");
        }
        let max_c = *CACHE_BUCKETS.last().unwrap();
        if cache.len() + m > max_c {
            bail!("sequence of {} tokens exceeds max cache bucket {max_c}", cache.len() + m);
        }
        // Gate executables exist for every power-of-two token bucket.
        let bucket = round_up_bucket(m, TOKEN_BUCKETS);
        let mut x = Tensor::zeros(vec![bucket, self.cfg.hidden]);
        let emb = self.ws.embed_tokens(tokens);
        x.data[..m * self.cfg.hidden].copy_from_slice(&emb.data);

        let kvd = self.cfg.kv_dim();
        let (kvh, hd) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        for layer in 0..self.cfg.n_layers {
            let wn = self.attn_weight_names(layer);
            let mut h_attn = Tensor::zeros(vec![bucket, self.cfg.hidden]);
            for t in 0..m {
                let pos = cache.layers[layer].len;
                let c = round_up_bucket(pos + 1, CACHE_BUCKETS);
                let (mut kcb, mut vcb) = {
                    let seq: &SequenceCache = cache;
                    gather_batch_padded(&[seq], layer, 1, c, kvd)
                };
                kcb.shape = vec![1, c, kvh, hd];
                vcb.shape = vec![1, c, kvh, hd];
                let xt = x.gather_rows_padded(&[t], 1);
                let pos_t = TensorI32::vec(vec![pos as i32]);
                let out = self.execute_mixed(
                    &format!("attn_decode_b1_c{c}"),
                    &[
                        MixedArg::F32(&xt),
                        MixedArg::F32(&kcb),
                        MixedArg::F32(&vcb),
                        MixedArg::I32(&pos_t),
                        MixedArg::Weight(&wn[0]),
                        MixedArg::Weight(&wn[1]),
                        MixedArg::Weight(&wn[2]),
                        MixedArg::Weight(&wn[3]),
                        MixedArg::Weight(&wn[4]),
                    ],
                )?;
                h_attn.row_mut(t).copy_from_slice(out[0].row(0));
                cache.layers[layer].append(&out[1].data[..kvd], &out[2].data[..kvd]);
            }

            let attn_dev = cx.policy.attn_device(layer);
            let mut attn_us = cx.hw.attn_prefill_per_token_us * m as f64;
            if attn_dev == DeviceKind::Cpu {
                attn_us *= cx.hw.attn_cpu_factor;
            }
            cx.charge_serial(attn_dev, attn_us);

            x = h_attn;
            self.moe_layer(layer, &mut x, m, cx)?;
        }
        Ok(x.gather_rows_padded(&[m - 1], 1))
    }

    /// One decode step for a batch of sequences: `xs` is `[b, hidden]`
    /// (embedded last tokens), caches/positions parallel arrays.
    /// Returns the new hidden states `[b, hidden]` and appends K/V.
    pub fn decode_step(
        &self,
        xs: &Tensor,
        caches: &mut [&mut SequenceCache],
        cx: &mut ExecContext,
    ) -> Result<Tensor> {
        let b = caches.len();
        assert_eq!(xs.shape, vec![b, self.cfg.hidden]);
        let bb = round_up_bucket(b, DECODE_BATCH_BUCKETS);
        if b > *DECODE_BATCH_BUCKETS.last().unwrap() {
            bail!("decode batch {b} exceeds max bucket");
        }
        let c = caches
            .iter()
            .map(|s| s.decode_bucket())
            .max()
            .unwrap_or(CACHE_BUCKETS[0]);

        // Pad inputs and positions to the batch bucket.
        let mut x = Tensor::zeros(vec![bb, self.cfg.hidden]);
        x.data[..b * self.cfg.hidden].copy_from_slice(&xs.data);
        let mut pos = vec![0i32; bb];
        for (i, s) in caches.iter().enumerate() {
            pos[i] = s.len() as i32;
        }

        let kvd = self.cfg.kv_dim();
        let (kvh, hd) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        for layer in 0..self.cfg.n_layers {
            let refs: Vec<&SequenceCache> = caches.iter().map(|c| &**c).collect();
            // Single-copy gather straight into the padded [bb, c, kv, d]
            // layout (perf iteration 2 — EXPERIMENTS.md §Perf).
            let (mut kcb, mut vcb) = gather_batch_padded(&refs, layer, bb, c, kvd);
            kcb.shape = vec![bb, c, kvh, hd];
            vcb.shape = vec![bb, c, kvh, hd];

            let pos_t = TensorI32::vec(pos.clone());
            let wn = self.attn_weight_names(layer);
            let out = self.execute_mixed(
                &format!("attn_decode_b{bb}_c{c}"),
                &[
                    MixedArg::F32(&x),
                    MixedArg::F32(&kcb),
                    MixedArg::F32(&vcb),
                    MixedArg::I32(&pos_t),
                    MixedArg::Weight(&wn[0]),
                    MixedArg::Weight(&wn[1]),
                    MixedArg::Weight(&wn[2]),
                    MixedArg::Weight(&wn[3]),
                    MixedArg::Weight(&wn[4]),
                ],
            )?;
            let (h_attn, k_new, v_new) = (&out[0], &out[1], &out[2]);
            for (i, cache) in caches.iter_mut().enumerate() {
                cache.layers[layer]
                    .append(&k_new.data[i * kvd..(i + 1) * kvd], &v_new.data[i * kvd..(i + 1) * kvd]);
            }

            let attn_dev = cx.policy.attn_device(layer);
            let mut attn_us = cx.hw.attn_decode_us;
            if attn_dev == DeviceKind::Cpu {
                attn_us *= cx.hw.attn_cpu_factor;
            }
            cx.charge_serial(attn_dev, attn_us);

            x = h_attn.clone();
            self.moe_layer(layer, &mut x, b, cx)?;
        }
        Ok(x.take_rows(b))
    }

    /// Final norm + LM head over `[n, hidden]` hidden states (n <= 16).
    pub fn lm_head(&self, h: &Tensor, cx: &mut ExecContext) -> Result<Tensor> {
        let n = h.shape[0];
        let bucket = round_up_bucket(n, LMHEAD_BUCKETS);
        let mut x = Tensor::zeros(vec![bucket, self.cfg.hidden]);
        x.data[..n * self.cfg.hidden].copy_from_slice(&h.data);
        let out = self.execute_mixed(
            &format!("lm_head_b{bucket}"),
            &[
                MixedArg::F32(&x),
                MixedArg::Weight("final_norm"),
                MixedArg::Weight("lm_head"),
            ],
        )?;
        cx.charge_serial(DeviceKind::Gpu, cx.hw.lm_head_us);
        Ok(out[0].take_rows(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::scheduler::policy::FiddlerPolicy;

    fn runner() -> ModelRunner {
        ModelRunner::load(artifacts_root().join("mixtral-tiny")).expect("make artifacts")
    }

    fn cx(runner: &ModelRunner) -> ExecContext {
        let hw = HardwareConfig::env1();
        let profile = Profile::load(
            runner.cfg.artifact_dir.join("analysis/analysis.json"),
        )
        .expect("analysis profile");
        ExecContext::new(Box::new(FiddlerPolicy::default()), &hw, &runner.cfg, &profile, 0)
    }

    #[test]
    fn prefill_fills_cache_and_advances_clock() {
        let r = runner();
        let mut cx = cx(&r);
        let mut cache = SequenceCache::new(&r.cfg);
        let tokens: Vec<u32> = (1..20).collect();
        let h = r.prefill(&tokens, &mut cache, &mut cx).unwrap();
        assert_eq!(h.shape, vec![1, r.cfg.hidden]);
        assert_eq!(cache.len(), 19);
        assert!(cx.clock.now_us() > 0.0);
        assert!(cx.events.total() > 0);
    }

    #[test]
    fn decode_step_appends_and_matches_shapes() {
        let r = runner();
        let mut cx = cx(&r);
        let mut cache = SequenceCache::new(&r.cfg);
        let tokens: Vec<u32> = (1..9).collect();
        r.prefill(&tokens, &mut cache, &mut cx).unwrap();
        let xs = r.ws.embed_tokens(&[42]);
        let mut caches = [&mut cache];
        let h = r.decode_step(&xs, &mut caches, &mut cx).unwrap();
        assert_eq!(h.shape, vec![1, r.cfg.hidden]);
        assert_eq!(caches[0].len(), 9);
    }

    #[test]
    fn lm_head_shapes() {
        let r = runner();
        let mut cx = cx(&r);
        let h = Tensor::zeros(vec![3, r.cfg.hidden]);
        let logits = r.lm_head(&h, &mut cx).unwrap();
        assert_eq!(logits.shape, vec![3, r.cfg.vocab]);
    }
}
