//! Host-side top-k expert selection (the gating decision itself is tiny;
//! the paper's system reads the router output on the host anyway to learn
//! per-expert input sizes — §3.3 "Execution").
//!
//! Semantics match `jax.lax.top_k` + renormalization in
//! `python/compile/model.reference_forward`: descending by probability,
//! ties broken by the lower expert index, weights renormalized to sum 1.

use crate::util::rank_key;

/// Returns (expert ids, renormalized weights), both length k.
pub fn top_k(probs: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    assert!(k > 0 && k <= probs.len(), "top_k: k={k} over {} experts", probs.len());
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    // Stable sort by descending prob; stability gives jax's tie-by-index.
    // rank_key: a NaN router prob ranks LAST (total_cmp alone would rank
    // positive NaN first and poison the renormalized weights).
    idx.sort_by(|&a, &b| rank_key(probs[b]).total_cmp(&rank_key(probs[a])));
    idx.truncate(k);
    let total: f32 = idx.iter().map(|&i| probs[i]).sum();
    let weights = idx
        .iter()
        .map(|&i| if total > 0.0 { probs[i] / total } else { 1.0 / k as f32 })
        .collect();
    (idx, weights)
}

/// Per-expert routing table for a batch of rows: `rows_for[e]` lists the
/// row indices routed to expert `e` (ascending — the gather order),
/// `weights_for[e]` the matching combine weights, and `inp_size[e]` the
/// counts — exactly Algorithm 1's `inp_size` array.
///
/// Rows and weights are split into parallel arrays (rather than one
/// `Vec<(usize, f32)>`) so the engine can hand them straight to
/// `Tensor::gather_rows_padded` / `Tensor::axpy_rows` without rebuilding a
/// `rows` and a `weights` Vec per expert per layer in the hot loop.
#[derive(Clone, Debug)]
pub struct Routing {
    pub rows_for: Vec<Vec<usize>>,
    pub weights_for: Vec<Vec<f32>>,
    pub inp_size: Vec<usize>,
}

/// Route `n_rows` rows of gate probabilities (`[n_rows, n_experts]` flat)
/// to their top-k experts.
pub fn route(probs: &[f32], n_rows: usize, n_experts: usize, k: usize) -> Routing {
    assert_eq!(probs.len(), n_rows * n_experts);
    let mut rows_for = vec![Vec::new(); n_experts];
    let mut weights_for = vec![Vec::new(); n_experts];
    for r in 0..n_rows {
        let row = &probs[r * n_experts..(r + 1) * n_experts];
        let (ids, ws) = top_k(row, k);
        for (e, w) in ids.into_iter().zip(ws) {
            rows_for[e].push(r);
            weights_for[e].push(w);
        }
    }
    let inp_size = rows_for.iter().map(|v| v.len()).collect();
    Routing { rows_for, weights_for, inp_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    #[test]
    fn picks_largest_and_renormalizes() {
        let (ids, ws) = top_k(&[0.1, 0.6, 0.3], 2);
        assert_eq!(ids, vec![1, 2]);
        assert!((ws[0] - 0.6 / 0.9).abs() < 1e-6);
        assert!((ws[1] - 0.3 / 0.9).abs() < 1e-6);
    }

    #[test]
    fn ties_break_by_lower_index() {
        let (ids, _) = top_k(&[0.25, 0.25, 0.25, 0.25], 2);
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn nan_prob_ranks_last_and_never_wins() {
        // Regression: partial_cmp(..).unwrap() panicked; raw total_cmp let
        // a positive NaN WIN (NaN > +inf in total order), poisoning every
        // renormalized weight.  NaN must rank last.
        let (ids, ws) = top_k(&[0.1, f32::NAN, 0.6], 2);
        assert_eq!(ids, vec![2, 0]);
        assert!(ws.iter().all(|w| w.is_finite()), "{ws:?}");
        // Only selected when nothing finite is left to fill k.
        let (ids, _) = top_k(&[f32::NAN, 0.4], 2);
        assert_eq!(ids, vec![1, 0]);
    }

    #[test]
    fn weights_sum_to_one_property() {
        check("topk weights normalized", 256, |g: &mut Gen| {
            let e = g.usize_in(2..17);
            let k = g.usize_in(1..e + 1);
            let probs = g.vec_f32(e..e + 1, 0.0, 1.0);
            let (ids, ws) = top_k(&probs, k);
            assert_eq!(ids.len(), k);
            let mut uniq = ids.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), k, "duplicate experts");
            let sum: f32 = ws.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "weights sum {sum}");
            // Selected experts have probs >= every unselected one.
            let min_sel = ids.iter().map(|&i| probs[i]).fold(f32::INFINITY, f32::min);
            for (i, &p) in probs.iter().enumerate() {
                if !ids.contains(&i) {
                    assert!(p <= min_sel + 1e-6);
                }
            }
        });
    }

    #[test]
    fn route_conserves_assignments_property() {
        check("routing conservation", 128, |g: &mut Gen| {
            let e = g.usize_in(2..12);
            let k = g.usize_in(1..e.min(4) + 1);
            let n = g.usize_in(1..50);
            let probs = g.vec_f32(n * e..n * e + 1, 0.001, 1.0);
            let r = route(&probs, n, e, k);
            // Every row appears exactly k times across experts.
            let total: usize = r.inp_size.iter().sum();
            assert_eq!(total, n * k);
            let mut per_row = vec![0usize; n];
            for (rows, weights) in r.rows_for.iter().zip(&r.weights_for) {
                assert_eq!(rows.len(), weights.len(), "parallel arrays diverge");
                for (&row, &w) in rows.iter().zip(weights) {
                    per_row[row] += 1;
                    assert!(w > 0.0 && w <= 1.0 + 1e-6);
                }
                // Gather order: ascending row indices.
                assert!(rows.windows(2).all(|p| p[0] < p[1]), "rows not ascending");
            }
            assert!(per_row.iter().all(|&c| c == k));
            // inp_size consistent with rows_for.
            for (lst, &sz) in r.rows_for.iter().zip(&r.inp_size) {
                assert_eq!(lst.len(), sz);
            }
        });
    }
}
