//! Int8 expert-weight quantization substrate.
//!
//! The paper (§2.2) treats compression as orthogonal to Fiddler and notes
//! it "could be applied on top".  This module demonstrates that claim:
//! expert matrices are stored symmetric-per-column int8 (exported by
//! `python/compile/export_weights.quantize_int8`), halving—vs the bf16
//! baseline—the PCIe transfer volume and the DRAM pass of the CPU kernel,
//! and doubling the GPU expert capacity.  [`HardwareConfig::quantized`]
//! (constructed via [`quantized_hw`]) feeds those effects into the latency
//! model; `examples/ablation_quant.rs` measures the end-to-end impact and
//! the quantization error.

use crate::config::HardwareConfig;
use crate::runtime::Tensor;
use crate::util::json::{self};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// An int8 per-column-quantized 2-D weight.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    pub shape: Vec<usize>, // [rows, cols]
    pub data: Vec<i8>,
    pub scales: Vec<f32>, // one per column
}

impl QuantTensor {
    /// Quantize an f32 tensor (mirror of the Python exporter; used in
    /// tests and for on-the-fly quantization of arbitrary tensors).
    pub fn quantize(t: &Tensor) -> QuantTensor {
        assert_eq!(t.rank(), 2, "quantize expects rank-2, got {:?}", t.shape);
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let mut scales = vec![1.0f32; cols];
        for c in 0..cols {
            let mut amax = 0.0f32;
            for r in 0..rows {
                amax = amax.max(t.data[r * cols + c].abs());
            }
            scales[c] = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        }
        let mut data = vec![0i8; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let q = (t.data[r * cols + c] / scales[c]).round();
                data[r * cols + c] = q.clamp(-127.0, 127.0) as i8;
            }
        }
        QuantTensor { shape: t.shape.clone(), data, scales }
    }

    pub fn dequantize(&self) -> Tensor {
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(self.shape.clone());
        for r in 0..rows {
            for c in 0..cols {
                out.data[r * cols + c] = self.data[r * cols + c] as f32 * self.scales[c];
            }
        }
        out
    }

    /// Worst-case absolute error of dequantization for column `c`:
    /// half a quantization step.
    pub fn max_abs_err(&self, c: usize) -> f32 {
        0.5 * self.scales[c]
    }

    /// Error statistics of the whole tensor in one pass over the scales.
    /// Hot paths (the error-budget check runs per expert per layer) must
    /// NOT call [`QuantTensor::max_abs_err`] per column per decision —
    /// [`QuantWeightStore`] precomputes these at load time instead.
    pub fn error_stats(&self) -> ExpertErrorStats {
        let (mut max, mut sum) = (0.0f32, 0.0f64);
        for &s in &self.scales {
            let e = 0.5 * s;
            max = max.max(e);
            sum += e as f64;
        }
        let mean = if self.scales.is_empty() { 0.0 } else { (sum / self.scales.len() as f64) as f32 };
        ExpertErrorStats { max_abs_err: max, mean_abs_err: mean }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// Per-expert dequantization error summary, aggregated over the expert's
/// three FFN matrices.  Computed ONCE at [`QuantWeightStore::load`] so the
/// scheduler's error-budget check is a map lookup, not a per-call sweep
/// over every column's `max_abs_err`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExpertErrorStats {
    /// Worst-case absolute dequant error of any weight (half the largest
    /// quantization step).
    pub max_abs_err: f32,
    /// Mean half-step error across columns — the budget-accounting term
    /// (worst case compounds too pessimistically across layers).
    pub mean_abs_err: f32,
}

/// Deterministic synthetic per-expert error estimate for hosts without
/// quantized artifacts (the virtual-time sim and the cache-policy paths):
/// the half-step of a symmetric `bits`-wide grid over unit-scale weights,
/// jittered ±25% by an FNV-1a hash of the expert id so experts rank
/// differently under an error budget.  Pure function of its arguments —
/// record→replay and cross-thread bit-identity depend on that.
pub fn synthetic_expert_error(layer: usize, expert: usize, bits: u32) -> f64 {
    let levels = (1u64 << (bits.clamp(2, 15) - 1)) - 1;
    let base = 0.5 / levels as f64;
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for v in [layer as u64, expert as u64] {
        h = (h ^ v).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let jitter = 0.75 + 0.5 * (h % 1024) as f64 / 1023.0;
    base * jitter
}

/// All quantized expert tensors of one model.
pub struct QuantWeightStore {
    tensors: BTreeMap<String, QuantTensor>,
    /// Per-expert error stats, precomputed at load (keyed `(layer, expert)`).
    expert_err: BTreeMap<(usize, usize), ExpertErrorStats>,
}

/// Parse `layers.{l}.experts.{e}.{name}` into `(l, e)`.
fn expert_key(name: &str) -> Option<(usize, usize)> {
    let mut parts = name.split('.');
    (parts.next()? == "layers").then_some(())?;
    let l = parts.next()?.parse().ok()?;
    (parts.next()? == "experts").then_some(())?;
    let e = parts.next()?.parse().ok()?;
    Some((l, e))
}

impl QuantWeightStore {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<QuantWeightStore> {
        let dir = artifact_dir.as_ref();
        let manifest = json::load(dir.join("weights_manifest.json"))?;
        let mut tensors = BTreeMap::new();
        for (name, desc) in manifest.get("quant_tensors")?.as_obj()? {
            let shape = desc.get("shape")?.as_usize_vec()?;
            let n: usize = shape.iter().product();
            let qpath = dir.join(desc.get("q_file")?.as_str()?);
            let qbytes = std::fs::read(&qpath)
                .with_context(|| format!("reading {}", qpath.display()))?;
            anyhow::ensure!(qbytes.len() == n, "quant tensor {name} size mismatch");
            let spath = dir.join(desc.get("scale_file")?.as_str()?);
            let sbytes = std::fs::read(&spath)
                .with_context(|| format!("reading {}", spath.display()))?;
            anyhow::ensure!(sbytes.len() == 4 * shape[1], "scales {name} size mismatch");
            let scales = sbytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(
                name.clone(),
                QuantTensor {
                    shape,
                    data: qbytes.into_iter().map(|b| b as i8).collect(),
                    scales,
                },
            );
        }
        anyhow::ensure!(!tensors.is_empty(), "no quant_tensors in manifest");
        // Fold per-tensor stats into per-expert stats ONCE, here: the
        // error-budget check consults these on every quantized hit, and a
        // per-call scan over every column's `max_abs_err` was measurable
        // on the plan hot path.
        let mut expert_err: BTreeMap<(usize, usize), ExpertErrorStats> = BTreeMap::new();
        let mut cols: BTreeMap<(usize, usize), (f64, usize)> = BTreeMap::new();
        for (name, t) in &tensors {
            let Some(key) = expert_key(name) else { continue };
            let s = t.error_stats();
            let agg = expert_err.entry(key).or_default();
            agg.max_abs_err = agg.max_abs_err.max(s.max_abs_err);
            let c = cols.entry(key).or_insert((0.0, 0));
            c.0 += s.mean_abs_err as f64 * t.scales.len() as f64;
            c.1 += t.scales.len();
        }
        for (key, (sum, n)) in cols {
            if n > 0 {
                expert_err.get_mut(&key).expect("stats entry").mean_abs_err =
                    (sum / n as f64) as f32;
            }
        }
        Ok(QuantWeightStore { tensors, expert_err })
    }

    /// Precomputed error stats for one expert — the error-budget check's
    /// data source.  `None` when the store has no tensors for that expert.
    pub fn expert_error(&self, layer: usize, expert: usize) -> Option<ExpertErrorStats> {
        self.expert_err.get(&(layer, expert)).copied()
    }

    pub fn get(&self, name: &str) -> Result<&QuantTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing quant tensor {name:?}"))
    }

    pub fn expert(&self, layer: usize, expert: usize, name: &str) -> Result<&QuantTensor> {
        self.get(&format!("layers.{layer}.experts.{expert}.{name}"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// Host expert FFN over quantized weights: dequantize into scratch, run
/// the blocked f32 kernel (the dequant pass is one linear sweep — tiny
/// next to the GEMM, matching real int8 CPU paths that upcast per tile).
pub fn expert_ffn_host_q8(
    x: &Tensor,
    w1: &QuantTensor,
    w3: &QuantTensor,
    w2: &QuantTensor,
) -> Tensor {
    crate::cpukernel::expert_ffn_host(x, &w1.dequantize(), &w3.dequantize(), &w2.dequantize())
}

/// Hardware environment with int8 expert weights: half the transfer bytes
/// (transfer_lat halves), half the CPU weight-read floor, double the
/// expert capacity.
pub fn quantized_hw(hw: &HardwareConfig) -> HardwareConfig {
    let mut q = hw.clone();
    q.name = format!("{}-int8", hw.name);
    q.expert_weight_bytes = hw.expert_weight_bytes / 2;
    q.cpu_expert_base_us = hw.cpu_expert_base_us / 2.0;
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::runtime::WeightStore;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: (0..n).map(|_| rng.normal() as f32 * scale).collect() }
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(0);
        let t = rand_t(&mut rng, vec![32, 16], 0.3);
        let q = QuantTensor::quantize(&t);
        let d = q.dequantize();
        for c in 0..16 {
            for r in 0..32 {
                let err = (t.data[r * 16 + c] - d.data[r * 16 + c]).abs();
                assert!(err <= q.max_abs_err(c) + 1e-6, "err {err} at ({r},{c})");
            }
        }
    }

    #[test]
    fn quantize_preserves_extremes() {
        let t = Tensor::new(vec![2, 1], vec![-1.27, 1.27]).unwrap();
        let q = QuantTensor::quantize(&t);
        assert_eq!(q.data, vec![-127, 127]);
        let d = q.dequantize();
        assert!((d.data[1] - 1.27).abs() < 1e-6);
    }

    #[test]
    fn loads_python_exported_quants_and_matches_f32() {
        let dir = artifacts_root().join("mixtral-tiny");
        let qs = QuantWeightStore::load(&dir).expect("make artifacts first");
        let ws = WeightStore::load(&dir).unwrap();
        // 3 tensors per expert
        assert_eq!(qs.len(), ws.config.total_experts() * 3);
        let w1 = ws.expert(0, 0, "w1");
        let q1 = qs.expert(0, 0, "w1").unwrap();
        assert_eq!(q1.shape, w1.shape);
        let deq = q1.dequantize();
        // Max dequant error bounded by half a step of the largest column.
        let max_scale = q1.scales.iter().cloned().fold(0.0f32, f32::max);
        assert!(deq.max_abs_diff(w1) <= 0.5 * max_scale + 1e-6);
    }

    #[test]
    fn q8_expert_kernel_close_to_f32() {
        let dir = artifacts_root().join("mixtral-tiny");
        let qs = QuantWeightStore::load(&dir).unwrap();
        let ws = WeightStore::load(&dir).unwrap();
        let mut rng = Rng::new(5);
        let x = rand_t(&mut rng, vec![3, ws.config.hidden], 0.5);
        let f32_out = crate::cpukernel::expert_ffn_host(
            &x,
            ws.expert(2, 1, "w1"),
            ws.expert(2, 1, "w3"),
            ws.expert(2, 1, "w2"),
        );
        let q8_out = expert_ffn_host_q8(
            &x,
            qs.expert(2, 1, "w1").unwrap(),
            qs.expert(2, 1, "w3").unwrap(),
            qs.expert(2, 1, "w2").unwrap(),
        );
        let rel = q8_out.max_abs_diff(&f32_out)
            / f32_out.data.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        assert!(rel < 0.05, "relative quant error too large: {rel}");
    }

    #[test]
    fn error_stats_match_per_column_scan() {
        let mut rng = Rng::new(3);
        let t = rand_t(&mut rng, vec![16, 8], 0.4);
        let q = QuantTensor::quantize(&t);
        let s = q.error_stats();
        let max_scan = (0..8).map(|c| q.max_abs_err(c)).fold(0.0f32, f32::max);
        let mean_scan = (0..8).map(|c| q.max_abs_err(c)).sum::<f32>() / 8.0;
        assert_eq!(s.max_abs_err, max_scan);
        assert!((s.mean_abs_err - mean_scan).abs() < 1e-6);
        assert!(s.mean_abs_err <= s.max_abs_err);
    }

    #[test]
    fn store_precomputes_expert_error() {
        let dir = artifacts_root().join("mixtral-tiny");
        let qs = QuantWeightStore::load(&dir).expect("make artifacts first");
        let stats = qs.expert_error(0, 0).expect("expert (0,0) has stats");
        // Must equal the on-the-fly aggregation over the three matrices.
        let mut max = 0.0f32;
        for name in ["w1", "w3", "w2"] {
            max = max.max(qs.expert(0, 0, name).unwrap().error_stats().max_abs_err);
        }
        assert_eq!(stats.max_abs_err, max);
        assert!(stats.mean_abs_err > 0.0 && stats.mean_abs_err <= stats.max_abs_err);
        assert!(qs.expert_error(999, 0).is_none());
    }

    #[test]
    fn expert_key_parses_manifest_names() {
        assert_eq!(expert_key("layers.2.experts.7.w1"), Some((2, 7)));
        assert_eq!(expert_key("layers.0.experts.0.w2"), Some((0, 0)));
        assert_eq!(expert_key("embed.weight"), None);
        assert_eq!(expert_key("layers.x.experts.0.w1"), None);
    }

    #[test]
    fn synthetic_error_is_deterministic_and_scales_with_bits() {
        assert_eq!(synthetic_expert_error(1, 2, 8), synthetic_expert_error(1, 2, 8));
        // Coarser grids err more: Q4 step is ~18x the Q8 step.
        assert!(synthetic_expert_error(0, 0, 4) > 2.0 * synthetic_expert_error(0, 0, 8));
        // Jitter stays within ±25% of the half-step base.
        for e in 0..16 {
            let v = synthetic_expert_error(0, e, 8);
            let base = 0.5 / 127.0;
            assert!(v >= 0.75 * base && v <= 1.25 * base, "{v}");
        }
        // Distinct experts rank differently (the budget orders them).
        assert_ne!(synthetic_expert_error(0, 1, 8), synthetic_expert_error(0, 2, 8));
    }

    #[test]
    fn quantized_hw_doubles_capacity_halves_transfer() {
        let hw = HardwareConfig::env1();
        let q = quantized_hw(&hw);
        assert_eq!(q.gpu_expert_capacity(), 113); // vs 56 fp16
        assert!(q.gpu_expert_capacity() >= 2 * hw.gpu_expert_capacity());
        assert!(q.weight_transfer_us() < 0.55 * hw.weight_transfer_us());
    }
}
