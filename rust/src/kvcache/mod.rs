//! KV-cache manager.
//!
//! Each sequence owns one cache per layer, padded to the AOT cache buckets
//! (the decode attention executables take `[B, C, kv, d]` with slots
//! `>= pos` required to be zero).  Supports growth across buckets, beam
//! forking (copy-on-fork), and batched gathering into the padded batch
//! tensors the executables consume.

use crate::config::model::CACHE_BUCKETS;
use crate::config::ModelConfig;
use crate::runtime::Tensor;
use crate::util::round_up_bucket;

/// KV cache of ONE sequence for ONE layer: k and v, each `[cap, kv, d]`
/// row-major, zero beyond `len`.
#[derive(Clone, Debug)]
pub struct LayerCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub cap: usize,
    pub len: usize,
    kv_dim: usize, // kv_heads * head_dim
}

impl LayerCache {
    fn new(kv_dim: usize) -> LayerCache {
        let cap = CACHE_BUCKETS[0];
        LayerCache { k: vec![0.0; cap * kv_dim], v: vec![0.0; cap * kv_dim], cap, len: 0, kv_dim }
    }

    fn ensure_cap(&mut self, needed: usize) {
        if needed <= self.cap {
            return;
        }
        let new_cap = round_up_bucket(needed, CACHE_BUCKETS);
        assert!(new_cap >= needed, "sequence exceeds max cache bucket");
        self.k.resize(new_cap * self.kv_dim, 0.0);
        self.v.resize(new_cap * self.kv_dim, 0.0);
        self.cap = new_cap;
    }

    /// Append one token's K/V (`[kv_dim]` each).
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        self.ensure_cap(self.len + 1);
        let off = self.len * self.kv_dim;
        self.k[off..off + self.kv_dim].copy_from_slice(k);
        self.v[off..off + self.kv_dim].copy_from_slice(v);
        self.len += 1;
    }

    /// Bulk-append `n` tokens from `[n, kv_dim]` buffers (prefill).
    pub fn extend(&mut self, n: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), n * self.kv_dim);
        self.ensure_cap(self.len + n);
        let off = self.len * self.kv_dim;
        self.k[off..off + n * self.kv_dim].copy_from_slice(k);
        self.v[off..off + n * self.kv_dim].copy_from_slice(v);
        self.len += n;
    }
}

/// All layers of one sequence.
#[derive(Clone, Debug)]
pub struct SequenceCache {
    pub layers: Vec<LayerCache>,
    /// Remaining per-request quantization error budget (`--error-budget`);
    /// `None` until the tier serves this sequence its first quantized hit.
    /// Lives with the sequence so preemption, beam forks, and batching
    /// carry it along.
    pub quant_budget: Option<f64>,
}

impl SequenceCache {
    pub fn new(cfg: &ModelConfig) -> SequenceCache {
        SequenceCache {
            layers: (0..cfg.n_layers).map(|_| LayerCache::new(cfg.kv_dim())).collect(),
            quant_budget: None,
        }
    }

    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fork for beam search: an independent copy (copy-on-fork; beams then
    /// diverge freely).
    pub fn fork(&self) -> SequenceCache {
        self.clone()
    }

    /// Bucket that fits this sequence plus one incoming token.
    pub fn decode_bucket(&self) -> usize {
        round_up_bucket(self.len() + 1, CACHE_BUCKETS)
    }
}

/// Gather a batch of per-sequence caches for `layer` into the padded
/// `[bb, c, kv_dim]` tensors the decode executable takes (rows beyond
/// `caches.len()` stay zero — batch-bucket padding).  `c` must be a bucket
/// >= every sequence's len + 1.  Single copy: each sequence's live prefix
/// is memcpy'd straight into its padded slot.
pub fn gather_batch_padded(
    caches: &[&SequenceCache],
    layer: usize,
    bb: usize,
    c: usize,
    kv_dim: usize,
) -> (Tensor, Tensor) {
    assert!(bb >= caches.len());
    let mut k = Tensor::zeros(vec![bb, c, kv_dim]); // caller reshapes to [bb,c,kv,d]
    let mut v = Tensor::zeros(vec![bb, c, kv_dim]);
    for (i, seq) in caches.iter().enumerate() {
        let lc = &seq.layers[layer];
        assert!(lc.len < c, "cache bucket {c} too small for seq len {}", lc.len);
        let n = lc.len * kv_dim;
        let off = i * c * kv_dim;
        k.data[off..off + n].copy_from_slice(&lc.k[..n]);
        v.data[off..off + n].copy_from_slice(&lc.v[..n]);
    }
    (k, v)
}

/// Back-compat wrapper: exact batch, no bucket padding.
pub fn gather_batch(
    caches: &[&SequenceCache],
    layer: usize,
    c: usize,
    kv_dim: usize,
) -> (Tensor, Tensor) {
    gather_batch_padded(caches, layer, caches.len(), c, kv_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testkit::{check, Gen};

    fn cfg() -> ModelConfig {
        ModelConfig::test_tiny()
    }

    #[test]
    fn append_grows_through_buckets() {
        let cfg = cfg();
        let mut s = SequenceCache::new(&cfg);
        let kvd = cfg.kv_dim();
        for i in 0..200 {
            let k = vec![i as f32; kvd];
            let v = vec![-(i as f32); kvd];
            s.layers[0].append(&k, &v);
        }
        assert_eq!(s.layers[0].len, 200);
        assert_eq!(s.layers[0].cap, 512); // 200 -> bucket 512
        // Values preserved across the growth.
        assert_eq!(s.layers[0].k[0], 0.0);
        assert_eq!(s.layers[0].k[199 * kvd], 199.0);
    }

    #[test]
    fn extend_matches_repeated_append_property() {
        check("extend == appends", 64, |g: &mut Gen| {
            let kvd = 8;
            let n = g.usize_in(1..40);
            let data_k = g.vec_f32(n * kvd..n * kvd + 1, -1.0, 1.0);
            let data_v = g.vec_f32(n * kvd..n * kvd + 1, -1.0, 1.0);
            let mut a = LayerCache::new(kvd);
            a.extend(n, &data_k, &data_v);
            let mut b = LayerCache::new(kvd);
            for i in 0..n {
                b.append(&data_k[i * kvd..(i + 1) * kvd], &data_v[i * kvd..(i + 1) * kvd]);
            }
            assert_eq!(a.len, b.len);
            assert_eq!(a.k[..n * kvd], b.k[..n * kvd]);
            assert_eq!(a.v[..n * kvd], b.v[..n * kvd]);
        });
    }

    #[test]
    fn fork_is_independent() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let mut a = SequenceCache::new(&cfg);
        a.layers[0].append(&vec![1.0; kvd], &vec![2.0; kvd]);
        let mut b = a.fork();
        b.layers[0].append(&vec![9.0; kvd], &vec![9.0; kvd]);
        assert_eq!(a.len(), 1);
        assert_eq!(b.layers[0].len, 2);
        assert_eq!(a.layers[0].k[0], 1.0); // untouched
    }

    #[test]
    fn gather_zero_pads_beyond_len() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let mut s1 = SequenceCache::new(&cfg);
        s1.layers[0].append(&vec![1.0; kvd], &vec![1.0; kvd]);
        let mut s2 = SequenceCache::new(&cfg);
        s2.layers[0].append(&vec![2.0; kvd], &vec![2.0; kvd]);
        s2.layers[0].append(&vec![3.0; kvd], &vec![3.0; kvd]);
        let (k, _v) = gather_batch(&[&s1, &s2], 0, 128, kvd);
        assert_eq!(k.shape, vec![2, 128, kvd]);
        assert_eq!(k.data[0], 1.0);
        assert_eq!(k.data[kvd], 0.0); // s1 slot 1 padded
        assert_eq!(k.data[128 * kvd], 2.0);
        assert_eq!(k.data[128 * kvd + kvd], 3.0);
        assert_eq!(k.data[128 * kvd + 2 * kvd], 0.0);
    }

    #[test]
    fn decode_bucket_rounds_up() {
        let cfg = cfg();
        let mut s = SequenceCache::new(&cfg);
        assert_eq!(s.decode_bucket(), 128);
        let kvd = cfg.kv_dim();
        for _ in 0..127 {
            for l in &mut s.layers {
                l.append(&vec![0.0; kvd], &vec![0.0; kvd]);
            }
        }
        assert_eq!(s.len(), 127);
        assert_eq!(s.decode_bucket(), 128);
        for l in &mut s.layers {
            l.append(&vec![0.0; kvd], &vec![0.0; kvd]);
        }
        assert_eq!(s.decode_bucket(), 512);
    }
}
