//! Wall-clock parallel expert executor (the paper's §3.3–§3.4 made real).
//!
//! The simulated substrate has always *modeled* CPU/GPU concurrency
//! (`scheduler::predict_layer_us` takes `max(gpu_queue, cpu_queue)`), but
//! the numerics used to run every expert serially on the engine thread.
//! This module closes that gap:
//!
//! * [`pool::ExecutorPool`] — persistent CPU workers executing all
//!   CPU-planned experts of a layer concurrently, with caller-side work
//!   stealing ([`ExecutorPool::try_run_one`] /
//!   [`PendingBatch::wait_stealing`]): at the layer join the engine thread
//!   drains still-queued chunks instead of idling behind the workers;
//! * [`partition_rows`] — intra-expert row partitioning, so one large-`s`
//!   prefill expert also spreads across cores;
//! * [`run_expert_chunks`] / [`run_cpu_experts`] — the
//!   longest-chunk-first (per-expert priority) dispatch + ordered merge
//!   the pipelined layer executor (and the benches/tests) drive.
//!
//! Determinism contract: for fixed inputs the merged outputs are
//! **bit-identical for every thread count and every chunking**.  Two
//! things make that true: (1) each output row of the expert FFN depends
//! only on its own input row, and the host kernel accumulates every output
//! element in ascending-`k` order from `+0.0` regardless of the number of
//! rows in the call (see `cpukernel::gemm`); (2) chunk outputs are merged
//! positionally and the engine reduces expert outputs in expert-index
//! order, never in completion order.

pub mod pool;

pub use pool::{ExecutorPool, PendingBatch};

use crate::runtime::Tensor;
use std::sync::Arc;

/// Minimum rows per intra-expert chunk: below this the per-chunk dispatch
/// and weight-panel repacking cost more than the GEMM they parallelize
/// (decode-size inputs always stay whole).
pub const MIN_CHUNK_ROWS: usize = 16;

/// One unit of pool work: a row-slice of one expert's gathered input plus
/// shared handles to that expert's weights.
pub struct ExpertChunk {
    /// Expert index within the layer (output slot to merge into).
    pub expert: usize,
    /// First row of this chunk within the expert's input.
    pub row0: usize,
    /// Gathered activation rows for this chunk, `[rows, hidden]`, exact.
    pub x: Tensor,
    pub w1: Arc<Tensor>,
    pub w3: Arc<Tensor>,
    pub w2: Arc<Tensor>,
}

/// Output of one chunk, tagged for positional merge.
pub struct ChunkOut {
    pub expert: usize,
    pub row0: usize,
    pub out: Tensor,
}

/// A whole CPU-planned expert (the convenience form used by benches and
/// tests; the engine builds [`ExpertChunk`]s straight from the routing
/// table to skip one gather).
pub struct CpuExpertTask {
    pub expert: usize,
    /// Full gathered input `[s, hidden]`.
    pub x: Tensor,
    pub w1: Arc<Tensor>,
    pub w3: Arc<Tensor>,
    pub w2: Arc<Tensor>,
}

/// Split `rows` into at most `threads` contiguous chunks, targeting
/// [`MIN_CHUNK_ROWS`] rows per chunk: the chunk *count* is capped at
/// `ceil(rows / MIN_CHUNK_ROWS)`, so even splitting can produce chunks
/// down to half the target (never smaller) — inputs below `2 *
/// MIN_CHUNK_ROWS` rows stay whole.  Covers `[0, rows)` exactly, in
/// order, with no empty chunk.
pub fn partition_rows(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let max_chunks = rows.div_ceil(MIN_CHUNK_ROWS);
    let chunks = threads.max(1).min(max_chunks);
    let base = rows / chunks;
    let rem = rows % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut r0 = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push((r0, r0 + len));
        r0 += len;
    }
    debug_assert_eq!(r0, rows);
    out
}

/// Dispatch expert chunks to the pool.  Non-blocking on a threaded pool:
/// the caller overlaps GPU work and joins via [`PendingBatch::wait`] (or
/// [`PendingBatch::wait_stealing`], which drains leftover chunks on the
/// calling thread).
///
/// Chunks enter the queue with per-expert priority: longest first (LPT
/// scheduling), deterministically tie-broken, so one oversized prefill
/// expert starts immediately instead of queueing behind its siblings and
/// serializing the layer join.  Execution order never affects the outputs
/// — the merge is positional and the kernel chunk-invariant.
pub fn run_expert_chunks(
    pool: &ExecutorPool,
    mut chunks: Vec<ExpertChunk>,
) -> PendingBatch<ChunkOut> {
    chunks.sort_by(|a, b| {
        b.x.shape[0]
            .cmp(&a.x.shape[0])
            .then(a.expert.cmp(&b.expert))
            .then(a.row0.cmp(&b.row0))
    });
    let jobs: Vec<_> = chunks
        .into_iter()
        .map(|c| {
            move || ChunkOut {
                expert: c.expert,
                row0: c.row0,
                out: crate::cpukernel::expert_ffn_host(&c.x, &c.w1, &c.w3, &c.w2),
            }
        })
        .collect();
    pool.submit(jobs)
}

/// Execute a batch of whole CPU experts on the pool (blocking): partitions
/// each task's rows, dispatches every chunk, and merges the outputs back
/// into one `[s, hidden]` tensor per task, ordered like `tasks`.  Tasks
/// are borrowed — chunk inputs are copied out row-wise (the same copy the
/// engine's gather performs), weights travel as `Arc` clones.
pub fn run_cpu_experts(pool: &ExecutorPool, tasks: &[CpuExpertTask]) -> Vec<Tensor> {
    let mut outputs: Vec<Tensor> = Vec::with_capacity(tasks.len());
    let mut chunks: Vec<ExpertChunk> = Vec::new();
    for (slot, task) in tasks.iter().enumerate() {
        let (s, h) = (task.x.shape[0], task.x.shape[1]);
        outputs.push(Tensor::zeros(vec![s, h]));
        for (r0, r1) in partition_rows(s, pool.threads()) {
            chunks.push(ExpertChunk {
                expert: slot,
                row0: r0,
                x: Tensor {
                    shape: vec![r1 - r0, h],
                    data: task.x.data[r0 * h..r1 * h].to_vec(),
                },
                w1: Arc::clone(&task.w1),
                w3: Arc::clone(&task.w3),
                w2: Arc::clone(&task.w2),
            });
        }
    }
    for c in run_expert_chunks(pool, chunks).wait_stealing(pool) {
        let h = c.out.shape[1];
        outputs[c.expert].data[c.row0 * h..c.row0 * h + c.out.data.len()]
            .copy_from_slice(&c.out.data);
    }
    outputs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpukernel::expert_ffn_host;
    use crate::testkit::{check, Gen};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    fn rand_task(rng: &mut Rng, expert: usize, s: usize, h: usize, f: usize) -> CpuExpertTask {
        CpuExpertTask {
            expert,
            x: rand_tensor(rng, vec![s, h], 0.5),
            w1: Arc::new(rand_tensor(rng, vec![h, f], 0.2)),
            w3: Arc::new(rand_tensor(rng, vec![h, f], 0.2)),
            w2: Arc::new(rand_tensor(rng, vec![f, h], 0.2)),
        }
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn partition_rows_covers_exactly() {
        check("partition_rows covers", 256, |g: &mut Gen| {
            let rows = g.usize_in(1..600);
            let threads = g.usize_in(1..33);
            let parts = partition_rows(rows, threads);
            assert!(!parts.is_empty());
            assert!(parts.len() <= threads);
            let mut next = 0;
            for &(r0, r1) in &parts {
                assert_eq!(r0, next, "gap or overlap");
                assert!(r1 > r0, "empty chunk");
                next = r1;
            }
            assert_eq!(next, rows);
            // Chunks respect the minimum unless rows itself is small.
            if parts.len() > 1 {
                for &(r0, r1) in &parts {
                    assert!(r1 - r0 >= MIN_CHUNK_ROWS / 2, "chunk too small: {parts:?}");
                }
            }
        });
    }

    #[test]
    fn partition_rows_keeps_decode_whole() {
        for s in 1..MIN_CHUNK_ROWS {
            assert_eq!(partition_rows(s, 8), vec![(0, s)]);
        }
        assert_eq!(partition_rows(0, 8), Vec::<(usize, usize)>::new());
    }

    /// The acceptance-criteria property: parallel output is bit-identical
    /// to serial output for threads in {1, 2, 4} — same reduction order,
    /// chunk-invariant kernel.
    #[test]
    fn parallel_output_bitwise_equals_serial() {
        check("executor determinism", 12, |g: &mut Gen| {
            let h = 2 * g.usize_in(2..20);
            let f = 2 * g.usize_in(2..33);
            let n_experts = g.usize_in(1..6);
            let seed = g.u64();
            let mut rng = Rng::new(seed);
            let tasks: Vec<CpuExpertTask> = (0..n_experts)
                .map(|e| {
                    // Mix decode-size and prefill-size experts so both the
                    // whole-expert and the row-partitioned paths run.
                    let s = if e % 2 == 0 { 1 + e } else { 40 + 8 * e };
                    rand_task(&mut rng, e, s, h, f)
                })
                .collect();

            // Reference: direct serial kernel calls, no executor at all.
            let reference: Vec<Tensor> = tasks
                .iter()
                .map(|t| expert_ffn_host(&t.x, &t.w1, &t.w3, &t.w2))
                .collect();

            for threads in [1usize, 2, 4] {
                let pool = ExecutorPool::new(threads);
                let got = run_cpu_experts(&pool, &tasks);
                assert_eq!(got.len(), reference.len());
                for (g_out, want) in got.iter().zip(&reference) {
                    assert_eq!(g_out.shape, want.shape);
                    assert_eq!(
                        bits(g_out),
                        bits(want),
                        "threads={threads}: executor output not bit-identical to serial"
                    );
                }
            }
        });
    }

    #[test]
    fn chunked_expert_matches_whole_expert_bitwise() {
        // Intra-expert partitioning alone (one big expert, many chunks).
        let mut rng = Rng::new(99);
        let task = rand_task(&mut rng, 0, 130, 24, 40);
        let want = expert_ffn_host(&task.x, &task.w1, &task.w3, &task.w2);
        let tasks = [task];
        for threads in [2usize, 4, 7] {
            let pool = ExecutorPool::new(threads);
            let got = run_cpu_experts(&pool, &tasks);
            assert_eq!(bits(&got[0]), bits(&want), "threads={threads}");
        }
    }

    #[test]
    fn chunks_dispatch_longest_first() {
        // The inline pool executes submission order, and wait() returns
        // results in that same order — so the result sequence reveals the
        // dispatch priority: descending rows, ties by (expert, row0).
        let mut rng = Rng::new(3);
        let h = 8;
        let sizes = [3usize, 90, 17, 90, 1];
        let chunks: Vec<ExpertChunk> = sizes
            .iter()
            .enumerate()
            .map(|(e, &s)| ExpertChunk {
                expert: e,
                row0: 0,
                x: rand_tensor(&mut rng, vec![s, h], 0.1),
                w1: Arc::new(rand_tensor(&mut rng, vec![h, h], 0.1)),
                w3: Arc::new(rand_tensor(&mut rng, vec![h, h], 0.1)),
                w2: Arc::new(rand_tensor(&mut rng, vec![h, h], 0.1)),
            })
            .collect();
        let pool = ExecutorPool::new(1);
        let order: Vec<usize> = run_expert_chunks(&pool, chunks)
            .wait()
            .iter()
            .map(|c| c.expert)
            .collect();
        assert_eq!(order, vec![1, 3, 2, 0, 4], "LPT order with deterministic ties");
    }

    #[test]
    fn overlap_submit_returns_before_join() {
        // On a threaded pool, submit must not block: the engine thread uses
        // the gap to run GPU-planned experts.
        let pool = ExecutorPool::new(2);
        let jobs: Vec<_> = (0..2)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    i
                }
            })
            .collect();
        let t0 = std::time::Instant::now();
        let pending = pool.submit(jobs);
        let submit_elapsed = t0.elapsed();
        let out = pending.wait();
        let total_elapsed = t0.elapsed();
        assert_eq!(out, vec![0, 1]);
        assert!(
            submit_elapsed < std::time::Duration::from_millis(10),
            "submit blocked: {submit_elapsed:?}"
        );
        assert!(total_elapsed >= std::time::Duration::from_millis(20));
    }
}
