//! Persistent worker pool for the wall-clock expert executor.
//!
//! std-only (no rayon/crossbeam): a shared `Mutex<VecDeque>` job queue, a
//! condvar for wakeups, and an `mpsc` channel per submitted batch.  Jobs
//! are `'static` closures — the expert layer above ships owned activation
//! chunks and `Arc`-shared weights, so no scoped-lifetime tricks (and no
//! `unsafe`) are needed.
//!
//! Semantics:
//!
//! * `threads <= 1` builds an **inline** pool: `submit` runs every job on
//!   the calling thread before returning.  This is the `--threads 1`
//!   serial regression path — bit-for-bit the old single-threaded engine.
//! * `threads >= 2` spawns that many persistent workers.  `submit` is
//!   non-blocking; the caller overlaps its own (GPU) work and joins at
//!   [`PendingBatch::wait`].
//! * Results come back **in submission order** regardless of completion
//!   order, which is what makes the layer reduction deterministic.
//! * A panicking job surfaces as a panic in `wait()` (its result channel
//!   is dropped); workers themselves survive and keep serving.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// Jobs run via [`ExecutorPool::try_run_one`] (work stealing).  Only
    /// the engine thread steals (at the layer join), so per-layer deltas
    /// of this counter are deterministic observability data.
    steals: AtomicU64,
}

/// A fixed-size pool of persistent worker threads (or the inline stub).
pub struct ExecutorPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ExecutorPool {
    /// Build a pool with `threads` CPU workers (clamped to >= 1).
    /// `threads == 1` means inline/serial execution — no threads spawned.
    pub fn new(threads: usize) -> ExecutorPool {
        Self::with_affinity(threads, false)
    }

    /// [`ExecutorPool::new`], optionally pinning worker `i` to CPU core
    /// `i` (`--pin-workers`).  Pinning is best-effort: on platforms
    /// without `sched_setaffinity` — or when the call fails (cgroup cpuset
    /// restrictions, fewer cores than workers) — the worker simply runs
    /// unpinned.  Affinity never changes job results or their (submission)
    /// order, only wall-clock dispatch jitter from OS migrations.
    pub fn with_affinity(threads: usize, pin_workers: bool) -> ExecutorPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            steals: AtomicU64::new(0),
        });
        let mut workers = Vec::new();
        if threads > 1 {
            for i in 0..threads {
                let sh = Arc::clone(&shared);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("fiddler-exec-{i}"))
                        .spawn(move || {
                            if pin_workers {
                                // Failure is fine: run unpinned.
                                let _ = pin_current_thread(i);
                            }
                            worker_loop(sh)
                        })
                        .expect("spawn executor worker"),
                );
            }
        }
        ExecutorPool { shared, workers, threads }
    }

    /// Worker count the pool was built with (1 for the inline pool).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when `submit` runs jobs on the calling thread (serial mode).
    pub fn is_inline(&self) -> bool {
        self.workers.is_empty()
    }

    /// Work stealing: pop one queued job and run it on the calling
    /// thread.  Returns false when the queue is empty (always, for the
    /// inline pool — `submit` leaves it nothing to steal).  A stolen job
    /// that panics is contained exactly like on a worker: the panic
    /// surfaces at its batch's `wait()` through the dropped result sender,
    /// never on this thread.
    pub fn try_run_one(&self) -> bool {
        let job = {
            let mut q = self.shared.queue.lock().unwrap();
            q.pop_front()
        };
        match job {
            Some(j) => {
                self.shared.steals.fetch_add(1, Ordering::Relaxed);
                let _ = catch_unwind(AssertUnwindSafe(j));
                true
            }
            None => false,
        }
    }

    /// Cumulative count of jobs stolen through [`ExecutorPool::try_run_one`].
    pub fn steal_count(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Submit a batch of independent jobs.  Non-blocking when the pool has
    /// workers; the returned handle yields results in submission order.
    pub fn submit<T, F>(&self, jobs: Vec<F>) -> PendingBatch<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let expected = jobs.len();
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        if self.is_inline() {
            for (i, job) in jobs.into_iter().enumerate() {
                let _ = tx.send((i, job()));
            }
        } else {
            {
                let mut q = self.shared.queue.lock().unwrap();
                for (i, job) in jobs.into_iter().enumerate() {
                    let tx = tx.clone();
                    q.push_back(Box::new(move || {
                        // Receiver may be gone (submitter bailed on an
                        // unrelated error): dropping the result is fine.
                        let _ = tx.send((i, job()));
                    }));
                }
            }
            self.shared.available.notify_all();
        }
        PendingBatch { rx, expected }
    }
}

/// Pin the calling thread to `core % available_cores` (best effort).
///
/// Raw `sched_setaffinity` syscall — the crate is std-only, so no libc.
/// Returns `Err(())` where unsupported or when the kernel rejects the
/// mask; callers treat that as "run unpinned".
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn pin_current_thread(core: usize) -> Result<(), ()> {
    let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let core = core % n.max(1);
    // cpu_set_t as a 1024-bit mask (16 x u64), one bit set.
    let mut mask = [0u64; 16];
    if core >= 1024 {
        return Err(());
    }
    mask[core / 64] = 1u64 << (core % 64);
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0i64,                 // pid 0 = calling thread
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    if ret == 0 {
        Ok(())
    } else {
        Err(())
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn pin_current_thread(_core: usize) -> Result<(), ()> {
    Err(())
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        // A panicking job must not kill the worker; the panic reaches the
        // submitter through its dropped result sender.
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle to a submitted batch; results ordered by submission index.
pub struct PendingBatch<T> {
    rx: mpsc::Receiver<(usize, T)>,
    expected: usize,
}

impl<T> PendingBatch<T> {
    /// Number of jobs in the batch.
    pub fn len(&self) -> usize {
        self.expected
    }

    pub fn is_empty(&self) -> bool {
        self.expected == 0
    }

    /// [`PendingBatch::wait`], with the calling thread first *stealing*
    /// still-queued jobs and running them itself.  Once the submitter has
    /// finished its own overlapped (GPU) work, any chunk left in the queue
    /// would otherwise wait behind the workers' in-progress jobs — with
    /// one oversized prefill expert, exactly the serialization that used
    /// to stall the layer join.  Steals may execute jobs of other
    /// batches; their results flow to their own channels.  Determinism is
    /// unaffected: who runs a job never changes its output, and results
    /// are still merged by submission index.
    pub fn wait_stealing(self, pool: &ExecutorPool) -> Vec<T> {
        while pool.try_run_one() {}
        self.wait()
    }

    /// Block until every job of the batch has finished; panics if any job
    /// panicked (the layer must not silently drop an expert's output).
    pub fn wait(self) -> Vec<T> {
        let mut slots: Vec<Option<T>> = (0..self.expected).map(|_| None).collect();
        for _ in 0..self.expected {
            let (i, v) = self
                .rx
                .recv()
                .expect("executor job lost (worker panicked?)");
            slots[i] = Some(v);
        }
        slots
            .into_iter()
            .map(|s| s.expect("executor returned a duplicate job index"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_pool_runs_on_caller() {
        let pool = ExecutorPool::new(1);
        assert!(pool.is_inline());
        assert_eq!(pool.threads(), 1);
        let out = pool.submit((0..5).map(|i| move || i * 10).collect()).wait();
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn threaded_pool_preserves_submission_order() {
        let pool = ExecutorPool::new(4);
        assert!(!pool.is_inline());
        // Uneven job durations: completion order != submission order.
        let jobs: Vec<_> = (0..64usize)
            .map(|i| {
                move || {
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.submit(jobs).wait();
        let want: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn pool_survives_multiple_batches() {
        let pool = ExecutorPool::new(2);
        for round in 0..10u64 {
            let jobs: Vec<_> = (0..8u64).map(|i| move || round * 100 + i).collect();
            let out = pool.submit(jobs).wait();
            assert_eq!(out.len(), 8);
            assert_eq!(out[3], round * 100 + 3);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = ExecutorPool::new(2);
        let jobs: Vec<fn() -> usize> = Vec::new();
        assert!(pool.submit(jobs).wait().is_empty());
    }

    #[test]
    fn stealing_wait_matches_plain_wait() {
        // Same jobs, same ordered results — whether the caller steals or
        // idles at the join.
        let pool = ExecutorPool::new(3);
        let mk = || (0..40usize).map(|i| move || i * 3).collect::<Vec<_>>();
        let waited = pool.submit(mk()).wait();
        let stolen = pool.submit(mk()).wait_stealing(&pool);
        assert_eq!(waited, stolen);
        assert_eq!(stolen[13], 39);
    }

    #[test]
    fn inline_pool_has_nothing_to_steal() {
        let pool = ExecutorPool::new(1);
        assert!(!pool.try_run_one());
        let out = pool.submit(vec![|| 7]).wait_stealing(&pool);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn caller_steals_queued_jobs() {
        // Both workers are parked inside long jobs; newly queued jobs can
        // then only run if the submitter steals them — which is exactly
        // what wait_stealing's drain does at the layer join.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::{Arc, Barrier};
        let pool = ExecutorPool::new(2);
        let entered = Arc::new(AtomicUsize::new(0));
        let release = Arc::new(Barrier::new(3));
        let blockers: Vec<_> = (0..2)
            .map(|_| {
                let entered = Arc::clone(&entered);
                let release = Arc::clone(&release);
                move || {
                    entered.fetch_add(1, Ordering::SeqCst);
                    release.wait();
                    0usize
                }
            })
            .collect();
        let blocked = pool.submit(blockers);
        // Wait until both workers are provably inside the blockers, so the
        // steal below cannot pick one up and deadlock on the barrier.
        while entered.load(Ordering::SeqCst) < 2 {
            std::thread::yield_now();
        }
        let stealable = pool.submit((1..=4usize).map(|i| move || i).collect::<Vec<_>>());
        while pool.try_run_one() {}
        release.wait(); // let the workers finish
        assert_eq!(blocked.wait(), vec![0, 0]);
        assert_eq!(stealable.wait(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn steal_count_tracks_try_run_one() {
        let pool = ExecutorPool::new(1);
        assert_eq!(pool.steal_count(), 0);
        assert!(!pool.try_run_one());
        assert_eq!(pool.steal_count(), 0, "empty queue: nothing stolen");
        // Park both workers of a threaded pool, queue jobs only the
        // caller can run, and steal them (the wait_stealing pattern).
        use std::sync::{Arc, Barrier};
        let pool = ExecutorPool::new(2);
        let entered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let release = Arc::new(Barrier::new(3));
        let blockers: Vec<_> = (0..2)
            .map(|_| {
                let entered = Arc::clone(&entered);
                let release = Arc::clone(&release);
                move || {
                    entered.fetch_add(1, Ordering::SeqCst);
                    release.wait();
                    0usize
                }
            })
            .collect();
        let blocked = pool.submit(blockers);
        while entered.load(Ordering::SeqCst) < 2 {
            std::thread::yield_now();
        }
        let stealable = pool.submit((0..3usize).map(|i| move || i).collect::<Vec<_>>());
        while pool.try_run_one() {}
        assert_eq!(pool.steal_count(), 3, "caller ran all three queued jobs");
        release.wait();
        assert_eq!(blocked.wait(), vec![0, 0]);
        assert_eq!(stealable.wait(), vec![0, 1, 2]);
    }

    #[test]
    fn pinned_pool_matches_unpinned_results() {
        // Affinity is a placement hint only: same jobs, same ordered
        // results, pinned or not (and pinning must not panic on hosts
        // where sched_setaffinity is unavailable or restricted).
        let plain = ExecutorPool::new(3);
        let pinned = ExecutorPool::with_affinity(3, true);
        let mk = || (0..32usize).map(|i| move || i * 7).collect::<Vec<_>>();
        assert_eq!(plain.submit(mk()).wait(), pinned.submit(mk()).wait());
        assert_eq!(pinned.threads(), 3);
        // Inline pools accept the flag and stay inline.
        assert!(ExecutorPool::with_affinity(1, true).is_inline());
    }

    #[test]
    fn job_panic_reaches_wait_not_worker() {
        let pool = ExecutorPool::new(2);
        // Box<dyn FnOnce() -> usize + Send> is itself FnOnce + Send.
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("expert exploded")),
            Box::new(|| 3),
        ];
        let pending = pool.submit(jobs);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| pending.wait()));
        assert!(r.is_err(), "panic in a job must propagate to wait()");
        // The pool still serves later batches.
        let out = pool.submit((0..4).map(|i| move || i + 1).collect()).wait();
        assert_eq!(out, vec![1, 2, 3, 4]);
    }
}
