//! Baseline systems re-implemented as execution policies (paper §4.1).
//!
//! * [`MiiOffloadPolicy`] — DeepSpeed-MII with ZeRO-Infinity: all expert
//!   weights live in (pinned) CPU memory and are streamed to the GPU for
//!   every use.  Streaming is pipelined with compute (pin_memory +
//!   prefetch), which is why this baseline shines on long prefill and
//!   suffers on latency-critical decode (Fig. 4 vs Fig. 5).
//! * [`LruOffloadPolicy`] — Mixtral-Offloading (Eliseev & Mazur 2023): an
//!   LRU expert cache on the GPU; a miss transfers weights CPU->GPU
//!   synchronously before compute.  Never computes on the CPU.
//! * [`StaticSplitPolicy`] — llama.cpp with `-ngl N`: the first N layers
//!   (weights, including all their experts) are pinned on the GPU, the
//!   rest run on the CPU where their weights live.  No weight ever moves
//!   at runtime; beams are processed sequentially (the b2956 beam path).

use crate::config::serving::ServingConfig;
use crate::config::DeviceKind;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::popularity::Profile;
use crate::scheduler::policy::ExecPolicy;
use crate::scheduler::ExpertPlan;

// ---------------------------------------------------------------------------

/// DeepSpeed-MII + ZeRO-Infinity offloading.
#[derive(Default)]
pub struct MiiOffloadPolicy;

impl ExecPolicy for MiiOffloadPolicy {
    fn name(&self) -> &'static str {
        "mii"
    }

    // No initialization-time pinning: ZeRO-Infinity keeps parameters in CPU
    // memory and streams them in on demand.

    fn plan_layer(
        &mut self,
        _layer: usize,
        inp_size: &[usize],
        _memory: &mut ExpertCache,
        _lat: &LatencyModel,
        _now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        inp_size
            .iter()
            .map(|&s| (s > 0).then_some(ExpertPlan::GpuTransfer))
            .collect()
    }

    fn expert_cost_us(&self, plan: ExpertPlan, s: usize, lat: &LatencyModel) -> f64 {
        match plan {
            // Pipelined streaming: compute of expert j overlaps the
            // transfer of expert j+1 (pin_memory enabled, as in §4.1).
            ExpertPlan::GpuTransfer => lat.transfer_lat().max(lat.gpu_lat(s)),
            p => p.cost_us(lat, s),
        }
    }
}

// ---------------------------------------------------------------------------

/// Mixtral-Offloading: LRU expert cache on the GPU.
pub struct LruOffloadPolicy {
    /// Experts kept per layer (the paper sets `offload_per_layer` = 7 for
    /// Env1 / 5 for Env2, i.e. cache 1 resp. 3 of 8 per layer); we model
    /// the equivalent total capacity through the [`ExpertCache`] with its
    /// default LRU eviction policy.
    pub hits: u64,
    pub misses: u64,
}

impl Default for LruOffloadPolicy {
    fn default() -> Self {
        LruOffloadPolicy { hits: 0, misses: 0 }
    }
}

impl ExecPolicy for LruOffloadPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        _lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    return None;
                }
                let id = (layer, j);
                if memory.lookup(id, now_us) {
                    self.hits += 1;
                    Some(ExpertPlan::GpuResident)
                } else {
                    // Synchronous CPU->GPU weight copy, cached for reuse.
                    memory.admit(id);
                    self.misses += 1;
                    Some(ExpertPlan::GpuTransfer)
                }
            })
            .collect()
    }

    // Synchronous transfer-then-compute (no prefetch pipeline): the default
    // ExpertPlan cost (transfer + compute) applies.
}

// ---------------------------------------------------------------------------

/// llama.cpp-style static layer split.
pub struct StaticSplitPolicy {
    /// Layers [0, ngl) fully on GPU.
    pub ngl: usize,
    n_experts: usize,
}

impl StaticSplitPolicy {
    pub fn new(ngl: usize, n_experts: usize) -> Self {
        StaticSplitPolicy { ngl, n_experts }
    }

    /// The paper's ngl (8 or 16 out of 32 layers), rescaled to a model with
    /// `n_layers` layers.
    pub fn scaled_ngl(env_name: &str, n_layers: usize) -> usize {
        let paper = ServingConfig::paper_ngl_for(env_name);
        ((paper * n_layers + 31) / 32).max(1).min(n_layers)
    }
}

impl ExecPolicy for StaticSplitPolicy {
    fn name(&self) -> &'static str {
        "static-split"
    }

    fn init(&mut self, memory: &mut ExpertCache, _profile: &Profile, _seed: u64) {
        // Pin every expert of the first `ngl` layers, capacity permitting.
        'outer: for layer in 0..self.ngl {
            for e in 0..self.n_experts {
                if memory.resident_count() >= memory.capacity() {
                    break 'outer;
                }
                memory.pin((layer, e));
            }
        }
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut ExpertCache,
        _lat: &LatencyModel,
        now_us: f64,
    ) -> Vec<Option<ExpertPlan>> {
        inp_size
            .iter()
            .enumerate()
            .map(|(j, &s)| {
                if s == 0 {
                    None
                } else if memory.lookup((layer, j), now_us) {
                    Some(ExpertPlan::GpuResident)
                } else {
                    // Weights live on the CPU; computation follows them.
                    Some(ExpertPlan::Cpu)
                }
            })
            .collect()
    }

    fn batches_beams(&self) -> bool {
        false // beams decoded one at a time
    }

    fn attn_device(&self, layer: usize) -> DeviceKind {
        if layer < self.ngl {
            DeviceKind::Gpu
        } else {
            DeviceKind::Cpu
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    #[test]
    fn mii_always_transfers() {
        let mut pol = MiiOffloadPolicy;
        let mut mem = ExpertCache::with_capacity(8);
        let plans = pol.plan_layer(0, &[1, 0, 5], &mut mem, &lat(), 0.0);
        assert_eq!(plans[0], Some(ExpertPlan::GpuTransfer));
        assert_eq!(plans[1], None);
        assert_eq!(plans[2], Some(ExpertPlan::GpuTransfer));
        // And again — nothing was cached.
        let plans = pol.plan_layer(0, &[1, 0, 5], &mut mem, &lat(), 0.0);
        assert_eq!(plans[0], Some(ExpertPlan::GpuTransfer));
    }

    #[test]
    fn mii_overlaps_stream_with_compute() {
        let pol = MiiOffloadPolicy;
        let lat = lat();
        let c = pol.expert_cost_us(ExpertPlan::GpuTransfer, 1024, &lat);
        assert!(c < ExpertPlan::GpuTransfer.cost_us(&lat, 1024));
    }

    #[test]
    fn lru_caches_across_steps() {
        let mut pol = LruOffloadPolicy::default();
        let mut mem = ExpertCache::with_capacity(2);
        let p1 = pol.plan_layer(0, &[1, 1], &mut mem, &lat(), 0.0);
        assert!(p1.iter().all(|p| *p == Some(ExpertPlan::GpuTransfer)));
        let p2 = pol.plan_layer(0, &[1, 1], &mut mem, &lat(), 0.0);
        assert!(p2.iter().all(|p| *p == Some(ExpertPlan::GpuResident)));
        assert_eq!(pol.hits, 2);
        assert_eq!(pol.misses, 2);
    }

    #[test]
    fn lru_does_not_overlap_transfer() {
        let pol = LruOffloadPolicy::default();
        let lat = lat();
        let c = pol.expert_cost_us(ExpertPlan::GpuTransfer, 1, &lat);
        assert!((c - (lat.transfer_lat() + lat.gpu_lat(1))).abs() < 1e-9);
    }

    #[test]
    fn static_split_layers() {
        let mut pol = StaticSplitPolicy::new(1, 4);
        let mut mem = ExpertCache::with_capacity(8);
        let prof = Profile::new(2, 4);
        pol.init(&mut mem, &prof, 0);
        let p0 = pol.plan_layer(0, &[1, 1, 1, 1], &mut mem, &lat(), 0.0);
        assert!(p0.iter().all(|p| *p == Some(ExpertPlan::GpuResident)));
        let p1 = pol.plan_layer(1, &[1, 1, 1, 1], &mut mem, &lat(), 0.0);
        assert!(p1.iter().all(|p| *p == Some(ExpertPlan::Cpu)));
        assert_eq!(pol.attn_device(0), DeviceKind::Gpu);
        assert_eq!(pol.attn_device(1), DeviceKind::Cpu);
        assert!(!pol.batches_beams());
    }

    #[test]
    fn scaled_ngl_matches_paper_proportion() {
        assert_eq!(StaticSplitPolicy::scaled_ngl("env1", 32), 8);
        assert_eq!(StaticSplitPolicy::scaled_ngl("env2", 32), 16);
        assert_eq!(StaticSplitPolicy::scaled_ngl("env1", 4), 1);
        assert_eq!(StaticSplitPolicy::scaled_ngl("env2", 4), 2);
    }

    #[test]
    fn static_split_respects_capacity() {
        let mut pol = StaticSplitPolicy::new(4, 8);
        let mut mem = ExpertCache::with_capacity(10);
        pol.init(&mut mem, &Profile::new(4, 8), 0);
        assert_eq!(mem.resident_count(), 10); // capped, no panic
    }
}
