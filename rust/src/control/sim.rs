//! Trace-driven lookahead simulation — adaptive vs static prefetch
//! windows on drifting routing traces, without model artifacts.
//!
//! Extends [`crate::expertcache::sim::run_cache_sim`]'s loop with the
//! pipeline's cross-layer prefetch window.  The predictor mirrors the
//! engine's `TransitionProfile` idea: the drifting trace routes each
//! layer as a rotation of the previous layer's expert set, so the sim
//! learns the per-layer cumulative shifts from the *previous* step and
//! projects the current layer's routed set forward to layers
//! `L+1..=L+W`.  Inside a stable phase those predictions are exact;
//! right after a drift boundary they are stale and every speculative
//! transfer is wasted lane time — which is exactly the trade-off a
//! fixed `W` cannot navigate.  When `W > 0` speculation is owned by the
//! window (one in-flight attempt per target layer, lane backlog stops
//! the scan); at `W = 0` the loop degenerates to `run_cache_sim`'s
//! reactive miss-triggered prefetch, bit for bit.
//!
//! `W` is either static (the `--pipeline-lookahead` sweep) or driven by
//! a [`LookaheadController`](super::LookaheadController) fed the
//! virtual step latency as its waste signal, so the hill climb descends
//! the true objective.  (The engine's loop 1 feeds prefetch counter
//! deltas instead — the controller is reward-agnostic.)
//!
//! Fully deterministic (virtual time only) so BENCH_PR10.json and the
//! zero-dep Python port (`python/sim/verify_control.py`) reproduce the
//! numbers bit-for-bit.

use super::LookaheadController;
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::scheduler::{decide_expert, ExpertPlan};
use crate::util::stats::mean;
use crate::workload::DriftingExpertTrace;

/// One drifting-trace workload: segments run back-to-back over one cache
/// (so the controller carries its learned state across regime changes).
#[derive(Clone, Debug)]
pub struct LookaheadSimConfig {
    pub capacity: usize,
    pub layers: usize,
    pub experts: usize,
    pub top_k: usize,
    pub seed: u64,
    /// Tokens per routed expert (the trace emits per-expert counts for
    /// one sequence; `batch` scales them to a batched decode step, which
    /// moves the CPU/GPU crossover so prefetch hits actually pay).
    pub batch: usize,
    /// `(phase_len, steps)` per segment; segment `i` uses `seed + i`.
    pub segments: Vec<(usize, usize)>,
}

/// Prefetch-window selection for one run.
#[derive(Clone, Copy, Debug)]
pub enum LookaheadMode {
    /// Fixed window (`--pipeline-lookahead` analogue).
    Static(usize),
    /// Hill-climbing controller starting at the given window, exploring
    /// `[0, max]` (the sim has no in-band signal to lose at 0).
    Adaptive { start: usize, max: usize },
}

/// Outcome of one simulated run.
#[derive(Clone, Debug)]
pub struct LookaheadSimReport {
    pub mode: String,
    /// Mean simulated decode-step latency per segment (µs).
    pub segment_step_us: Vec<f64>,
    /// Virtual decode throughput per segment (steps/s = tokens/s at
    /// batch 1).
    pub segment_tok_per_s: Vec<f64>,
    pub mean_step_us: f64,
    pub final_lookahead: usize,
    pub adjustments: u64,
    pub prefetches: u64,
    pub prefetch_hits: u64,
    pub hit_rate: f64,
}

/// Decode's `kind_idx` (the only pass kind the trace models).
const KIND_DECODE: usize = 2;

/// Learn the per-layer cumulative rotation offsets from one observed
/// step: `cum[l]` is the shift that maps layer 0's routed set onto layer
/// `l`'s, accumulated from the smallest rotation matching each adjacent
/// layer pair.  Expert `j` at layer `a` predicts expert
/// `(j + cum[b] - cum[a]) mod n` at layer `b`.
fn learn_cum_shifts(prev: &[Vec<usize>], n: usize) -> Vec<usize> {
    let layers = prev.len();
    let mut cum = vec![0usize; layers];
    for l in 1..layers {
        let a: Vec<usize> = (0..n).filter(|&j| prev[l - 1][j] > 0).collect();
        let b: Vec<bool> = (0..n).map(|j| prev[l][j] > 0).collect();
        let b_count = b.iter().filter(|&&x| x).count();
        let mut found = 0usize;
        for s in 0..n {
            if a.len() == b_count && a.iter().all(|&e| b[(e + s) % n]) {
                found = s;
                break;
            }
        }
        cum[l] = (cum[l - 1] + found) % n;
    }
    cum
}

/// Drive one cache over the segmented drifting trace with the chosen
/// window mode.
pub fn run_lookahead_sim(
    cfg: &LookaheadSimConfig,
    lat: &LatencyModel,
    mode: LookaheadMode,
) -> LookaheadSimReport {
    let mut cache = ExpertCache::with_capacity(cfg.capacity);
    let (mut ctl, static_w, label) = match mode {
        LookaheadMode::Static(w) => (None, w, format!("static-{w}")),
        LookaheadMode::Adaptive { start, max } => (
            Some(LookaheadController::with_range(start, 0, max)),
            start,
            "adaptive".to_string(),
        ),
    };
    let transfer = lat.transfer_lat();
    let mut now = 0.0f64;
    let mut prev_routing: Option<Vec<Vec<usize>>> = None;
    let mut segment_step_us = Vec::with_capacity(cfg.segments.len());
    let mut all_step_us = Vec::new();
    for (si, &(phase_len, steps)) in cfg.segments.iter().enumerate() {
        let mut trace = DriftingExpertTrace::new(
            cfg.layers,
            cfg.experts,
            cfg.top_k,
            phase_len,
            cfg.seed + si as u64,
        );
        let mut step_us = Vec::with_capacity(steps);
        for _ in 0..steps {
            let w = ctl.as_ref().map(|c| c.lookahead(KIND_DECODE)).unwrap_or(static_w);
            let routing = trace.step();
            let t_step = now;
            // Shift structure learned once per step from last step's
            // observed routing (the TransitionProfile analogue).
            let cum = match (&prev_routing, w > 0) {
                (Some(prev), true) => Some(learn_cum_shifts(prev, cfg.experts)),
                _ => None,
            };
            for (layer, inp) in routing.iter().enumerate() {
                cache.observe_layer(layer, inp);
                // Cross-layer prefetch window: project this layer's
                // routed set forward by the learned shifts, one lane
                // attempt per target layer, stop on backlog.
                if let Some(cum) = &cum {
                    let cur: Vec<usize> = (0..cfg.experts).filter(|&j| inp[j] > 0).collect();
                    'dist: for d in 1..=w {
                        let tl = layer + d;
                        if tl >= cfg.layers {
                            break;
                        }
                        let delta = (cum[tl] + cfg.experts - cum[layer]) % cfg.experts;
                        let mut predicted: Vec<usize> =
                            cur.iter().map(|&j| (j + delta) % cfg.experts).collect();
                        predicted.sort_unstable();
                        for j in predicted {
                            let id = (tl, j);
                            if cache.is_resident(id) {
                                continue;
                            }
                            if cache.prefetch(id, now, transfer).is_none() {
                                break 'dist; // lane backlogged
                            }
                            break; // one issue per (layer, distance)
                        }
                    }
                }
                // Serve the layer (run_cache_sim's Algorithm 1 loop, at
                // batched token counts).
                let mut gpu = 0.0f64;
                let mut cpu = 0.0f64;
                for (j, &s) in inp.iter().enumerate() {
                    if s == 0 {
                        continue;
                    }
                    let s = s * cfg.batch;
                    let id = (layer, j);
                    let resident = cache.lookup(id, now);
                    match decide_expert(resident, s, lat) {
                        Some(ExpertPlan::GpuResident) => gpu += lat.gpu_lat(s),
                        Some(ExpertPlan::GpuTransfer) => {
                            cache.admit(id);
                            gpu += lat.transfer_lat().max(lat.gpu_lat(s));
                        }
                        Some(ExpertPlan::Cpu) => {
                            // The window owns speculation when armed;
                            // only the W=0 loop falls back to reactive
                            // miss-triggered prefetch (run_cache_sim
                            // parity).
                            if w == 0 {
                                let _ = cache.prefetch(id, now, lat.transfer_lat());
                            }
                            cpu += lat.cpu_lat(s);
                        }
                        _ => {}
                    }
                }
                let t = gpu.max(cpu);
                now += t;
            }
            let dt = now - t_step;
            step_us.push(dt);
            prev_routing = Some(routing);
            if let Some(c) = &mut ctl {
                // Virtual step latency (ms ticks) as the waste signal:
                // the climb minimizes what the sim actually measures.
                c.on_pass(KIND_DECODE, 0, 0, (dt / 1000.0) as u64);
            }
        }
        segment_step_us.push(mean(&step_us));
        all_step_us.extend_from_slice(&step_us);
    }
    let st = cache.stats().clone();
    LookaheadSimReport {
        mode: label,
        segment_tok_per_s: segment_step_us.iter().map(|&us| 1e6 / us.max(1e-9)).collect(),
        segment_step_us,
        mean_step_us: mean(&all_step_us),
        final_lookahead: ctl
            .as_ref()
            .map(|c| c.lookahead(KIND_DECODE))
            .unwrap_or(static_w),
        adjustments: ctl.as_ref().map(|c| c.adjustments(KIND_DECODE)).unwrap_or(0),
        prefetches: st.prefetches,
        prefetch_hits: st.prefetch_hits,
        hit_rate: st.hit_rate(),
    }
}

/// The workload BENCH_PR10.json sweeps: a long-stable regime (shift
/// predictions are exact, the right window hides most transfers) into a
/// fast-churning one (predictions go stale every few steps).  At this
/// batch shape the one-layer window is the sweep's optimum — deeper
/// windows crowd the serialized lane, no window leaves misses on the
/// CPU — and the controller has to find that from latency feedback
/// alone, without the offline sweep.
pub fn bench_workload(seed: u64, steps_per_segment: usize) -> LookaheadSimConfig {
    LookaheadSimConfig {
        capacity: 24,
        layers: 8,
        experts: 16,
        top_k: 2,
        seed,
        batch: 16,
        segments: vec![
            (steps_per_segment.max(1), steps_per_segment), // stable: no drift
            (3, steps_per_segment),                        // drift every 3 steps
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn lat() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    #[test]
    fn static_zero_matches_plain_cache_sim() {
        // W=0 never speculates ahead and keeps the reactive prefetch:
        // the loop degenerates to run_cache_sim over the same trace,
        // step for step.
        let cfg = LookaheadSimConfig {
            capacity: 10,
            layers: 4,
            experts: 8,
            top_k: 2,
            seed: 5,
            batch: 1,
            segments: vec![(100, 200)],
        };
        let r = run_lookahead_sim(&cfg, &lat(), LookaheadMode::Static(0));
        let mut cache = ExpertCache::with_capacity(10);
        let mut trace = DriftingExpertTrace::new(4, 8, 2, 100, 5);
        let base = crate::expertcache::sim::run_cache_sim(&mut cache, &mut trace, 200, &lat());
        assert_eq!(r.mean_step_us, base.mean_step_us);
        assert_eq!(r.hit_rate, base.hit_rate);
    }

    #[test]
    fn sim_is_deterministic() {
        let cfg = bench_workload(9, 60);
        let a = run_lookahead_sim(&cfg, &lat(), LookaheadMode::Adaptive { start: 1, max: 2 });
        let b = run_lookahead_sim(&cfg, &lat(), LookaheadMode::Adaptive { start: 1, max: 2 });
        assert_eq!(a.mean_step_us, b.mean_step_us);
        assert_eq!(a.adjustments, b.adjustments);
        assert_eq!(a.final_lookahead, b.final_lookahead);
    }

    #[test]
    fn prefetch_window_pays_on_the_stable_segment() {
        // On a stable trace the learned shifts predict exactly: a
        // one-layer window must land hits and beat no window.
        let cfg = LookaheadSimConfig {
            capacity: 24,
            layers: 8,
            experts: 16,
            top_k: 2,
            seed: 3,
            batch: 16,
            segments: vec![(10_000, 150)],
        };
        let w0 = run_lookahead_sim(&cfg, &lat(), LookaheadMode::Static(0));
        let w1 = run_lookahead_sim(&cfg, &lat(), LookaheadMode::Static(1));
        assert!(w1.prefetch_hits > 0);
        assert!(
            w1.mean_step_us < w0.mean_step_us,
            "window did not pay on a stable trace: W1 {:.0}us !< W0 {:.0}us",
            w1.mean_step_us,
            w0.mean_step_us
        );
    }

    #[test]
    fn adaptive_tracks_the_best_static_window() {
        // The BENCH_PR10 shape: the static sweep spreads materially and
        // the controller — which never sees the sweep — must land within
        // a few percent of its winner while strictly beating both
        // non-optimal windows.
        let cfg = bench_workload(9, 150);
        let l = lat();
        let statics: Vec<LookaheadSimReport> = (0..=2)
            .map(|w| run_lookahead_sim(&cfg, &l, LookaheadMode::Static(w)))
            .collect();
        let adaptive =
            run_lookahead_sim(&cfg, &l, LookaheadMode::Adaptive { start: 1, max: 2 });
        let best = statics
            .iter()
            .min_by(|a, b| a.mean_step_us.total_cmp(&b.mean_step_us))
            .unwrap();
        let worst = statics
            .iter()
            .max_by(|a, b| a.mean_step_us.total_cmp(&b.mean_step_us))
            .unwrap();
        assert!(
            worst.mean_step_us > best.mean_step_us * 1.05,
            "static sweep spread is immaterial: {} {:.0}us vs {} {:.0}us",
            worst.mode,
            worst.mean_step_us,
            best.mode,
            best.mean_step_us
        );
        assert!(
            adaptive.mean_step_us <= best.mean_step_us * 1.05,
            "adaptive {:.0}us not within 5% of best static ({}) {:.0}us",
            adaptive.mean_step_us,
            best.mode,
            best.mean_step_us
        );
        for s in statics.iter().filter(|s| s.mode != best.mode) {
            assert!(
                adaptive.mean_step_us < s.mean_step_us,
                "adaptive {:.0}us does not beat {} {:.0}us",
                adaptive.mean_step_us,
                s.mode,
                s.mean_step_us
            );
        }
        // By the drift segment the controller has settled on the paying
        // window: adaptive matches the best static drift-phase time.
        let best_drift = statics
            .iter()
            .map(|s| s.segment_step_us[1])
            .fold(f64::INFINITY, f64::min);
        assert!(
            adaptive.segment_step_us[1] <= best_drift * 1.001,
            "adaptive drift {:.0}us worse than best static drift {:.0}us",
            adaptive.segment_step_us[1],
            best_drift
        );
        assert!(adaptive.adjustments > 0, "controller never moved");
    }
}
