//! Adaptive control plane — deterministic, virtual-time-driven feedback
//! loops that retune the pipeline online (`--adaptive on`; ROADMAP item 5).
//!
//! Every knob this module adjusts is static at startup without it:
//! `--pipeline-lookahead` (one global window for three very different pass
//! kinds), the eviction scoring (blind to what the PCIe lane just paid
//! for), the in-flight override pricing (blind to which *sequence* in a
//! batch wants an expert), and the `slo` admission deadline (a prior the
//! workload immediately falsifies).  The four loops:
//!
//! 1. **Per-phase lookahead learning** ([`LookaheadController`]): one
//!    hill-climbing controller per pass kind (prefill / chunked
//!    continuation / decode — `PipelineState`'s existing `kind_idx`
//!    split), fed per-pass reward windows from
//!    [`crate::moe::ExpertEvents::delta_since`]-style counter deltas
//!    (prefetch hits + overlapped overrides, minus wasted transfers).
//! 2. **Prefetch-aware eviction**: [`crate::expertcache::ExpertCache`]
//!    charges a landing-cost penalty so a copy the window just paid PCIe
//!    for is not evicted before its predicted-use layer arrives
//!    (`ExpertCache::set_landing_protection`; armed only under
//!    `--adaptive on`).
//! 3. **Per-sequence routing-skew overrides** ([`SkewTracker`]): batched
//!    decode tracks which batch row routed to which expert last step, so
//!    the in-flight override pricing can bias against demand-admitting an
//!    expert only one hot-routed sequence wants and no row will reuse.
//! 4. **Admission SLO feedback** ([`SloEstimator`]): the `slo` admission
//!    policy's TTFT/ITL estimates update from measured retire-time
//!    [`crate::metrics::GenMetrics`] (EWMA) instead of trusting the
//!    static `--slo-ttft-ms` prior forever.
//!
//! Determinism contract: every input is a virtual-time counter (cache
//! stats, expert events, virtual-µs metrics) — never the wall clock —
//! and every decision is emitted as a trace event
//! (`controller_adjusted`, `slo_estimate_updated`), so an adaptive run
//! records→replays bit-identically.  With `--adaptive off` nothing in
//! this module is constructed and the engine is bit-identical to the
//! static pipeline (property-tested in `rust/tests/control.rs`).

pub mod sim;

/// EWMA whose first observation *seeds* the estimate directly instead of
/// blending with a zero initial value (the cold-start bug class the
/// pipeline's gap estimate must avoid: blending the first layer gap with
/// 0.0 would underestimate lead time for the whole first window and
/// suppress early profitable prefetches).
#[derive(Clone, Copy, Debug)]
pub struct SeededEwma {
    decay: f64,
    alpha: f64,
    value: Option<f64>,
}

impl SeededEwma {
    /// `alpha` is the weight of each new sample (`v = (1-a)*v + a*x`).
    pub fn new(alpha: f64) -> SeededEwma {
        SeededEwma::with_weights(1.0 - alpha, alpha)
    }

    /// Explicit old/new weights.  Callers that must stay bit-identical
    /// with a legacy `D*v + A*x` update pass both literals: `1.0 - 0.3`
    /// is NOT the same double as `0.7`.
    pub fn with_weights(decay: f64, alpha: f64) -> SeededEwma {
        SeededEwma { decay, alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x, // seed, don't blend with an implicit 0
            Some(v) => self.decay * v + self.alpha * x,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn value_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Pass-kind labels, indexed by `ForwardKind::idx()` (prefill /
/// chunked-continuation / decode) — the strings `controller_adjusted`
/// events and `trace-summary` print.
pub const KIND_LABELS: [&str; 3] = ["prefill", "chunk", "decode"];

/// Passes per reward window: the controller only moves after this many
/// passes of a kind have accumulated counters (smooths the hit/waste lag
/// of in-flight transfers).
pub const WINDOW_PASSES: usize = 4;

/// Hard ceiling on any learned lookahead window (beyond ~4 layers the
/// transition-chain predictions are noise-level; see
/// `PipelineState::predict`'s confidence floor).
pub const MAX_LOOKAHEAD: usize = 4;

/// Direction flips before the controller settles on the best window seen
/// (pure hill climbing oscillates ±1 around a noiseless optimum forever).
const SETTLE_FLIPS: u32 = 2;

/// Fractional reward drop that re-opens exploration from the held
/// setting (workload drift detection).
const RELEASE_FRACTION: f64 = 0.25;

/// One committed controller move (for the `controller_adjusted` event).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Adjustment {
    /// The newly effective lookahead window.
    pub lookahead: usize,
    /// The reward of the window that triggered the move.
    pub reward: f64,
    /// Total moves this phase's controller has committed.
    pub adjustments: u64,
}

#[derive(Clone, Debug)]
struct PhaseCtl {
    lookahead: usize,
    dir: isize,
    last_reward: Option<f64>,
    flips: u32,
    /// Best (lookahead, reward) window seen since exploration opened.
    best: Option<(usize, f64)>,
    /// Settled: hold `lookahead` until reward degrades past the release
    /// threshold.
    held: bool,
    hold_reward: f64,
    acc_overlapped: u64,
    acc_hits: u64,
    acc_wasted: u64,
    passes: usize,
    adjustments: u64,
}

impl PhaseCtl {
    fn new(lookahead: usize) -> PhaseCtl {
        PhaseCtl {
            lookahead,
            dir: 1,
            last_reward: None,
            flips: 0,
            best: None,
            held: false,
            hold_reward: 0.0,
            acc_overlapped: 0,
            acc_hits: 0,
            acc_wasted: 0,
            passes: 0,
            adjustments: 0,
        }
    }
}

/// Loop 1: per-pass-kind hill-climbing lookahead controller.
///
/// Each pass feeds its counter deltas (`on_pass`); every
/// [`WINDOW_PASSES`] passes of a kind close a reward window
/// (`hits + overlapped - wasted`) and the controller climbs: keep
/// direction while reward improves, flip when it degrades, and after
/// [`SETTLE_FLIPS`] flips settle on the best window seen (hill climbing
/// would otherwise oscillate ±1 around the optimum forever).  A held
/// setting re-opens exploration when its reward drops by
/// [`RELEASE_FRACTION`] — that is what makes the controller *track
/// drift* instead of converging once.
#[derive(Clone, Debug)]
pub struct LookaheadController {
    phases: [PhaseCtl; 3],
    min: usize,
    max: usize,
    window: usize,
}

impl LookaheadController {
    /// Engine-path controller: every phase starts at the configured
    /// `--pipeline-lookahead`, exploring in `[1, min(base+2, 4)]` — the
    /// floor of 1 keeps the pipeline observing (a window of 0 would blind
    /// the reward signal and the controller could never recover).
    pub fn new(base: usize) -> LookaheadController {
        let b = base.clamp(1, MAX_LOOKAHEAD);
        Self::with_range(b, 1, (b + 2).min(MAX_LOOKAHEAD))
    }

    /// Controller with an explicit exploration range (the trace-driven
    /// sim allows 0 — it has no in-band reward signal to lose).
    pub fn with_range(base: usize, min: usize, max: usize) -> LookaheadController {
        let max = max.max(min);
        let base = base.clamp(min, max);
        LookaheadController {
            phases: [PhaseCtl::new(base), PhaseCtl::new(base), PhaseCtl::new(base)],
            min,
            max,
            window: WINDOW_PASSES,
        }
    }

    /// Effective lookahead for a pass kind right now.
    pub fn lookahead(&self, kind_idx: usize) -> usize {
        self.phases[kind_idx].lookahead
    }

    /// Committed moves for a pass kind.
    pub fn adjustments(&self, kind_idx: usize) -> u64 {
        self.phases[kind_idx].adjustments
    }

    /// Whether a phase has settled (stopped exploring).
    pub fn is_held(&self, kind_idx: usize) -> bool {
        self.phases[kind_idx].held
    }

    /// Feed one pass's counter deltas for `kind_idx`: prefetch-overlapped
    /// overrides, prefetch hits, and wasted transfers (issued minus hit).
    /// Returns the committed move when a reward window closed and changed
    /// the effective lookahead.
    pub fn on_pass(
        &mut self,
        kind_idx: usize,
        overlapped: u64,
        hits: u64,
        wasted: u64,
    ) -> Option<Adjustment> {
        let p = &mut self.phases[kind_idx];
        p.acc_overlapped += overlapped;
        p.acc_hits += hits;
        p.acc_wasted += wasted;
        p.passes += 1;
        if p.passes < self.window {
            return None;
        }
        let reward = (p.acc_hits + p.acc_overlapped) as f64 - p.acc_wasted as f64;
        p.acc_overlapped = 0;
        p.acc_hits = 0;
        p.acc_wasted = 0;
        p.passes = 0;

        if p.best.map(|(_, r)| reward > r).unwrap_or(true) {
            p.best = Some((p.lookahead, reward));
        }
        if p.held {
            let release = p.hold_reward - RELEASE_FRACTION * p.hold_reward.abs().max(1.0);
            if reward >= release {
                return None; // still paying: hold
            }
            // Drift: the held setting degraded — explore again from here.
            p.held = false;
            p.flips = 0;
            p.best = Some((p.lookahead, reward));
            p.last_reward = Some(reward);
            return self.step_phase(kind_idx);
        }
        let prev = p.last_reward.replace(reward);
        if let Some(prev) = prev {
            if reward + 1e-9 < prev {
                p.dir = -p.dir;
                p.flips += 1;
            }
            if p.flips >= SETTLE_FLIPS {
                // Oscillating around the optimum: settle on the best seen.
                let (best_w, best_r) = p.best.expect("best tracked above");
                p.held = true;
                p.hold_reward = best_r;
                if best_w != p.lookahead {
                    p.lookahead = best_w;
                    p.adjustments += 1;
                    return Some(Adjustment {
                        lookahead: best_w,
                        reward,
                        adjustments: p.adjustments,
                    });
                }
                return None;
            }
        }
        self.step_phase(kind_idx)
    }

    fn step_phase(&mut self, kind_idx: usize) -> Option<Adjustment> {
        let (min, max) = (self.min as isize, self.max as isize);
        let p = &mut self.phases[kind_idx];
        let next = (p.lookahead as isize + p.dir).clamp(min, max) as usize;
        if next == p.lookahead {
            // Range boundary: bounce (counts toward settling).
            p.dir = -p.dir;
            p.flips += 1;
            return None;
        }
        p.lookahead = next;
        p.adjustments += 1;
        Some(Adjustment {
            lookahead: next,
            reward: p.last_reward.unwrap_or(0.0),
            adjustments: p.adjustments,
        })
    }
}

/// Loop 3: per-sequence routing history for batched decode.
///
/// Rows are batch positions (the pipeline's unit of sequence identity —
/// positional, so a retire mid-stream shifts attribution for one step;
/// the signal is a heuristic bias, not an invariant).  `repeated` answers
/// "did this row route to this expert at this layer on the previous
/// decode step?" — a row with no repeat is showing one-off skew, and an
/// in-flight override should win against demand-admitting for it alone.
#[derive(Debug, Default)]
pub struct SkewTracker {
    active: bool,
    /// `prev[row][layer]` = experts the row routed to last decode step.
    prev: Vec<Vec<Vec<usize>>>,
    cur: Vec<Vec<Vec<usize>>>,
}

impl SkewTracker {
    pub fn new() -> SkewTracker {
        SkewTracker::default()
    }

    /// Start a decode step with `batch` rows: last step's recordings
    /// become the lookup side.
    pub fn begin_step(&mut self, batch: usize) {
        std::mem::swap(&mut self.prev, &mut self.cur);
        self.cur.clear();
        self.cur.resize(batch, Vec::new());
        self.active = true;
    }

    /// Non-decode passes interleave between steps; their routing is
    /// neither recorded nor consulted.
    pub fn set_inactive(&mut self) {
        self.active = false;
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn record(&mut self, row: usize, layer: usize, expert: usize) {
        if !self.active {
            return;
        }
        let Some(r) = self.cur.get_mut(row) else { return };
        if r.len() <= layer {
            r.resize(layer + 1, Vec::new());
        }
        r[layer].push(expert);
    }

    /// Did `row` route to `expert` at `layer` on the previous step?
    pub fn repeated(&self, row: usize, layer: usize, expert: usize) -> bool {
        self.prev
            .get(row)
            .and_then(|r| r.get(layer))
            .map(|experts| experts.contains(&expert))
            .unwrap_or(false)
    }
}

/// Kept-plan cost multiplier when an expert's demand comes from a single
/// batch row with no cross-step reuse: the override (waiting out the
/// in-flight copy) is favored over a demand admit the batch won't reuse.
pub const SKEW_OVERRIDE_BIAS: f64 = 1.25;

/// Measured samples before the learned TTFT budget replaces the
/// `--slo-ttft-ms` prior.
pub const SLO_MIN_SAMPLES: u64 = 3;

/// Deadline margin over the learned TTFT estimate.
pub const SLO_MARGIN: f64 = 2.0;

/// Smoothing weight of each retired request's measurements.
const SLO_ALPHA: f64 = 0.2;

/// Loop 4: the `slo` admission policy's learned TTFT/ITL estimates,
/// updated from measured per-request outcomes at retire time.
///
/// Until [`SLO_MIN_SAMPLES`] requests have retired the static prior
/// stands; after that the default deadline becomes
/// `SLO_MARGIN * ttft_estimate`, clamped to `[prior/4, 4*prior]` so a
/// burst of anomalous retirements can never collapse or explode
/// admission.  All inputs are virtual-µs [`crate::metrics::GenMetrics`]
/// fields — replay reproduces the estimator exactly.
#[derive(Clone, Debug)]
pub struct SloEstimator {
    prior_ttft_us: f64,
    ttft_us: SeededEwma,
    itl_us: SeededEwma,
    samples: u64,
}

impl SloEstimator {
    pub fn new(prior_ttft_us: f64) -> SloEstimator {
        SloEstimator {
            prior_ttft_us,
            ttft_us: SeededEwma::new(SLO_ALPHA),
            itl_us: SeededEwma::new(SLO_ALPHA),
            samples: 0,
        }
    }

    /// Absorb one retired request's measured TTFT and mean ITL (µs).
    pub fn observe(&mut self, ttft_us: f64, mean_itl_us: f64) {
        if ttft_us.is_finite() && ttft_us > 0.0 {
            self.ttft_us.observe(ttft_us);
        }
        if mean_itl_us.is_finite() && mean_itl_us > 0.0 {
            self.itl_us.observe(mean_itl_us);
        }
        self.samples += 1;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Current TTFT estimate (µs); the prior until a sample lands.
    pub fn ttft_est_us(&self) -> f64 {
        self.ttft_us.value_or(self.prior_ttft_us)
    }

    /// Current mean-ITL estimate (µs); 0 until a sample lands.
    pub fn itl_est_us(&self) -> f64 {
        self.itl_us.value_or(0.0)
    }

    /// The default deadline budget (µs from enqueue) for requests without
    /// an explicit SLO: the prior until warmed up, then the learned
    /// estimate with margin, clamped around the prior.
    pub fn ttft_budget_us(&self) -> f64 {
        let prior = self.prior_ttft_us;
        if self.samples < SLO_MIN_SAMPLES {
            return prior;
        }
        let learned = SLO_MARGIN * self.ttft_est_us();
        if prior > 0.0 {
            learned.clamp(0.25 * prior, 4.0 * prior)
        } else {
            learned
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_ewma_seeds_then_blends() {
        let mut e = SeededEwma::new(0.3);
        assert_eq!(e.get(), None);
        assert_eq!(e.value_or(7.0), 7.0);
        e.observe(100.0);
        assert_eq!(e.get(), Some(100.0), "first sample must seed, not blend with 0");
        e.observe(200.0);
        let v = e.get().unwrap();
        assert!((v - 130.0).abs() < 1e-9, "0.7*100 + 0.3*200, got {v}");
    }

    /// Drive one phase through reward windows of a synthetic reward
    /// function; returns (lookahead, adjustments) after `windows`.
    fn climb(f: impl Fn(usize) -> f64, windows: usize, range: (usize, usize, usize)) -> (usize, u64) {
        let mut c = LookaheadController::with_range(range.0, range.1, range.2);
        for _ in 0..windows {
            let w = c.lookahead(2);
            let r = f(w);
            // Encode the reward as hit counts (reward = hits - wasted).
            let (hits, wasted) =
                if r >= 0.0 { (r as u64, 0u64) } else { (0u64, (-r) as u64) };
            for _ in 0..WINDOW_PASSES {
                c.on_pass(2, 0, hits, wasted);
            }
        }
        (c.lookahead(2), c.adjustments(2))
    }

    #[test]
    fn controller_converges_on_stationary_reward_and_stops_oscillating() {
        // Concave reward peaked at W=2: the controller must find it,
        // settle, and commit no further moves.
        let f = |w: usize| 16.0 - 4.0 * (w as f64 - 2.0) * (w as f64 - 2.0);
        let (w8, adj8) = climb(f, 8, (1, 0, 4));
        assert_eq!(w8, 2, "did not converge to the reward peak");
        let (w40, adj40) = climb(f, 40, (1, 0, 4));
        assert_eq!(w40, 2, "left the peak after converging");
        assert_eq!(adj8, adj40, "kept adjusting on a stationary workload");
    }

    #[test]
    fn controller_tracks_a_reward_shift() {
        // Peak moves from W=3 to W=1 mid-run: a settled controller must
        // release its hold and re-converge.
        let mut c = LookaheadController::with_range(1, 0, 4);
        let run = |c: &mut LookaheadController, peak: f64, windows: usize| {
            for _ in 0..windows {
                let w = c.lookahead(2) as f64;
                let r = 16.0 - 4.0 * (w - peak) * (w - peak);
                let (hits, wasted) =
                    if r >= 0.0 { (r as u64, 0u64) } else { (0u64, (-r) as u64) };
                for _ in 0..WINDOW_PASSES {
                    c.on_pass(2, 0, hits, wasted);
                }
            }
        };
        run(&mut c, 3.0, 12);
        assert_eq!(c.lookahead(2), 3);
        assert!(c.is_held(2), "should settle on the stationary phase");
        run(&mut c, 1.0, 12);
        assert_eq!(c.lookahead(2), 1, "did not track the drifted peak");
    }

    #[test]
    fn controller_phases_are_independent() {
        let mut c = LookaheadController::new(2);
        for _ in 0..(3 * WINDOW_PASSES) {
            // Decode: waste grows with the window — climb down.
            let wasted = 10 * c.lookahead(2) as u64;
            c.on_pass(2, 0, 0, wasted);
        }
        assert!(c.lookahead(2) < 2);
        assert_eq!(c.lookahead(0), 2, "prefill phase must be untouched");
        assert_eq!(c.adjustments(0), 0);
    }

    #[test]
    fn engine_controller_floors_at_one() {
        let mut c = LookaheadController::new(1);
        for _ in 0..(20 * WINDOW_PASSES) {
            c.on_pass(2, 0, 0, 50);
        }
        assert!(c.lookahead(2) >= 1, "engine floor keeps the pipeline observing");
    }

    #[test]
    fn skew_tracker_tracks_per_row_repeats() {
        let mut sk = SkewTracker::new();
        assert!(!sk.repeated(0, 0, 3));
        sk.begin_step(2);
        sk.record(0, 1, 3);
        sk.record(1, 1, 5);
        // Current-step recordings are not visible until the next step.
        assert!(!sk.repeated(0, 1, 3));
        sk.begin_step(2);
        assert!(sk.repeated(0, 1, 3));
        assert!(sk.repeated(1, 1, 5));
        assert!(!sk.repeated(0, 1, 5), "row attribution must not leak across rows");
        assert!(!sk.repeated(0, 0, 3), "layer attribution must not leak across layers");
        // Inactive (non-decode pass): neither records nor matches.
        sk.set_inactive();
        sk.record(0, 1, 7);
        sk.begin_step(2);
        assert!(!sk.repeated(0, 1, 7));
    }

    #[test]
    fn slo_estimator_warms_up_then_clamps() {
        let prior = 250_000.0; // 250 ms in µs
        let mut e = SloEstimator::new(prior);
        assert_eq!(e.ttft_budget_us(), prior, "prior stands before any sample");
        e.observe(10_000.0, 500.0);
        e.observe(10_000.0, 500.0);
        assert_eq!(e.ttft_budget_us(), prior, "prior stands below SLO_MIN_SAMPLES");
        e.observe(10_000.0, 500.0);
        // Learned 2*10ms = 20ms, clamped up to prior/4 = 62.5ms.
        assert_eq!(e.ttft_budget_us(), 0.25 * prior);
        let mut slow = SloEstimator::new(prior);
        for _ in 0..SLO_MIN_SAMPLES {
            slow.observe(10_000_000.0, 500.0);
        }
        assert_eq!(slow.ttft_budget_us(), 4.0 * prior, "clamped above 4x prior");
        let mut mid = SloEstimator::new(prior);
        for _ in 0..SLO_MIN_SAMPLES {
            mid.observe(200_000.0, 500.0);
        }
        assert_eq!(mid.ttft_budget_us(), SLO_MARGIN * 200_000.0);
        assert!(mid.itl_est_us() > 0.0);
    }
}
