//! Expert-popularity profiling (paper §3.4 + Appendix C).
//!
//! Popularity is the per-(layer, expert) count of tokens routed to that
//! expert on calibration data.  Sources:
//!
//! * the offline profile computed at build time by `python/compile/analysis.py`
//!   (loaded from `artifacts/<model>/analysis/analysis.json`), or
//! * online profiling: [`Profile::record`] calls from the engine.
//!
//! Also hosts the Appendix-C hit-rate analysis (expected hit rate of the
//! best / worst / random placement under the profile).

use crate::util::json::{self, Json};
use anyhow::Result;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Profile {
    pub n_layers: usize,
    pub n_experts: usize,
    /// counts[layer][expert]
    pub counts: Vec<Vec<u64>>,
}

impl Profile {
    pub fn new(n_layers: usize, n_experts: usize) -> Profile {
        Profile { n_layers, n_experts, counts: vec![vec![0; n_experts]; n_layers] }
    }

    /// Load the build-time profile from the analysis JSON.
    pub fn load(analysis_path: impl AsRef<Path>) -> Result<Profile> {
        let v = json::load(analysis_path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Profile> {
        let rows = v.get("popularity_counts")?.as_arr()?;
        let counts: Vec<Vec<u64>> = rows
            .iter()
            .map(|r| {
                Ok(r.as_arr()?
                    .iter()
                    .map(|c| Ok(c.as_f64()? as u64))
                    .collect::<Result<Vec<u64>>>()?)
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!counts.is_empty(), "empty popularity profile");
        let n_experts = counts[0].len();
        anyhow::ensure!(
            counts.iter().all(|r| r.len() == n_experts),
            "ragged popularity profile"
        );
        Ok(Profile { n_layers: counts.len(), n_experts, counts })
    }

    pub fn record(&mut self, layer: usize, expert: usize, tokens: u64) {
        self.counts[layer][expert] += tokens;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().flatten().sum()
    }

    /// All experts sorted by popularity, most popular first; ties broken by
    /// (layer, expert) for determinism.
    pub fn ranked(&self) -> Vec<(usize, usize)> {
        let mut ids: Vec<(usize, usize)> = (0..self.n_layers)
            .flat_map(|l| (0..self.n_experts).map(move |e| (l, e)))
            .collect();
        ids.sort_by_key(|&(l, e)| (std::cmp::Reverse(self.counts[l][e]), l, e));
        ids
    }

    /// Normalized popularity (most popular = 1.0), like the paper's Fig. 8.
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        let maxc = self.counts.iter().flatten().copied().max().unwrap_or(1).max(1);
        self.counts
            .iter()
            .map(|row| row.iter().map(|&c| c as f64 / maxc as f64).collect())
            .collect()
    }

    /// Expected hit rate when the given experts are on the GPU: the
    /// probability that a routed token finds its expert resident, weighted
    /// by the profile (Appendix C).
    pub fn expected_hit_rate(&self, resident: &[(usize, usize)]) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let hit: u64 = resident.iter().map(|&(l, e)| self.counts[l][e]).sum();
        hit as f64 / total as f64
    }

    /// Per-(layer, expert) replica counts for fleet serving: an expert
    /// whose share of total routed tokens exceeds `hot_fraction` is
    /// replicated onto `ceil(share / hot_fraction)` engines (capped at
    /// `max_replicas`, i.e. the shard count); everything else keeps one
    /// replica.  `hot_fraction <= 0` disables replication entirely.
    pub fn replica_counts(&self, hot_fraction: f64, max_replicas: usize) -> Vec<Vec<usize>> {
        let total = self.total();
        let max_replicas = max_replicas.max(1);
        self.counts
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&c| {
                        if hot_fraction <= 0.0 || total == 0 {
                            return 1;
                        }
                        let share = c as f64 / total as f64;
                        if share > hot_fraction {
                            ((share / hot_fraction).ceil() as usize).clamp(1, max_replicas)
                        } else {
                            1
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Appendix-C style analysis for a capacity: (best, worst, random)
    /// expected hit rates.
    pub fn hit_rate_analysis(&self, capacity: usize) -> (f64, f64, f64) {
        let ranked = self.ranked();
        let k = capacity.min(ranked.len());
        let best: Vec<_> = ranked[..k].to_vec();
        let worst: Vec<_> = ranked[ranked.len() - k..].to_vec();
        let random = k as f64 / ranked.len() as f64; // expectation over uniform draws
        (
            self.expected_hit_rate(&best),
            self.expected_hit_rate(&worst),
            random,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> Profile {
        let mut p = Profile::new(2, 4);
        // layer 0: expert 0 hot; layer 1: expert 3 hot
        p.counts[0] = vec![100, 10, 10, 10];
        p.counts[1] = vec![5, 5, 5, 85];
        p
    }

    #[test]
    fn ranked_orders_by_count() {
        let p = profile();
        let r = p.ranked();
        assert_eq!(r[0], (0, 0));
        assert_eq!(r[1], (1, 3));
        assert_eq!(r.len(), 8);
    }

    #[test]
    fn hit_rates_ordered_best_random_worst() {
        let p = profile();
        let (best, worst, random) = p.hit_rate_analysis(2);
        assert!(best > random, "best {best} <= random {random}");
        assert!(random > worst, "random {random} <= worst {worst}");
        // best 2 = 100 + 85 = 185 of 230
        assert!((best - 185.0 / 230.0).abs() < 1e-9);
    }

    #[test]
    fn record_accumulates() {
        let mut p = Profile::new(1, 2);
        p.record(0, 1, 5);
        p.record(0, 1, 2);
        assert_eq!(p.counts[0][1], 7);
        assert_eq!(p.total(), 7);
    }

    #[test]
    fn normalized_max_is_one() {
        let p = profile();
        let n = p.normalized();
        let flat: Vec<f64> = n.iter().flatten().copied().collect();
        assert!((flat.iter().cloned().fold(0.0, f64::max) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn replica_counts_scale_with_share() {
        let p = profile(); // totals 230; (0,0)=100 → share ~0.435
        let r = p.replica_counts(0.25, 4);
        assert_eq!(r[0][0], 2, "share 0.435 / 0.25 → 2 replicas");
        assert_eq!(r[0][1], 1, "cold expert keeps one replica");
        assert_eq!(r[1][3], 2, "share 0.370 / 0.25 → 2 replicas");
        // Cap at the shard count.
        let r = p.replica_counts(0.05, 3);
        assert_eq!(r[0][0], 3);
        // Disabled / empty profiles never replicate.
        assert!(p.replica_counts(0.0, 4).iter().flatten().all(|&n| n == 1));
        assert!(Profile::new(1, 2).replica_counts(0.25, 4).iter().flatten().all(|&n| n == 1));
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(r#"{"popularity_counts": [[1, 2], [3, 4]]}"#).unwrap();
        let p = Profile::from_json(&j).unwrap();
        assert_eq!(p.n_layers, 2);
        assert_eq!(p.counts[1][0], 3);
    }
}
