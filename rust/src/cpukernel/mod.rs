//! Host-side expert FFN kernel — the stand-in for the paper's specialized
//! AVX512_BF16 CPU kernel (§3.4).
//!
//! The paper's point is that the CPU path deserves a dedicated kernel
//! rather than the framework default.  Here the "framework default" is the
//! XLA executable (which is fine numerically but pays per-call dispatch),
//! and this module is the dedicated kernel: a cache-blocked f32 GEMM
//! fused with the SiLU gate, operating directly on the weight store's
//! buffers with zero dispatch overhead.  `rustc`'s auto-vectorizer emits
//! the SIMD (the image has no AVX512_BF16; see DESIGN.md §2).
//!
//! It is validated against the HLO expert op (tests below) and used by the
//! engine for `ExpertPlan::Cpu` executions when
//! `FIDDLER_HOST_KERNEL=1` (the perf pass measures both paths).

use crate::runtime::Tensor;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Blocked matmul-accumulate: `out[m][n] += a[m][k] * b[k][n]`.
/// Row-major; blocks sized for L1/L2 residency of the b-panel.
fn gemm_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    const BK: usize = 64;
    const BN: usize = 128;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for n0 in (0..n).step_by(BN) {
            let n1 = (n0 + BN).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n1];
                    // Inner loop over a contiguous panel: auto-vectorizes.
                    for nn in n0..n1 {
                        orow[nn] += av * brow[nn];
                    }
                }
            }
        }
    }
}

/// Fused expert FFN on the host: `(silu(x @ w1) * (x @ w3)) @ w2`.
///
/// x: `[s, h]`, w1/w3: `[h, f]`, w2: `[f, h]` -> `[s, h]`.
pub fn expert_ffn_host(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    let (s, h) = (x.shape[0], x.shape[1]);
    let f = w1.shape[1];
    assert_eq!(w1.shape, vec![h, f], "w1 shape");
    assert_eq!(w3.shape, vec![h, f], "w3 shape");
    assert_eq!(w2.shape, vec![f, h], "w2 shape");

    // a = x @ w1 ; g = x @ w3
    let mut a = vec![0.0f32; s * f];
    let mut g = vec![0.0f32; s * f];
    gemm_acc(&x.data, &w1.data, &mut a, s, h, f);
    gemm_acc(&x.data, &w3.data, &mut g, s, h, f);
    // a = silu(a) * g   (the fused gate — one pass, no temporaries)
    for (av, gv) in a.iter_mut().zip(&g) {
        *av = silu(*av) * gv;
    }
    // y = a @ w2
    let mut y = vec![0.0f32; s * h];
    gemm_acc(&a, &w2.data, &mut y, s, f, h);
    Tensor { shape: vec![s, h], data: y }
}

/// Whether the engine should use this kernel for CPU-planned experts.
pub fn host_kernel_enabled() -> bool {
    std::env::var("FIDDLER_HOST_KERNEL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::runtime::Runtime;
    use crate::testkit::{check, Gen};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: (0..n).map(|_| (rng.normal() as f32) * scale).collect(),
        }
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let mut rng = Rng::new(0);
        let x = Tensor::zeros(vec![4, 8]);
        let w1 = rand_tensor(&mut rng, vec![8, 16], 0.1);
        let w3 = rand_tensor(&mut rng, vec![8, 16], 0.1);
        let w2 = rand_tensor(&mut rng, vec![16, 8], 0.1);
        let y = expert_ffn_host(&x, &w1, &w3, &w2);
        assert!(y.data.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn matches_naive_reference_property() {
        check("host kernel vs naive", 32, |g: &mut Gen| {
            let s = g.usize_in(1..9);
            let h = 2 * g.usize_in(1..9);
            let f = 2 * g.usize_in(1..17);
            let seed = g.u64();
            let mut rng = Rng::new(seed);
            let x = rand_tensor(&mut rng, vec![s, h], 0.5);
            let w1 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w3 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w2 = rand_tensor(&mut rng, vec![f, h], 0.2);
            let got = expert_ffn_host(&x, &w1, &w3, &w2);

            // Naive O(s*h*f) reference, no blocking.
            let mut want = Tensor::zeros(vec![s, h]);
            for i in 0..s {
                let mut act = vec![0.0f32; f];
                for j in 0..f {
                    let mut a = 0.0f32;
                    let mut b = 0.0f32;
                    for kk in 0..h {
                        a += x.data[i * h + kk] * w1.data[kk * f + j];
                        b += x.data[i * h + kk] * w3.data[kk * f + j];
                    }
                    act[j] = silu(a) * b;
                }
                for o in 0..h {
                    let mut y = 0.0f32;
                    for j in 0..f {
                        y += act[j] * w2.data[j * h + o];
                    }
                    want.data[i * h + o] = y;
                }
            }
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-4, "host kernel diverges from naive: {d}");
        });
    }

    #[test]
    fn matches_hlo_expert_op() {
        // The authoritative check: host kernel == the lowered Pallas kernel
        // through PJRT, on the real exported weights.
        let rt = Runtime::open(artifacts_root().join("mixtral-tiny"))
            .expect("make artifacts first");
        let ws = crate::runtime::WeightStore::load(artifacts_root().join("mixtral-tiny"))
            .unwrap();
        let mut rng = Rng::new(3);
        let h = ws.config.hidden;
        let x = rand_tensor(&mut rng, vec![4, h], 0.7);
        let (w1, w3, w2) = (ws.expert(1, 2, "w1"), ws.expert(1, 2, "w3"), ws.expert(1, 2, "w2"));

        let host = expert_ffn_host(&x, w1, w3, w2);
        let hlo = rt
            .execute(
                "expert_b4",
                &[x.into(), w1.clone().into(), w3.clone().into(), w2.clone().into()],
            )
            .unwrap();
        let d = host.max_abs_diff(&hlo[0]);
        assert!(d < 1e-3, "host kernel vs HLO: max|Δ|={d}");
    }
}
