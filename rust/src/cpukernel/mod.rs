//! Host-side expert FFN kernel — the stand-in for the paper's specialized
//! AVX512_BF16 CPU kernel (§3.4).
//!
//! The paper's point is that the CPU path deserves a dedicated kernel
//! rather than the framework default.  Here the "framework default" is the
//! XLA executable (which is fine numerically but pays per-call dispatch),
//! and this module is the dedicated kernel: a register-blocked f32 GEMM
//! over packed weight panels, fused with the SiLU gate, operating directly
//! on the weight store's buffers with zero dispatch overhead and — after
//! per-thread warmup — zero heap allocation in the hot loop (activations
//! and packed panels live in thread-local scratch).  `rustc`'s
//! auto-vectorizer emits the SIMD (the image has no AVX512_BF16; see
//! DESIGN.md §2).
//!
//! Determinism contract (relied on by `exec`'s intra-expert row
//! partitioning): every output element is accumulated in ascending-`k`
//! order starting from `+0.0`, by both the small-`m` streaming path and
//! the packed micro-kernel path, so a row's bits never depend on how many
//! rows share the call.
//!
//! It is validated against the naive reference and the HLO expert op
//! (tests below) and used by the engine for `ExpertPlan::Cpu` executions
//! when `FIDDLER_HOST_KERNEL=1` (the perf pass measures both paths).

use crate::runtime::Tensor;
use std::cell::RefCell;
use std::sync::OnceLock;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Micro-kernel row block (register tile height).
const MR: usize = 4;
/// Packed panel width (register tile width; 8 f32 = one AVX2 vector).
const NR: usize = 8;

/// Per-thread reusable buffers: gate/up activations + packed B panels.
/// Workers of the executor pool each get their own copy, so the parallel
/// hot loop stays allocation- and contention-free.
#[derive(Default)]
struct Scratch {
    act1: Vec<f32>,
    act3: Vec<f32>,
    bpack: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// Pack row-major `b` (`[k, n]`) into `NR`-wide column panels: panel `p`
/// holds columns `[p*NR, p*NR+NR)` contiguously per `k` row, zero-padded
/// at the right edge.  One linear write, then the micro-kernel reads each
/// panel sequentially instead of striding across `n`.
fn pack_b(b: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
    let panels = n.div_ceil(NR);
    out.clear();
    out.resize(panels * k * NR, 0.0);
    for p in 0..panels {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        let dst = &mut out[p * k * NR..(p + 1) * k * NR];
        for kk in 0..k {
            dst[kk * NR..kk * NR + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
}

/// `out = a @ b` for row-major `a [m,k]`, `b [k,n]`, `out [m,n]`.
///
/// Two regimes, bit-identical per element (both sum `a[i][kk]*b[kk][j]`
/// over ascending `kk` into a single f32 accumulator that starts at
/// `+0.0`):
///
/// * `m < MR` — streaming axpy (k-outer) over `b`'s rows: decode-size
///   inputs read every weight exactly once, no packing overhead;
/// * `m >= MR` — pack `b` into `NR` panels (thread-local scratch), then an
///   `MR x NR` register-blocked micro-kernel reuses each loaded `b` value
///   across `MR` rows.
fn gemm(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize, bpack: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert!(out.len() >= m * n);
    let out = &mut out[..m * n];
    out.fill(0.0);

    if m < MR {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                // Contiguous inner loop: auto-vectorizes.
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        return;
    }

    pack_b(b, k, n, bpack);
    let panels = n.div_ceil(NR);
    let mut i0 = 0;
    while i0 < m {
        let mr = MR.min(m - i0);
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let panel = &bpack[p * k * NR..(p + 1) * k * NR];
            // Register tile: accumulates the full k-reduction before one
            // store, ascending kk — the same addition sequence as the
            // small-m path.
            let mut acc = [[0.0f32; NR]; MR];
            for kk in 0..k {
                let brow = &panel[kk * NR..kk * NR + NR];
                for ii in 0..mr {
                    let av = a[(i0 + ii) * k + kk];
                    let accrow = &mut acc[ii];
                    for jj in 0..NR {
                        accrow[jj] += av * brow[jj];
                    }
                }
            }
            for ii in 0..mr {
                let orow = &mut out[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + w];
                orow.copy_from_slice(&acc[ii][..w]);
            }
        }
        i0 += mr;
    }
}

/// Fused expert FFN on the host into a caller-provided buffer:
/// `out = (silu(x @ w1) * (x @ w3)) @ w2`, with `out.len() == s * h`.
/// All intermediates live in thread-local scratch — after warmup the hot
/// loop performs zero heap allocation.
pub fn expert_ffn_host_into(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor, out: &mut [f32]) {
    let (s, h) = (x.shape[0], x.shape[1]);
    let f = w1.shape[1];
    assert_eq!(w1.shape, vec![h, f], "w1 shape");
    assert_eq!(w3.shape, vec![h, f], "w3 shape");
    assert_eq!(w2.shape, vec![f, h], "w2 shape");
    assert_eq!(out.len(), s * h, "output buffer size");

    SCRATCH.with(|cell| {
        let scratch = &mut *cell.borrow_mut();
        let Scratch { act1, act3, bpack } = scratch;
        if act1.len() < s * f {
            act1.resize(s * f, 0.0);
        }
        if act3.len() < s * f {
            act3.resize(s * f, 0.0);
        }
        let a = &mut act1[..s * f];
        let g = &mut act3[..s * f];
        // a = x @ w1 ; g = x @ w3
        gemm(&x.data, &w1.data, a, s, h, f, bpack);
        gemm(&x.data, &w3.data, g, s, h, f, bpack);
        // a = silu(a) * g   (the fused gate — one pass, no temporaries)
        for (av, gv) in a.iter_mut().zip(g.iter()) {
            *av = silu(*av) * *gv;
        }
        // out = a @ w2
        gemm(a, &w2.data, out, s, f, h, bpack);
    });
}

/// Fused expert FFN on the host: `(silu(x @ w1) * (x @ w3)) @ w2`.
///
/// x: `[s, h]`, w1/w3: `[h, f]`, w2: `[f, h]` -> `[s, h]`.
pub fn expert_ffn_host(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    let (s, h) = (x.shape[0], x.shape[1]);
    let mut y = vec![0.0f32; s * h];
    expert_ffn_host_into(x, w1, w3, w2, &mut y);
    Tensor { shape: vec![s, h], data: y }
}

/// Whether the engine should use this kernel for CPU-planned experts.
/// The env var is read once per process (it used to be a `getenv` syscall
/// per expert invocation in the layer hot loop).
pub fn host_kernel_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("FIDDLER_HOST_KERNEL").map(|v| v == "1").unwrap_or(false)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::artifacts_root;
    use crate::runtime::Runtime;
    use crate::testkit::{check, Gen};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let mut rng = Rng::new(0);
        let x = Tensor::zeros(vec![4, 8]);
        let w1 = rand_tensor(&mut rng, vec![8, 16], 0.1);
        let w3 = rand_tensor(&mut rng, vec![8, 16], 0.1);
        let w2 = rand_tensor(&mut rng, vec![16, 8], 0.1);
        let y = expert_ffn_host(&x, &w1, &w3, &w2);
        assert!(y.data.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn matches_naive_reference_property() {
        check("host kernel vs naive", 32, |g: &mut Gen| {
            let s = g.usize_in(1..9);
            let h = 2 * g.usize_in(1..9);
            let f = 2 * g.usize_in(1..17);
            let seed = g.u64();
            let mut rng = Rng::new(seed);
            let x = rand_tensor(&mut rng, vec![s, h], 0.5);
            let w1 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w3 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w2 = rand_tensor(&mut rng, vec![f, h], 0.2);
            let got = expert_ffn_host(&x, &w1, &w3, &w2);

            // Naive O(s*h*f) reference, no blocking.
            let mut want = Tensor::zeros(vec![s, h]);
            for i in 0..s {
                let mut act = vec![0.0f32; f];
                for j in 0..f {
                    let mut a = 0.0f32;
                    let mut b = 0.0f32;
                    for kk in 0..h {
                        a += x.data[i * h + kk] * w1.data[kk * f + j];
                        b += x.data[i * h + kk] * w3.data[kk * f + j];
                    }
                    act[j] = silu(a) * b;
                }
                for o in 0..h {
                    let mut y = 0.0f32;
                    for j in 0..f {
                        y += act[j] * w2.data[j * h + o];
                    }
                    want.data[i * h + o] = y;
                }
            }
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-4, "host kernel diverges from naive: {d}");
        });
    }

    /// The executor's load-bearing property: splitting rows across calls
    /// never changes a single bit of any output row (same-k-order
    /// accumulation in both gemm regimes).
    #[test]
    fn row_chunks_are_bitwise_invariant_property() {
        check("host kernel chunk invariance", 24, |g: &mut Gen| {
            let s = g.usize_in(2..40);
            let h = 2 * g.usize_in(1..13);
            let f = 2 * g.usize_in(1..21);
            let seed = g.u64();
            let mut rng = Rng::new(seed);
            let x = rand_tensor(&mut rng, vec![s, h], 0.5);
            let w1 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w3 = rand_tensor(&mut rng, vec![h, f], 0.2);
            let w2 = rand_tensor(&mut rng, vec![f, h], 0.2);
            let whole = expert_ffn_host(&x, &w1, &w3, &w2);

            // Random chunk boundaries, including chunks below MR (the
            // streaming regime) next to chunks above it (the packed one).
            let mut r0 = 0;
            let mut merged = vec![0.0f32; s * h];
            while r0 < s {
                let len = g.usize_in(1..6).min(s - r0);
                let chunk = Tensor {
                    shape: vec![len, h],
                    data: x.data[r0 * h..(r0 + len) * h].to_vec(),
                };
                let out = expert_ffn_host(&chunk, &w1, &w3, &w2);
                merged[r0 * h..(r0 + len) * h].copy_from_slice(&out.data);
                r0 += len;
            }
            for (i, (a, b)) in whole.data.iter().zip(&merged).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "bit mismatch at element {i}: {a} vs {b}"
                );
            }
        });
    }

    #[test]
    fn into_variant_matches_allocating_variant() {
        let mut rng = Rng::new(5);
        let x = rand_tensor(&mut rng, vec![6, 10], 0.5);
        let w1 = rand_tensor(&mut rng, vec![10, 14], 0.2);
        let w3 = rand_tensor(&mut rng, vec![10, 14], 0.2);
        let w2 = rand_tensor(&mut rng, vec![14, 10], 0.2);
        let t = expert_ffn_host(&x, &w1, &w3, &w2);
        let mut buf = vec![7.0f32; 6 * 10]; // dirty buffer must be overwritten
        expert_ffn_host_into(&x, &w1, &w3, &w2, &mut buf);
        assert_eq!(t.data, buf);
    }

    #[test]
    fn matches_hlo_expert_op() {
        // The authoritative check: host kernel == the lowered Pallas kernel
        // through PJRT, on the real exported weights.
        let rt = Runtime::open(artifacts_root().join("mixtral-tiny"))
            .expect("make artifacts first");
        let ws = crate::runtime::WeightStore::load(artifacts_root().join("mixtral-tiny"))
            .unwrap();
        let mut rng = Rng::new(3);
        let h = ws.config.hidden;
        let x = rand_tensor(&mut rng, vec![4, h], 0.7);
        let (w1, w3, w2) = (ws.expert(1, 2, "w1"), ws.expert(1, 2, "w3"), ws.expert(1, 2, "w2"));

        let host = expert_ffn_host(&x, w1, w3, w2);
        let hlo = rt
            .execute(
                "expert_b4",
                &[x.into(), w1.clone().into(), w3.clone().into(), w2.clone().into()],
            )
            .unwrap();
        let d = host.max_abs_diff(&hlo[0]);
        assert!(d < 1e-3, "host kernel vs HLO: max|Δ|={d}");
    }
}
