//! Speculative cross-layer expert prefetching — an extension beyond the
//! paper (its related work: MoE-Infinity's activation-aware offloading and
//! Mixtral-Offloading's speculative loading do this; Fiddler §5 leaves it
//! open).
//!
//! Offline, the calibration pass records cross-layer routing transitions:
//! `T[l][i][j]` = tokens routed to expert `i` at layer `l` AND expert `j`
//! at layer `l+1` (python/compile/analysis.py).  At runtime, once layer
//! `l`'s routing is known, the predictor scores layer-`l+1` experts by the
//! transition mass from the active experts and prefetches the top
//! predictions over PCIe, overlapping the transfer with layer `l`'s
//! compute.  A prefetched expert only counts as resident once its transfer
//! has *completed* on the (serialized) PCIe lane — modeled by per-expert
//! ready timestamps.

use crate::util::json::Json;
use anyhow::Result;

/// Cross-layer routing transition profile.
#[derive(Clone, Debug)]
pub struct TransitionProfile {
    pub n_layers: usize,
    pub n_experts: usize,
    /// counts[l][i][j], l in 0..n_layers-1
    pub counts: Vec<Vec<Vec<u64>>>,
}

impl TransitionProfile {
    pub fn from_json(v: &Json) -> Result<TransitionProfile> {
        let t = v.get("transition_counts")?.as_arr()?;
        let counts: Vec<Vec<Vec<u64>>> = t
            .iter()
            .map(|l| {
                Ok(l.as_arr()?
                    .iter()
                    .map(|r| {
                        Ok(r.as_arr()?
                            .iter()
                            .map(|c| Ok(c.as_f64()? as u64))
                            .collect::<Result<Vec<u64>>>()?)
                    })
                    .collect::<Result<Vec<_>>>()?)
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!counts.is_empty(), "empty transition profile");
        let n_experts = counts[0].len();
        Ok(TransitionProfile { n_layers: counts.len() + 1, n_experts, counts })
    }

    pub fn load(analysis_path: impl AsRef<std::path::Path>) -> Result<TransitionProfile> {
        let v = crate::util::json::load(analysis_path)?;
        Self::from_json(&v)
    }

    /// Uniform profile (predictor degenerates to popularity-free guessing);
    /// useful as a control in tests/ablations.
    pub fn uniform(n_layers: usize, n_experts: usize) -> TransitionProfile {
        TransitionProfile {
            n_layers,
            n_experts,
            counts: vec![vec![vec![1; n_experts]; n_experts]; n_layers - 1],
        }
    }

    /// Score layer-`l+1` experts given the active experts (with token
    /// counts) at layer `l`; returns expert indices sorted by descending
    /// predicted mass.
    pub fn predict_next(&self, layer: usize, inp_size: &[usize]) -> Vec<usize> {
        self.predict_ahead(layer, inp_size, 1)
    }

    /// One transition step: propagate an expert-mass vector from layer
    /// `layer` to layer `layer + 1`, normalized to unit sum (so chained
    /// propagation stays in range regardless of count magnitudes).
    pub fn propagate_mass(&self, layer: usize, mass: &[f64]) -> Vec<f64> {
        assert!(layer + 1 < self.n_layers, "no transitions out of the last layer");
        assert_eq!(mass.len(), self.n_experts);
        let t = &self.counts[layer];
        let mut score = vec![0f64; self.n_experts];
        for (i, &m) in mass.iter().enumerate() {
            if m <= 0.0 {
                continue;
            }
            for (j, sc) in score.iter_mut().enumerate() {
                *sc += m * t[i][j] as f64;
            }
        }
        let sum: f64 = score.iter().sum();
        if sum > 0.0 {
            for sc in score.iter_mut() {
                *sc /= sum;
            }
        }
        score
    }

    /// Predict the experts of layer `layer + d` from the routing observed
    /// at `layer`, chaining `d` transition steps (the pipelined layer
    /// executor's lookahead window); indices sorted by descending mass,
    /// ties by index.
    pub fn predict_ahead(&self, layer: usize, inp_size: &[usize], d: usize) -> Vec<usize> {
        assert!(d >= 1, "lookahead distance must be at least 1");
        assert!(layer + d < self.n_layers, "lookahead beyond the last layer");
        assert_eq!(inp_size.len(), self.n_experts);
        let mut mass: Vec<f64> = inp_size.iter().map(|&s| s as f64).collect();
        for step in 0..d {
            mass = self.propagate_mass(layer + step, &mass);
        }
        let mut idx: Vec<usize> = (0..self.n_experts).collect();
        idx.sort_by(|&a, &b| mass[b].total_cmp(&mass[a]).then(a.cmp(&b)));
        idx
    }

    /// Top-1 prediction accuracy against an observed (cur, next) routing
    /// pair — used by tests and the ablation driver.
    pub fn hits_in_top_m(&self, layer: usize, cur: &[usize], next: &[usize], m: usize) -> usize {
        let pred = self.predict_next(layer, cur);
        pred[..m.min(pred.len())]
            .iter()
            .filter(|&&j| next[j] > 0)
            .count()
    }
}

/// Fiddler + speculative next-layer prefetching.
///
/// Wraps the paper's policy; after layer `l`'s routing is known it issues
/// PCIe transfers for the top-`depth` predicted layer-`l+1` experts that
/// are not resident.  The serialized PCIe lane and per-expert transfer
/// completion timestamps live in [`crate::expertcache::ExpertCache`]
/// ([`ExpertCache::prefetch`](crate::expertcache::ExpertCache::prefetch));
/// a still-in-flight expert reads as non-resident, and Algorithm 1 falls
/// back to CPU or synchronous transfer as usual.
pub struct PrefetchingFiddlerPolicy {
    inner: crate::scheduler::policy::FiddlerPolicy,
    transitions: TransitionProfile,
    /// How many predicted experts to prefetch per layer.
    pub depth: usize,
}

impl PrefetchingFiddlerPolicy {
    pub fn new(transitions: TransitionProfile, depth: usize) -> Self {
        PrefetchingFiddlerPolicy {
            inner: crate::scheduler::policy::FiddlerPolicy::default(),
            transitions,
            depth,
        }
    }
}

impl crate::scheduler::policy::ExecPolicy for PrefetchingFiddlerPolicy {
    fn name(&self) -> &'static str {
        "fiddler-prefetch"
    }

    fn init(
        &mut self,
        memory: &mut crate::expertcache::ExpertCache,
        profile: &crate::popularity::Profile,
        seed: u64,
    ) {
        // This policy predates the cache's speculation budget and its
        // figures are reported with an unbounded transfer queue — keep
        // that model (fiddler-cached uses the default bounded lane).
        memory.max_lane_depth = f64::INFINITY;
        // Pin popular experts like Fiddler, but leave `2 * depth` unpinned
        // slots as the prefetch working set (a fully-pinned cache would
        // reject every speculative fetch).
        let reserve = (2 * self.depth).min(memory.capacity().saturating_sub(1));
        let chosen = crate::placement::choose_experts(
            profile,
            memory.capacity().saturating_sub(reserve),
            self.inner.placement,
            seed,
        );
        for id in chosen {
            memory.pin(id);
        }
    }

    fn plan_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut crate::expertcache::ExpertCache,
        lat: &crate::latency::LatencyModel,
        now_us: f64,
    ) -> Vec<Option<crate::scheduler::ExpertPlan>> {
        // Algorithm 1 as in plain Fiddler; the cache's completion
        // timestamps make in-flight prefetches read as misses.
        self.inner.plan_layer(layer, inp_size, memory, lat, now_us)
    }

    fn post_layer(
        &mut self,
        layer: usize,
        inp_size: &[usize],
        memory: &mut crate::expertcache::ExpertCache,
        lat: &crate::latency::LatencyModel,
        now_us: f64,
    ) {
        if layer + 1 >= self.transitions.n_layers {
            return;
        }
        let predictions = self.transitions.predict_next(layer, inp_size);
        for &j in predictions.iter().take(self.depth) {
            // Serialized PCIe lane, overlapping this layer's compute.
            let _ = memory.prefetch((layer + 1, j), now_us, lat.transfer_lat());
        }
    }

    fn expert_cost_us(
        &self,
        plan: crate::scheduler::ExpertPlan,
        s: usize,
        lat: &crate::latency::LatencyModel,
    ) -> f64 {
        self.inner.expert_cost_us(plan, s, lat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag_profile() -> TransitionProfile {
        // Expert i at layer l strongly predicts expert i at layer l+1.
        let e = 4;
        let mut counts = vec![vec![vec![1u64; e]; e]; 2];
        for l in 0..2 {
            for i in 0..e {
                counts[l][i][i] = 100;
            }
        }
        TransitionProfile { n_layers: 3, n_experts: e, counts }
    }

    #[test]
    fn predicts_diagonal() {
        let p = diag_profile();
        let pred = p.predict_next(0, &[5, 0, 0, 0]);
        assert_eq!(pred[0], 0);
        let pred = p.predict_next(1, &[0, 0, 3, 2]);
        assert!(pred[..2].contains(&2) && pred[..2].contains(&3));
    }

    #[test]
    fn uniform_profile_is_deterministic_order() {
        let p = TransitionProfile::uniform(3, 4);
        assert_eq!(p.predict_next(0, &[1, 1, 0, 0]), vec![0, 1, 2, 3]);
    }

    #[test]
    fn predict_ahead_chains_the_diagonal() {
        let p = diag_profile();
        // Strongly diagonal transitions: expert 1 active at layer 0
        // predicts expert 1 two layers out.
        let pred = p.predict_ahead(0, &[0, 6, 0, 0], 2);
        assert_eq!(pred[0], 1);
        // d = 1 must agree with predict_next exactly (same ordering).
        assert_eq!(p.predict_ahead(0, &[5, 0, 0, 0], 1), p.predict_next(0, &[5, 0, 0, 0]));
        // Always a permutation of the expert set.
        let mut sorted = pred.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn propagate_mass_normalizes() {
        let p = diag_profile();
        let m = p.propagate_mass(0, &[3.0, 0.0, 1.0, 0.0]);
        let sum: f64 = m.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(m[0] > m[1], "diagonal mass dominates");
        // All-zero mass stays all-zero (no NaN from the 0/0 guard).
        let z = p.propagate_mass(0, &[0.0; 4]);
        assert!(z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"transition_counts": [[[1, 2], [3, 4]], [[5, 6], [7, 8]]]}"#,
        )
        .unwrap();
        let p = TransitionProfile::from_json(&j).unwrap();
        assert_eq!(p.n_layers, 3);
        assert_eq!(p.n_experts, 2);
        assert_eq!(p.counts[1][1][0], 7);
    }

    #[test]
    fn hits_counts_overlap() {
        let p = diag_profile();
        let cur = [4, 0, 0, 0];
        let next = [1, 0, 0, 1];
        assert_eq!(p.hits_in_top_m(0, &cur, &next, 1), 1); // predicts 0, active
    }

    #[test]
    fn real_profile_beats_uniform_on_selfconsistency() {
        // The build-time profile must predict its own marginals better
        // than a uniform profile on skewed input.
        let path = crate::config::model::artifacts_root()
            .join("mixtral-tiny/analysis/analysis.json");
        let p = TransitionProfile::load(path).expect("make artifacts first");
        // Use the most popular layer-0 expert as the observation.
        let inp: Vec<usize> = (0..p.n_experts).map(|e| usize::from(e == 0) * 8).collect();
        let pred = p.predict_next(0, &inp);
        // Prediction must be a permutation.
        let mut sorted = pred.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..p.n_experts).collect::<Vec<_>>());
    }
}
