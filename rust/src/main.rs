//! `fiddler` CLI — leader entrypoint for the serving system.
//!
//! Subcommands:
//!   serve          run the continuous-batching server on a synthetic workload
//!   generate       single-request generation
//!   beam           beam-search generation
//!   calibrate      print the latency model / run measured calibration
//!   inspect        show model + artifact + environment info
//!   trace-record   record a typed JSONL event trace of an open-loop sim run
//!   trace-replay   re-run a recorded trace and diff the token streams
//!   trace-summary  per-request flame summaries from a recorded trace
//!
//! Figure/table reproduction lives in `examples/` (see DESIGN.md §5).

use anyhow::Result;
use fiddler::config::serving::ServingConfig;
use fiddler::config::HardwareConfig;
use fiddler::coordinator::Engine;
use fiddler::figures;
use fiddler::latency::{calib, LatencyModel};
use fiddler::server::{collect, ServerHandle};
use fiddler::util::cli::Args;
use fiddler::workload::{Dataset, WorkloadGen};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "beam" => cmd_beam(&args),
        "calibrate" => cmd_calibrate(&args),
        "inspect" => cmd_inspect(&args),
        "trace-record" => cmd_trace_record(&args),
        "trace-replay" => cmd_trace_replay(&args),
        "trace-summary" => cmd_trace_summary(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "fiddler — CPU-GPU orchestration for fast MoE inference (reproduction)\n\
         \n\
         USAGE: fiddler <command> [--flags]\n\
         \n\
         COMMANDS:\n\
           serve      --model M --env E --policy P --requests N --inp L --out L\n\
                      [--width W] [--listen 127.0.0.1:7777]  (newline-JSON TCP)\n\
           generate   --model M --env E --policy P --inp L --out L [--prompt 1,2,3]\n\
           beam       --model M --env E --policy P --width W --inp L --out L\n\
           calibrate  --env E [--measured] [--measured-pool] [--threads N]\n\
           inspect    --model M --env E\n\
           trace-record   --trace T.jsonl [--requests N] [--rate R] [--inp L]\n\
                          [--out L] [--seed S] + any SERVING flag; records a\n\
                          typed JSONL event trace of an open-loop sim run.\n\
                          Workload shaping: --tight-every K --tight-slo-ms D\n\
                          (every Kth request gets a hard deadline), \n\
                          --cancel-every K --cancel-after-ms T (client cancels),\n\
                          --reload-at-ms T [--reload-admission P]\n\
                          [--reload-kv-budget-mb M] [--reload-prefill-tokens N]\n\
                          [--reload-prefill-chunk C] [--reload-slo-ttft-ms D]\n\
                          [--reload-max-preemptions P], --drain-at-ms T\n\
           trace-replay   --trace T.jsonl   re-runs the recorded workload and\n\
                          diffs token streams (exit 1 on divergence);\n\
                          --config-override \"k=v,...\" replays A/B under an\n\
                          overridden config and diffs aggregate metrics\n\
                          (keys: shards, shard-plan, replicate-hot, admission,\n\
                          max-batch, kv-budget-mb, prefill-chunk, ...)\n\
           trace-summary  --trace T.jsonl   per-request flame summaries\n\
                          (queue / prefill chunks / ITL / cache hits)\n\
         \n\
         OBSERVABILITY: every engine path accepts --events-out T.jsonl to\n\
                   stream typed events (see rust/src/events/)\n\
         \n\
         DEFAULTS: --model mixtral-tiny --env env1 --policy fiddler\n\
         POLICIES: fiddler | mii (DeepSpeed-MII*) | lru (Mixtral-Offloading*) |\n\
                   static (llama.cpp*) | fiddler-prefetch | fiddler-cached\n\
         CACHE:    fiddler-cached takes --cache-eviction lru|scored|transition\n\
                   and --cache-pin-fraction F (default 0.5)\n\
                   --cache-partition none|layer   per-layer capacity quotas\n\
                                       (one hot layer can't evict the rest)\n\
         TIERS:    --quant-tier on|off three-tier expert hierarchy: low-bit\n\
                                       GPU copies beyond fp capacity (off =\n\
                                       default, bit-identical to fp-only)\n\
                   --quant-bits B      width of the low-bit copies, 2..=16\n\
                                       (default 8; N fp slots hold 16/B copies)\n\
                   --error-budget E    per-request quantization error budget;\n\
                                       a quantized hit over budget is corrected\n\
                                       by an fp transfer (0 = always correct)\n\
         SERVING:  --prefill-chunk N   chunked prefill (0 = monolithic) so long\n\
                                       prompts don't stall running sequences\n\
                   --admission fcfs|sjf|slo   queue policy (slo = earliest TTFT\n\
                                       deadline first, --slo-ttft-ms D default)\n\
                   --kv-budget-mb M    paper-scale KV memory pool; queues or\n\
                                       rejects instead of OOM, borrowing expert\n\
                                       cache slots under pressure (0 = off)\n\
                   --max-batch B       decode batch cap (clamped to the AOT\n\
                                       bucket ceiling)\n\
                   --prefill-tokens N  per-iteration prefill token budget: admit\n\
                                       several concurrent prefills up to N\n\
                                       tokens per step (0 = one prefill at a\n\
                                       time, legacy)\n\
                   --max-preemptions P preempt up to P times per decoding\n\
                                       sequence to admit SLO-tight arrivals\n\
                                       (drop-and-recompute KV; 0 = reject-only)\n\
                   --shards N          expert-sharded fleet: N engines behind\n\
                                       one router (1 = single engine, default;\n\
                                       bit-identical to previous releases)\n\
                   --shard-plan P      layer | hash | auto — expert partition\n\
                                       across shards; auto prices both against\n\
                                       the latency model and picks the lower\n\
                                       worst-shard step time\n\
                   --replicate-hot F   replicate experts whose routed-token\n\
                                       share exceeds F onto extra shards\n\
                                       (0 = off)\n\
                   --faults SPEC       deterministic fault injection, e.g.\n\
                                       stall=0.1:30000,spike=0.05:50000,err=0.01\n\
                                       (--fault-seed S decorrelates from --seed)\n\
                   --conn-timeout-ms T per-connection TCP read timeout (0 = off)\n\
                   protocol extras: {{\"cancel\":ID}} | {{\"drain\":true}} |\n\
                                    {{\"reload\":{{...}}}} | \"deadline_ms\" per req\n\
                   see also: cargo run --release --example load_gen -- --compare\n\
         EXECUTOR: --threads N sizes the parallel CPU expert executor\n\
                   (1 = serial, 0 = one worker per core); set\n\
                   FIDDLER_HOST_KERNEL=1 to run CPU-planned experts through\n\
                   the dedicated host kernel\n\
         PIPELINE: --pipeline-lookahead W   cross-layer expert prefetch\n\
                   window of the pipelined layer executor (0 = serial\n\
                   legacy loop); FIDDLER_MEASURED_CALIB=1 calibrates the\n\
                   multicore CPU curve by measuring the executor pool\n\
         ADAPTIVE: --adaptive on|off   close the feedback loops online:\n\
                   per-phase lookahead hill-climbing, prefetch landing\n\
                   protection in eviction, per-row routing-skew override\n\
                   pricing, and learned SLO admission estimates (off =\n\
                   default, bit-identical static pipeline); decisions are\n\
                   virtual-time-only and recorded as trace events\n\
                   --pin-workers on|off best-effort core affinity for the\n\
                   executor pool's CPU workers (wall-clock jitter only)"
    );
}

fn engine_from(args: &Args) -> Result<Engine> {
    let model = args.str_or("model", "mixtral-tiny");
    let hw = HardwareConfig::by_name(args.str_or("env", "env1"))?;
    let mut serving = ServingConfig::from_args(args)?;
    if args.get("ngl").is_none() {
        serving.ngl = ServingConfig::paper_ngl_for(&hw.name);
    }
    Engine::new(figures::artifact_dir(model), &hw, serving)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut engine = engine_from(args)?;
    let inp = args.usize_or("inp", 32);
    let out = args.usize_or("out", 64);
    let prompt: Vec<u32> = match args.get("prompt") {
        Some(p) => p.split(',').map(|t| t.trim().parse().unwrap()).collect(),
        None => {
            WorkloadGen::new(Dataset::sharegpt(), engine.model().vocab, args.u64_or("seed", 0))
                .prompt(inp)
        }
    };
    eprintln!(
        "[generate] model={} env={} policy={} prompt_len={} out={}",
        engine.model().name,
        engine.cx.hw.name,
        engine.cx.policy.name(),
        prompt.len(),
        out
    );
    let g = engine.generate(&prompt, out)?;
    println!("tokens: {:?}", g.tokens);
    println!(
        "virtual: ttft {:.1} ms | mean itl {:.1} ms | {:.2} tok/s | hit rate {:.1}%",
        g.metrics.ttft_us() / 1e3,
        g.metrics.mean_itl_us() / 1e3,
        g.metrics.tokens_per_s(),
        engine.cx.events.hit_rate() * 100.0
    );
    if let Some(c) = g.metrics.cache.as_ref().filter(|c| c.lookups() > 0) {
        println!(
            "cache ({}): {:.1}% hit rate | {} evictions | {} transfers in | {} prefetch hits",
            engine.cx.memory.policy_name(),
            c.hit_rate() * 100.0,
            c.evictions,
            c.transfers_in,
            c.prefetch_hits
        );
    }
    Ok(())
}

fn cmd_beam(args: &Args) -> Result<()> {
    let mut engine = engine_from(args)?;
    let width = args.usize_or("width", 4);
    let inp = args.usize_or("inp", 32);
    let out = args.usize_or("out", 64);
    let prompt = WorkloadGen::new(
        Dataset::sharegpt(),
        engine.model().vocab,
        args.u64_or("seed", 0),
    )
    .prompt(inp);
    let b = engine.beam_search(&prompt, width, out)?;
    println!("best beam (score {:.3}): {:?}", b.score, b.tokens);
    println!(
        "virtual: {:.3} tok/s over {} tokens (width {width})",
        b.metrics.tokens_per_s(),
        b.tokens.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.usize_or("requests", 8);
    let inp = args.usize_or("inp", 32);
    let out = args.usize_or("out", 64);
    let model = args.str_or("model", "mixtral-tiny").to_string();
    let hw = HardwareConfig::by_name(args.str_or("env", "env1"))?;
    let mut serving = ServingConfig::from_args(args)?;
    if args.get("ngl").is_none() {
        serving.ngl = ServingConfig::paper_ngl_for(&hw.name);
    }
    // --shards N > 1: route through the expert-sharded fleet instead of
    // a single engine (--shards 1 stays on the single-engine scheduler,
    // token-bit-identical to previous releases).
    if serving.shards > 1 {
        return cmd_serve_fleet(args, model, hw, serving);
    }
    let conn_timeout_ms = serving.conn_timeout_ms;
    let hw2 = hw.clone();
    let handle = ServerHandle::spawn(move || {
        Engine::new(figures::artifact_dir(&model), &hw2, serving)
    });

    // --listen ADDR: expose the newline-JSON TCP protocol and run forever.
    if let Some(addr) = args.get("listen") {
        let listener = std::net::TcpListener::bind(addr)?;
        println!("listening on {addr} (protocol: see rust/src/server/net.rs)");
        fiddler::server::net::serve_tcp(listener, handle.requests.clone(), conn_timeout_ms)?;
        return handle.shutdown();
    }

    let width = args.usize_or("width", 1);
    let mut gen = WorkloadGen::new(Dataset::sharegpt(), 512, args.u64_or("seed", 0));
    let receivers: Vec<_> = (0..n_requests)
        .map(|_| {
            if width > 1 {
                handle.submit_beam(gen.prompt(inp), out, width)
            } else {
                handle.submit(gen.prompt(inp), out)
            }
        })
        .collect();
    let mut tps = Vec::new();
    for (i, rx) in receivers.iter().enumerate() {
        let (tokens, m) = collect(rx)?;
        println!(
            "req {i}: {} tokens | ttft {:.1} ms | queue {:.1} ms | {:.2} tok/s",
            tokens.len(),
            m.ttft_us() / 1e3,
            m.queue_delay_us() / 1e3,
            m.tokens_per_s()
        );
        tps.push(m.tokens_per_s());
    }
    println!(
        "aggregate: {:.2} tok/s mean over {n_requests} requests (virtual time)",
        fiddler::util::stats::mean(&tps)
    );
    handle.shutdown()
}

/// N-shard fleet serving: a front-end router owns global ingest order
/// and dispatches each request to one of `--shards` engines by predicted
/// expert demand; the sharding planner prices `--shard-plan layer|hash`
/// against the latency model's bottleneck decomposition before the first
/// request lands.
fn cmd_serve_fleet(
    args: &Args,
    model: String,
    hw: HardwareConfig,
    serving: ServingConfig,
) -> Result<()> {
    use fiddler::events::EventSink;
    use fiddler::popularity::Profile;
    use fiddler::prefetch::TransitionProfile;
    use fiddler::server::fleet::{plan_shards, FleetHandle, FleetRouter};

    let dir = figures::artifact_dir(&model);
    let analysis = dir.join("analysis/analysis.json");
    // Planner inputs: the build-time popularity/transition profiles when
    // the artifacts carry them, a flat single-layer profile otherwise.
    let profile = Profile::load(&analysis).unwrap_or_else(|_| Profile::new(1, 8));
    let transitions = TransitionProfile::load(&analysis).ok();
    let lat = LatencyModel::from_hardware(&hw);
    let plan = plan_shards(
        &profile,
        &lat,
        serving.shards,
        serving.shard_plan,
        serving.ngl.max(1),
        serving.quant_tier.then_some(serving.quant_bits),
    );
    println!(
        "fleet: {} shards | plan {} | bottlenecks [{}] | priced step {:.2} ms",
        plan.n_shards,
        plan.plan.label(),
        plan.bottleneck_summary(),
        plan.max_step_us() / 1e3
    );
    let sink = match serving.events_out.as_deref() {
        Some(path) => EventSink::to_path(path)?,
        None => EventSink::disabled(),
    };
    let router = FleetRouter::new(plan, transitions, serving.replicate_hot, sink.clone());
    let conn_timeout_ms = serving.conn_timeout_ms;
    let make_serving = serving.clone();
    let handle = FleetHandle::spawn(router, move |_shard| {
        let mut engine =
            Engine::new(figures::artifact_dir(&model), &hw, make_serving.clone())?;
        // One shared sink across the fleet: each shard's serve loop sees
        // it pre-armed and skips opening --events-out itself (N engines
        // opening one path would clobber each other).
        if sink.is_enabled() {
            engine.set_event_sink(sink.clone());
        }
        Ok(engine)
    });

    if let Some(addr) = args.get("listen") {
        let listener = std::net::TcpListener::bind(addr)?;
        println!("listening on {addr} (protocol: see rust/src/server/net.rs)");
        fiddler::server::net::serve_tcp(listener, handle.requests.clone(), conn_timeout_ms)?;
        return handle.shutdown();
    }

    anyhow::ensure!(
        args.usize_or("width", 1) == 1,
        "beam groups are not fleet-routed yet; use --shards 1 for --width > 1"
    );
    let n_requests = args.usize_or("requests", 8);
    let inp = args.usize_or("inp", 32);
    let out = args.usize_or("out", 64);
    let mut gen = WorkloadGen::new(Dataset::sharegpt(), 512, args.u64_or("seed", 0));
    let receivers: Vec<_> =
        (0..n_requests).map(|_| handle.submit(gen.prompt(inp), out)).collect();
    let mut tps = Vec::new();
    for (i, rx) in receivers.iter().enumerate() {
        let (tokens, m) = collect(rx)?;
        println!(
            "req {i}: {} tokens | ttft {:.1} ms | queue {:.1} ms | {:.2} tok/s",
            tokens.len(),
            m.ttft_us() / 1e3,
            m.queue_delay_us() / 1e3,
            m.tokens_per_s()
        );
        tps.push(m.tokens_per_s());
    }
    println!(
        "aggregate: {:.2} tok/s mean over {n_requests} requests (virtual time)",
        fiddler::util::stats::mean(&tps)
    );
    handle.shutdown()
}

/// `LoadSpec` from CLI flags (shared by trace-record and the bench).
fn load_spec_from(args: &Args) -> Result<fiddler::server::sim::LoadSpec> {
    use fiddler::server::ControlMsg;
    let d = fiddler::server::sim::LoadSpec::default();
    let mut controls = Vec::new();
    if let Some(t) = args.get("reload-at-ms") {
        let t_us = t.parse::<f64>().map_err(|_| anyhow::anyhow!("--reload-at-ms wants a number"))?
            * 1e3;
        let spec = fiddler::server::ReloadSpec {
            admission: match args.get("reload-admission") {
                Some(name) => Some(fiddler::config::serving::AdmissionKind::by_name(name)?),
                None => None,
            },
            kv_budget_mb: args.get("reload-kv-budget-mb").map(|_| args.usize_or("reload-kv-budget-mb", 0)),
            prefill_chunk: args.get("reload-prefill-chunk").map(|_| args.usize_or("reload-prefill-chunk", 0)),
            prefill_tokens: args.get("reload-prefill-tokens").map(|_| args.usize_or("reload-prefill-tokens", 0)),
            slo_ttft_ms: args.get("reload-slo-ttft-ms").map(|_| args.f64_or("reload-slo-ttft-ms", 0.0)),
            max_preemptions: args.get("reload-max-preemptions").map(|_| args.usize_or("reload-max-preemptions", 0)),
        };
        controls.push((t_us, ControlMsg::Reload(spec)));
    }
    if let Some(t) = args.get("drain-at-ms") {
        let t_us = t.parse::<f64>().map_err(|_| anyhow::anyhow!("--drain-at-ms wants a number"))?
            * 1e3;
        controls.push((t_us, ControlMsg::Drain));
    }
    Ok(fiddler::server::sim::LoadSpec {
        n_requests: args.usize_or("requests", 32),
        rate_per_s: args.f64_or("rate", d.rate_per_s),
        inp: args.usize_or("inp", d.inp),
        out: args.usize_or("out", d.out),
        long_every: args.usize_or("long-every", d.long_every),
        long_inp: args.usize_or("long-inp", d.long_inp),
        seed: args.u64_or("seed", d.seed),
        tight_every: args.usize_or("tight-every", d.tight_every),
        tight_deadline_us: args.f64_or("tight-slo-ms", d.tight_deadline_us / 1e3) * 1e3,
        cancel_every: args.usize_or("cancel-every", d.cancel_every),
        cancel_after_us: args.f64_or("cancel-after-ms", d.cancel_after_us / 1e3) * 1e3,
        controls,
    })
}

fn cmd_trace_record(args: &Args) -> Result<()> {
    let path = args.str_or("trace", "trace.jsonl").to_string();
    let mut serving = ServingConfig::from_args(args)?;
    serving.events_out = Some(path.clone());
    // Surface a bad --faults spec before the run, not as a silent
    // disabled-faults fallback deep in the sim.
    if let Some(f) = &serving.faults {
        fiddler::server::sim::FailPoints::parse(f, serving.fault_seed)?;
    }
    let spec = load_spec_from(args)?;
    // --shards N > 1 records through the fleet harness (router events,
    // per-shard engines); --shards 1 stays on the single-engine path.
    let report = if serving.shards > 1 {
        let fleet = fiddler::server::sim::run_fleet_open_loop(serving, &spec)?;
        println!(
            "fleet: plan {} | per-shard {:?} | bottlenecks [{}]",
            fleet.plan, fleet.per_shard, fleet.bottlenecks
        );
        fleet.report
    } else {
        fiddler::server::sim::run_open_loop(serving, &spec)?
    };
    println!(
        "recorded {path}: {} completed / {} rejected | {:.2} tok/s | makespan {:.2} s (virtual)",
        report.completed,
        report.rejected,
        report.throughput_tok_s(),
        report.makespan_s
    );
    if !report.reasons.is_empty() {
        let hist: Vec<String> =
            report.reasons.iter().map(|(k, n)| format!("{k}={n}")).collect();
        println!("terminal reasons: {}", hist.join(" "));
    }
    if report.slo_eligible > 0 {
        println!(
            "tight-SLO attainment: {}/{} ({:.1}%) | {} preemptions",
            report.slo_attained,
            report.slo_eligible,
            report.slo_attainment() * 100.0,
            report.preemptions
        );
    }
    let events = fiddler::events::replay::read_log(&path)?;
    println!("{} events on {} requests", events.len(), spec.n_requests);
    Ok(())
}

fn cmd_trace_replay(args: &Args) -> Result<()> {
    use fiddler::events::replay;
    let path = args.str_or("trace", "trace.jsonl");
    let events = replay::read_log(path)?;
    let rec = replay::fold_trace(&events);
    // --config-override "k=v,...": A/B harness — replay the recorded
    // workload under the trace's own config AND the overridden one, and
    // diff aggregate metrics (token streams legitimately change under a
    // different config, so bit-diffing them would only report noise).
    if let Some(spec) = args.get("config-override") {
        let base_cfg = rec.serving_config()?;
        let mut over_cfg = base_cfg.clone();
        replay::apply_config_overrides(&mut over_cfg, spec)?;
        let base = replay::aggregate_outcomes(&replay::replay_with_config(&rec, base_cfg)?);
        let over = replay::aggregate_outcomes(&replay::replay_with_config(&rec, over_cfg)?);
        println!("A/B replay of {path} under --config-override {spec:?}:");
        for line in replay::diff_aggregates(&base, &over) {
            println!("  {line}");
        }
        return Ok(());
    }
    let outcomes = replay::replay_trace(&rec)?;
    let diffs = fiddler::events::replay::diff_replay(&rec, &outcomes);
    if diffs.is_empty() {
        println!(
            "replay of {path}: {} requests bit-identical ({} events)",
            rec.requests.len(),
            events.len()
        );
        return Ok(());
    }
    for d in &diffs {
        eprintln!("DIVERGED: {d}");
    }
    anyhow::bail!("{} of {} requests diverged on replay", diffs.len(), rec.requests.len());
}

fn cmd_trace_summary(args: &Args) -> Result<()> {
    let path = args.str_or("trace", "trace.jsonl");
    let events = fiddler::events::replay::read_log(path)?;
    let summaries = fiddler::events::summary::summarize(&events);
    print!("{}", fiddler::events::summary::render(&summaries));
    print!("{}", fiddler::events::summary::control_footer(&events));
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let hw = HardwareConfig::by_name(args.str_or("env", "env1"))?;
    let analytic = LatencyModel::from_hardware(&hw);
    let fitted = calib::calibrate_paper_env(&hw, args.u64_or("seed", 42));
    println!("environment: {} ({} / {})", hw.name, hw.gpu_name, hw.cpu_name);
    for (name, m) in [("analytic", &analytic), ("fitted", &fitted)] {
        println!(
            "{name:>9}: gpu {:.2} ms | cpu {:.2} + {:.3}*s ms | transfer {:.2} ms | crossover s*={}",
            m.gpu_const_us / 1e3,
            m.cpu_base_us / 1e3,
            m.cpu_per_token_us / 1e3,
            m.transfer_us / 1e3,
            m.crossover_tokens()
        );
    }
    // Multi-core CPU path: how the parallel executor shifts Algorithm 1's
    // crossover (--threads N, 0 = one worker per core).
    let threads = match args.usize_or("threads", 1) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };
    if threads > 1 {
        let mc = calib::calibrate_multicore(&hw, threads, args.u64_or("seed", 42));
        println!(
            "{:>9}: cpu {:.2} + {:.3}*s ms | crossover s*={} ({} executor threads)",
            "multicore",
            mc.cpu_base_us / 1e3,
            mc.cpu_per_token_us / 1e3,
            mc.crossover_tokens(),
            threads
        );
    }
    if args.has("measured-pool") {
        // Measured (not modeled) multicore calibration: time the host
        // expert kernel through real executor pools and feed the realized
        // speedup into the threaded latency model (no artifacts needed).
        let seed = args.u64_or("seed", 42);
        let sp = calib::measure_pool_speedup(threads, seed);
        let m = LatencyModel::from_hardware_threaded_with_speedup(&hw, threads, sp);
        println!(
            " measured-pool ({threads} threads): speedup {sp:.2}x | cpu {:.2} + {:.3}*s ms | crossover s*={}",
            m.cpu_base_us / 1e3,
            m.cpu_per_token_us / 1e3,
            m.crossover_tokens()
        );
    }
    if args.has("measured") {
        // Time the real expert executable on THIS host and fit.
        let model = args.str_or("model", "mixtral-tiny");
        let dir = figures::artifact_dir(model);
        let rt = fiddler::runtime::Runtime::open(dir.clone())?;
        let ws = fiddler::runtime::WeightStore::load(&dir)?;
        let samples =
            calib::measure_host_expert(&rt, &ws, &[1, 2, 4, 8, 16, 32, 64], 8)?;
        let m = calib::fit(&samples, &samples, hw.weight_transfer_us());
        println!(
            " measured (this host, expert op): {:.3} + {:.4}*s ms over {} samples",
            m.cpu_base_us / 1e3,
            m.cpu_per_token_us / 1e3,
            samples.len()
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let cfg = engine.model().clone();
    let hw = &engine.cx.hw;
    figures::print_env_banner(hw, &cfg);
    println!(
        "model {}: {} layers x {} experts (top-{}), hidden {}, ffn {}, vocab {}",
        cfg.name, cfg.n_layers, cfg.n_experts, cfg.top_k, cfg.hidden, cfg.ffn, cfg.vocab
    );
    println!("artifact ops: {}", engine.runner.rt.op_names().len());
    println!(
        "placement: {} experts pinned of {} capacity",
        engine.cx.memory.resident_count(),
        engine.cx.memory.capacity()
    );
    Ok(())
}
