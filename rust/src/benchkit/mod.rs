//! In-house micro-benchmark harness (`criterion` is unavailable offline).
//!
//! Mirrors the criterion workflow: named benchmarks, warmup, timed
//! iterations, outlier-trimmed statistics, and a compact table report.
//! `cargo bench` targets (benches/*.rs with `harness = false`) use this.

use crate::util::stats::{mean, percentile, std_dev};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in nanoseconds (outlier-trimmed).
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // FIDDLER_BENCH_FAST=1 shrinks budgets so `cargo bench` smoke-runs in CI.
        let fast = std::env::var("FIDDLER_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Bench {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, warmup: Duration, measure: Duration) -> Bench {
        self.warmup = warmup;
        self.measure = measure;
        self
    }

    /// Benchmark `f`, preventing the result from being optimized away.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0usize;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }

        // Trim the top/bottom 5% (scheduler noise).
        samples_ns.sort_by(f64::total_cmp);
        let trim = samples_ns.len() / 20;
        let trimmed = &samples_ns[trim..samples_ns.len() - trim.min(samples_ns.len() - 1)];

        let r = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean(trimmed),
            std_ns: std_dev(trimmed),
            p50_ns: percentile(trimmed, 50.0),
            p95_ns: percentile(trimmed, 95.0),
            min_ns: trimmed.first().copied().unwrap_or(0.0),
        };
        eprintln!("  {:<44} {:>12} /iter  (p50 {}, p95 {}, n={})",
            r.name, fmt_ns(r.mean_ns), fmt_ns(r.p50_ns), fmt_ns(r.p95_ns), r.iters);
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render the criterion-style summary table.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "mean", "p50", "p95");
        for r in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12}",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p95_ns)
            );
        }
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Bench {
        Bench::new().with_budget(Duration::from_millis(5), Duration::from_millis(20))
    }

    #[test]
    fn measures_something_positive() {
        let mut b = fast();
        let r = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert!(r.iters > 10);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn slower_function_measures_slower() {
        // black_box the loop bounds so release mode cannot const-fold.
        let mut b = fast();
        let fast_ns = b
            .bench("fast", || (0..std::hint::black_box(10u64)).sum::<u64>())
            .mean_ns;
        let slow_ns = b
            .bench("slow", || {
                (0..std::hint::black_box(100_000u64))
                    .fold(0u64, |a, x| a.wrapping_add(x.wrapping_mul(x)))
            })
            .mean_ns;
        assert!(slow_ns > fast_ns, "slow={slow_ns} fast={fast_ns}");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1_500.0).contains("µs"));
        assert!(fmt_ns(2_000_000.0).contains("ms"));
        assert!(fmt_ns(3e9).contains(" s"));
    }
}
