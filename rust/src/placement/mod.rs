//! Initialization-time expert placement (paper §3.1/§3.4).
//!
//! Non-expert layers always live on the GPU (their reservation is part of
//! [`crate::config::HardwareConfig::non_expert_reserved_bytes`]); the expert
//! budget is filled by one of three strategies:
//!
//! * `Popularity` — most-popular experts first (the paper's system),
//! * `Random` — uniform random subset (Appendix C baseline),
//! * `Worst` — least-popular first (Appendix C lower bound).

use crate::config::serving::PlacementStrategy;
use crate::expertcache::{ExpertCache, ExpertId};
use crate::popularity::Profile;
use crate::util::rng::Rng;

/// Decide which experts to pin, without touching memory (pure function —
/// property-tested).
pub fn choose_experts(
    profile: &Profile,
    capacity: usize,
    strategy: PlacementStrategy,
    seed: u64,
) -> Vec<ExpertId> {
    let ranked = profile.ranked();
    let k = capacity.min(ranked.len());
    match strategy {
        PlacementStrategy::Popularity => ranked[..k].to_vec(),
        PlacementStrategy::Worst => {
            let mut v = ranked[ranked.len() - k..].to_vec();
            v.reverse(); // least popular first, deterministic
            v
        }
        PlacementStrategy::Random => {
            let mut rng = Rng::new(seed);
            let mut all = ranked;
            rng.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            all
        }
    }
}

/// Pin the chosen experts into the GPU expert cache (pinned entries are
/// exempt from eviction — placement is a cache with eviction disabled).
pub fn place(
    memory: &mut ExpertCache,
    profile: &Profile,
    strategy: PlacementStrategy,
    seed: u64,
) -> Vec<ExpertId> {
    let chosen = choose_experts(profile, memory.capacity(), strategy, seed);
    for &id in &chosen {
        memory.pin(id);
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    fn skewed_profile(n_layers: usize, n_experts: usize, seed: u64) -> Profile {
        let mut p = Profile::new(n_layers, n_experts);
        let mut rng = Rng::new(seed);
        for l in 0..n_layers {
            for e in 0..n_experts {
                p.counts[l][e] = rng.below(1000) + 1;
            }
        }
        p
    }

    #[test]
    fn popularity_picks_top_counts() {
        let mut p = Profile::new(1, 4);
        p.counts[0] = vec![5, 50, 500, 1];
        let chosen = choose_experts(&p, 2, PlacementStrategy::Popularity, 0);
        assert_eq!(chosen, vec![(0, 2), (0, 1)]);
    }

    #[test]
    fn placement_respects_capacity_property() {
        check("placement capacity", 128, |g: &mut Gen| {
            let layers = g.usize_in(1..6);
            let experts = g.usize_in(1..10);
            let capacity = g.usize_in(0..layers * experts + 4);
            let strategy = *g.choice(&[
                PlacementStrategy::Popularity,
                PlacementStrategy::Random,
                PlacementStrategy::Worst,
            ]);
            let p = skewed_profile(layers, experts, g.u64());
            let chosen = choose_experts(&p, capacity, strategy, g.u64());
            assert!(chosen.len() == capacity.min(layers * experts));
            // no duplicates
            let mut dedup = chosen.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), chosen.len());
            // all ids valid
            assert!(chosen.iter().all(|&(l, e)| l < layers && e < experts));
        });
    }

    #[test]
    fn popularity_dominates_random_dominates_worst_property() {
        check("placement hit-rate dominance", 64, |g: &mut Gen| {
            let p = skewed_profile(g.usize_in(1..5), g.usize_in(2..9), g.u64());
            let cap = g.usize_in(1..p.n_layers * p.n_experts);
            let best =
                p.expected_hit_rate(&choose_experts(&p, cap, PlacementStrategy::Popularity, 0));
            let worst =
                p.expected_hit_rate(&choose_experts(&p, cap, PlacementStrategy::Worst, 0));
            let rand =
                p.expected_hit_rate(&choose_experts(&p, cap, PlacementStrategy::Random, g.u64()));
            assert!(best + 1e-12 >= rand, "best {best} < random {rand}");
            assert!(rand + 1e-12 >= worst * 0.999999, "random {rand} < worst {worst}");
        });
    }

    #[test]
    fn place_pins_into_memory() {
        let p = skewed_profile(2, 4, 7);
        let mut mem = ExpertCache::with_capacity(3);
        let chosen = place(&mut mem, &p, PlacementStrategy::Popularity, 0);
        assert_eq!(chosen.len(), 3);
        assert_eq!(mem.resident_count(), 3);
        for id in chosen {
            assert!(mem.is_pinned(id));
        }
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let p = skewed_profile(3, 8, 1);
        let a = choose_experts(&p, 10, PlacementStrategy::Random, 99);
        let b = choose_experts(&p, 10, PlacementStrategy::Random, 99);
        let c = choose_experts(&p, 10, PlacementStrategy::Random, 100);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
