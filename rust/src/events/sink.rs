//! Lock-light JSONL event sink: a bounded in-memory queue drained by one
//! background writer thread.
//!
//! Hot-path cost contract (ISSUE 6): a *disabled* sink is one `Option`
//! branch — [`EventSink::emit_with`] takes a closure so callers never
//! construct a [`TraceEvent`] (or clone a prompt, or format a string)
//! unless a sink is actually attached.  An *enabled* sink costs one
//! short mutex-protected push; serialization and I/O happen on the
//! writer thread, never on the engine thread.
//!
//! Back-pressure policy: the queue is bounded ([`QUEUE_CAP`]) and
//! overflow **drops the newest event** rather than blocking the engine —
//! observability must not perturb the schedule it observes.  Drops are
//! counted and recorded as a final [`TraceEvent::SinkDropped`] line so a
//! truncated log is detectable, never silent.
//!
//! Flush/ordering contract: [`EventSink`] is a cheap `Arc` clone; when
//! the **last** clone drops, the writer thread is joined and the output
//! flushed.  Holders (backend, `ExpertCache`, `ExecContext`) all hang off
//! the backend, so dropping the backend completes the log file.

use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::TraceEvent;

/// Bounded queue depth; past this, new events are dropped (and counted).
pub const QUEUE_CAP: usize = 1 << 16;

struct Queue {
    buf: VecDeque<TraceEvent>,
    closed: bool,
}

struct Shared {
    q: Mutex<Queue>,
    ready: Condvar,
    dropped: AtomicU64,
}

/// Owns the writer thread; joining it on the final drop is what makes
/// "backend dropped => log complete" hold.
struct Handle {
    shared: Arc<Shared>,
    writer: Mutex<Option<JoinHandle<()>>>,
}

impl Drop for Handle {
    fn drop(&mut self) {
        {
            let mut q = self.shared.q.lock().unwrap();
            q.closed = true;
        }
        self.shared.ready.notify_all();
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Cloneable handle to the event stream; `Default` is the disabled sink.
#[derive(Clone, Default)]
pub struct EventSink(Option<Arc<Handle>>);

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventSink").field("enabled", &self.is_enabled()).finish()
    }
}

impl EventSink {
    /// The no-op sink (also what `EventSink::default()` gives you).
    pub fn disabled() -> EventSink {
        EventSink(None)
    }

    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Sink writing JSONL to a file at `path` (truncating).
    pub fn to_path(path: impl AsRef<std::path::Path>) -> anyhow::Result<EventSink> {
        let path = path.as_ref();
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating event log {}: {e}", path.display()))?;
        Ok(EventSink::to_writer(std::io::BufWriter::new(f)))
    }

    /// Sink writing JSONL to any writer (tests use `Vec<u8>` behind a
    /// shared buffer; the server could hand a socket here).
    pub fn to_writer<W: Write + Send + 'static>(w: W) -> EventSink {
        let shared = Arc::new(Shared {
            q: Mutex::new(Queue { buf: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            dropped: AtomicU64::new(0),
        });
        let worker_shared = Arc::clone(&shared);
        let writer = std::thread::Builder::new()
            .name("fiddler-events".into())
            .spawn(move || writer_loop(worker_shared, w))
            .expect("spawn event-sink writer");
        EventSink(Some(Arc::new(Handle { shared, writer: Mutex::new(Some(writer)) })))
    }

    /// Enqueue one event (no-op when disabled).  Prefer
    /// [`EventSink::emit_with`] on hot paths where even *constructing*
    /// the event costs something.
    pub fn emit(&self, ev: TraceEvent) {
        if let Some(h) = &self.0 {
            push(&h.shared, ev);
        }
    }

    /// Enqueue the event produced by `f`, which runs only when the sink
    /// is enabled — the disabled-path cost is exactly one branch.
    #[inline]
    pub fn emit_with(&self, f: impl FnOnce() -> TraceEvent) {
        if let Some(h) = &self.0 {
            push(&h.shared, f());
        }
    }

    /// Events dropped so far due to queue overflow.
    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |h| h.shared.dropped.load(Ordering::Relaxed))
    }
}

fn push(shared: &Shared, ev: TraceEvent) {
    let mut q = shared.q.lock().unwrap();
    if q.closed {
        return;
    }
    if q.buf.len() >= QUEUE_CAP {
        shared.dropped.fetch_add(1, Ordering::Relaxed);
        return;
    }
    q.buf.push_back(ev);
    drop(q);
    shared.ready.notify_one();
}

fn writer_loop<W: Write>(shared: Arc<Shared>, mut w: W) {
    let mut batch: Vec<TraceEvent> = Vec::new();
    loop {
        {
            let mut q = shared.q.lock().unwrap();
            while q.buf.is_empty() && !q.closed {
                q = shared.ready.wait(q).unwrap();
            }
            if q.buf.is_empty() && q.closed {
                break;
            }
            batch.extend(q.buf.drain(..));
        }
        // Serialize + write outside the lock; producers never wait on I/O.
        for ev in batch.drain(..) {
            let _ = w.write_all(ev.encode_line().as_bytes());
        }
    }
    let dropped = shared.dropped.load(Ordering::Relaxed);
    if dropped > 0 {
        let line = TraceEvent::SinkDropped { count: dropped }.encode_line();
        let _ = w.write_all(line.as_bytes());
    }
    let _ = w.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `Vec<u8>` behind a mutex so the test can read what the writer
    /// thread wrote after the sink drops.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn disabled_sink_is_a_noop() {
        let s = EventSink::disabled();
        assert!(!s.is_enabled());
        s.emit(TraceEvent::SinkDropped { count: 1 });
        let mut ran = false;
        s.emit_with(|| {
            ran = true;
            TraceEvent::SinkDropped { count: 2 }
        });
        assert!(!ran, "emit_with must not construct events when disabled");
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn events_drain_in_order_and_flush_on_drop() {
        let buf = SharedBuf::default();
        let sink = EventSink::to_writer(buf.clone());
        for i in 0..100u64 {
            sink.emit(TraceEvent::SinkDropped { count: i });
        }
        let clone = sink.clone();
        drop(sink);
        drop(clone); // last clone: joins the writer, flushes
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 100);
        for (i, l) in lines.iter().enumerate() {
            match TraceEvent::parse_line(l).unwrap() {
                TraceEvent::SinkDropped { count } => assert_eq!(count, i as u64),
                other => panic!("unexpected event {other:?}"),
            }
        }
    }

    #[test]
    fn overflow_drops_newest_and_records_a_marker() {
        // Stall the writer by holding the queue lock while overfilling.
        let buf = SharedBuf::default();
        let sink = EventSink::to_writer(buf.clone());
        {
            let h = sink.0.as_ref().unwrap();
            let mut q = h.shared.q.lock().unwrap();
            for i in 0..(QUEUE_CAP + 5) as u64 {
                if q.buf.len() >= QUEUE_CAP {
                    h.shared.dropped.fetch_add(1, Ordering::Relaxed);
                } else {
                    q.buf.push_back(TraceEvent::SinkDropped { count: i });
                }
            }
        }
        sink.0.as_ref().unwrap().shared.ready.notify_all();
        assert_eq!(sink.dropped(), 5);
        drop(sink);
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let last = text.lines().last().unwrap();
        match TraceEvent::parse_line(last).unwrap() {
            TraceEvent::SinkDropped { count } => assert_eq!(count, 5),
            other => panic!("expected drop marker, got {other:?}"),
        }
        assert_eq!(text.lines().count(), QUEUE_CAP + 1);
    }
}
