//! Typed engine-wide event stream, serialized as JSONL.
//!
//! One enum ([`TraceEvent`]) covers the whole engine — request lifecycle
//! transitions, admission/KV-budget decisions, prefill chunk boundaries,
//! per-layer pipeline prefetch decisions, expert-cache traffic, and
//! exec-pool dispatch/steal — and one codec serves every consumer: the
//! live sink ([`EventSink`]), saved logs, the wire protocol
//! (`server/net.rs` encodes its lines through [`wire_event_json`]), the
//! trace [`replay`] driver, and the per-request flame [`summary`] folder.
//! Stream and replay go through the same decoder, so the live protocol
//! and the on-disk log cannot drift apart.
//!
//! Schema: every line is one JSON object whose `"ev"` field names the
//! variant (snake_case).  Decoding is *lenient* by construction —
//! unknown `"ev"` values decode to [`TraceEvent::Unknown`], unknown
//! fields are ignored, and missing fields default — so an old parser
//! reads a newer log without erroring (forward compatibility), and a
//! grep-ed/truncated log still folds.  All timestamps are **virtual
//! microseconds** (`t_us`), the same clock every metric in this repo
//! uses.

pub mod replay;
pub mod sink;
pub mod summary;

pub use sink::EventSink;

use crate::util::json::Json;

/// One engine event.  See the module docs for schema and conventions;
/// [`TraceEvent::examples`] enumerates one instance of every variant
/// (the round-trip tests and the README schema table lean on it).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Run header: the serving configuration a replay needs to rebuild
    /// the scheduler bit-identically.  First line of every log.
    Meta {
        seed: u64,
        temperature: f64,
        max_batch: usize,
        queue_capacity: usize,
        prefill_chunk: usize,
        admission: String,
        kv_budget_mb: usize,
        slo_ttft_ms: f64,
        lookahead: usize,
        /// Per-iteration prefill token budget (0 = legacy one-at-a-time).
        prefill_tokens: usize,
        /// Per-request preemption bound (0 = preemption off).
        max_preemptions: usize,
        /// Fault-injection spec string ("" = none) + its RNG seed; both
        /// are scheduling-relevant, so replay must reconstruct them.
        faults: String,
        fault_seed: u64,
        /// Fleet shape: engine count, shard-plan label ("layer" / "hash"
        /// / "auto"), and hot-expert replication threshold.  Replay needs
        /// them to rebuild the router bit-identically (`shards` 0 decodes
        /// as 1 for pre-fleet logs).
        shards: usize,
        shard_plan: String,
        replicate_hot: f64,
        /// Quantized expert tier (PR9): enabled flag, resident copy
        /// width in bits, per-request error budget, and cache partition
        /// mode ("" / "none" = global pool).  All default to off so
        /// pre-tier logs replay unchanged.
        quant_tier: bool,
        quant_bits: usize,
        error_budget: f64,
        cache_partition: String,
        /// Adaptive control plane (PR10): when true the run closed its
        /// feedback loops (lookahead controller, landing protection,
        /// skew pricing, SLO feedback) — replay must arm the same loops
        /// to reproduce the schedule.  Defaults to off so earlier logs
        /// replay unchanged.
        adaptive: bool,
    },
    /// A request reached the scheduler (its full prompt is recorded —
    /// this is what makes a log a replayable trace).
    RequestArrived {
        req: u64,
        t_us: f64,
        prompt: Vec<u32>,
        max_new: usize,
        width: usize,
        slo_us: Option<f64>,
        /// Enforced end-to-end deadline (µs from enqueue); key omitted
        /// when the request carries none.
        deadline_us: Option<f64>,
    },
    /// Rejected at ingest (queue full, KV-infeasible, malformed);
    /// `kind` is the typed [`crate::server::FailReason`] label.
    RequestRejected { req: u64, t_us: f64, reason: String, kind: String },
    /// Admission: the scheduler reserved KV and started prefill.
    RequestAdmitted { req: u64, t_us: f64, kv_reserved: u64, queue_delay_us: f64 },
    /// KV budget snapshot after a reservation or release.
    KvBudget { t_us: f64, used_bytes: u64, borrowed_slots: usize },
    /// One chunk of chunked prefill completed (`start..start+len` of the
    /// prompt; `is_last` chunks produce the first token).
    PrefillChunk { req: u64, t_us: f64, start: usize, len: usize, is_last: bool },
    /// One output token (index is the position in the output stream).
    TokenEmitted { req: u64, t_us: f64, token: u32, index: usize },
    /// Terminal: the group retired normally.
    RequestFinished { req: u64, t_us: f64, tokens: usize, ttft_us: f64, queue_delay_us: f64 },
    /// Terminal: error or shutdown before/while running; `kind` is the
    /// typed [`crate::server::FailReason`] label.
    RequestFailed { req: u64, t_us: f64, reason: String, kind: String },
    /// Terminal: client cancelled the request mid-flight; `phase` names
    /// the state it was cancelled from (queued / prefilling / decoding).
    RequestCancelled { req: u64, t_us: f64, phase: String },
    /// A decoding sequence was preempted for a tighter-deadline arrival:
    /// its KV reservation (`kv_released` bytes) was dropped for
    /// recomputation on readmission; `preemptions` is the running count
    /// for this request and `tokens_done` how many tokens it had
    /// already streamed (they are not re-streamed).
    RequestPreempted {
        req: u64,
        t_us: f64,
        kv_released: u64,
        preemptions: usize,
        tokens_done: usize,
    },
    /// The preempted request re-entered the admission queue.
    RequestRequeued { req: u64, t_us: f64 },
    /// Hot config reload applied between iterations; fields are the full
    /// post-reload snapshot (what replay re-applies at `t_us`).
    ConfigReloaded {
        t_us: f64,
        admission: String,
        kv_budget_mb: usize,
        prefill_chunk: usize,
        prefill_tokens: usize,
        slo_ttft_ms: f64,
        max_preemptions: usize,
    },
    /// Graceful drain began: admission stops, queued requests fail,
    /// in-flight sequences finish, then the loop exits.
    DrainStarted { t_us: f64 },
    /// Deterministic fault injection fired in the sim backend (`kind` is
    /// stall / spike / error; `delay_us` the extra virtual time charged).
    FaultInjected { t_us: f64, kind: String, delay_us: f64 },
    /// Fleet router dispatched a request to an engine shard.  Emitted by
    /// the front-end router at ingest, before the owning shard's own
    /// `request_arrived`; replay routes by this record instead of
    /// re-running the demand predictor.
    ShardAssigned { req: u64, t_us: f64, shard: usize },
    /// Cross-engine load accounting raised a hot expert's replica count
    /// (`replicas` = new total across the fleet).
    ReplicaScaled { t_us: f64, layer: usize, expert: usize, replicas: usize },
    /// The sharding planner committed a layout: `plan` is the partition
    /// kind actually chosen ("layer" / "hash"), `shards` the engine
    /// count, `bottleneck` the per-shard saturating resource labels
    /// (comma-joined, e.g. "cpu-bw,pcie,gpu").
    PlanChosen { t_us: f64, plan: String, shards: usize, bottleneck: String },
    /// Expert-cache lookup (`hit == false` means a demand transfer was
    /// charged; `prefetch_hit` marks hits on prefetched entries).
    CacheLookup { t_us: f64, layer: usize, expert: usize, hit: bool, prefetch_hit: bool },
    /// Expert evicted to make room (capacity pressure or KV borrowing).
    CacheEvict { t_us: f64, layer: usize, expert: usize },
    /// Host-to-GPU expert weight transfer charged to the PCIe lane.
    CacheTransfer { t_us: f64, layer: usize, expert: usize, bytes: u64 },
    /// Speculative transfer admitted by the cache (`ready_us` = when the
    /// weights land).
    CachePrefetch { t_us: f64, layer: usize, expert: usize, ready_us: f64 },
    /// A quantized resident copy was promoted to full precision — an fp
    /// transfer on the PCIe lane (`ready_us` = when the fp weights are
    /// usable; 0.0 for synchronous demand promotions).
    TierPromoted { t_us: f64, layer: usize, expert: usize, ready_us: f64 },
    /// An fp expert evicted under capacity pressure was re-quantized in
    /// place into the low-bit tier (on-GPU, no PCIe traffic).
    TierDemoted { t_us: f64, layer: usize, expert: usize },
    /// A quantized resident copy served the layer; `err` is the
    /// expert's precomputed max-abs quantization error charged against
    /// the request's error budget.
    QuantHit { t_us: f64, layer: usize, expert: usize, err: f64 },
    /// The error budget could not absorb a quantized hit: the expert
    /// ran at full precision instead (fp refresh scheduled).
    QuantCorrected { t_us: f64, layer: usize, expert: usize },
    /// Pipeline driver issued a cross-layer prefetch from `layer` for
    /// `target_layer` (`distance` layers ahead).
    PrefetchIssued {
        t_us: f64,
        layer: usize,
        target_layer: usize,
        expert: usize,
        distance: usize,
        ready_us: f64,
    },
    /// A predicted expert's in-flight transfer overlapped compute: the
    /// plan flipped to GPU-resident, waiting `wait_us` instead of a full
    /// demand transfer.
    PrefetchOverlapped { t_us: f64, layer: usize, expert: usize, wait_us: f64 },
    /// A queued demand transfer was cancelled in favor of an in-flight
    /// prefetch of the same expert.
    PrefetchCancelled { t_us: f64, layer: usize, expert: usize },
    /// Exec-pool dispatch for one MoE layer: CPU expert chunks queued,
    /// split of experts across devices.
    ExecDispatch { t_us: f64, layer: usize, chunks: usize, cpu_experts: usize, gpu_experts: usize },
    /// The layer's CPU work joined; `stolen` chunks ran inline on the
    /// engine thread (work stealing) during the wait.
    ExecJoin { t_us: f64, layer: usize, stolen: u64 },
    /// Adaptive loop 1 committed a lookahead move for one pass kind
    /// (`pass` ∈ prefill / chunk / decode): the window that closed scored
    /// `reward` and the kind's effective lookahead is now `lookahead`
    /// (`adjustments` = running move count for the kind).
    ControllerAdjusted {
        t_us: f64,
        pass: String,
        lookahead: usize,
        reward: f64,
        adjustments: u64,
    },
    /// Adaptive loop 4 absorbed one retired request's measured TTFT and
    /// mean ITL into the admission estimator (`samples` = total retired
    /// observations so far).
    SloEstimateUpdated { t_us: f64, ttft_ms: f64, itl_ms: f64, samples: u64 },
    /// Writer-thread marker: `count` events were dropped on queue
    /// overflow (the log is truncated, not silently complete).
    SinkDropped { count: u64 },
    /// Forward-compat catch-all: an `"ev"` this build doesn't know.
    Unknown { kind: String },
}

impl TraceEvent {
    /// The `"ev"` discriminator string for this variant.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Meta { .. } => "meta",
            TraceEvent::RequestArrived { .. } => "request_arrived",
            TraceEvent::RequestRejected { .. } => "request_rejected",
            TraceEvent::RequestAdmitted { .. } => "request_admitted",
            TraceEvent::KvBudget { .. } => "kv_budget",
            TraceEvent::PrefillChunk { .. } => "prefill_chunk",
            TraceEvent::TokenEmitted { .. } => "token",
            TraceEvent::RequestFinished { .. } => "request_finished",
            TraceEvent::RequestFailed { .. } => "request_failed",
            TraceEvent::RequestCancelled { .. } => "request_cancelled",
            TraceEvent::RequestPreempted { .. } => "request_preempted",
            TraceEvent::RequestRequeued { .. } => "request_requeued",
            TraceEvent::ConfigReloaded { .. } => "config_reloaded",
            TraceEvent::DrainStarted { .. } => "drain_started",
            TraceEvent::FaultInjected { .. } => "fault_injected",
            TraceEvent::ShardAssigned { .. } => "shard_assigned",
            TraceEvent::ReplicaScaled { .. } => "replica_scaled",
            TraceEvent::PlanChosen { .. } => "plan_chosen",
            TraceEvent::CacheLookup { .. } => "cache_lookup",
            TraceEvent::CacheEvict { .. } => "cache_evict",
            TraceEvent::CacheTransfer { .. } => "cache_transfer",
            TraceEvent::CachePrefetch { .. } => "cache_prefetch",
            TraceEvent::TierPromoted { .. } => "tier_promoted",
            TraceEvent::TierDemoted { .. } => "tier_demoted",
            TraceEvent::QuantHit { .. } => "quant_hit",
            TraceEvent::QuantCorrected { .. } => "quant_corrected",
            TraceEvent::PrefetchIssued { .. } => "prefetch_issued",
            TraceEvent::PrefetchOverlapped { .. } => "prefetch_overlapped",
            TraceEvent::PrefetchCancelled { .. } => "prefetch_cancelled",
            TraceEvent::ExecDispatch { .. } => "exec_dispatch",
            TraceEvent::ExecJoin { .. } => "exec_join",
            TraceEvent::ControllerAdjusted { .. } => "controller_adjusted",
            TraceEvent::SloEstimateUpdated { .. } => "slo_estimate_updated",
            TraceEvent::SinkDropped { .. } => "sink_dropped",
            TraceEvent::Unknown { .. } => "unknown",
        }
    }

    /// Serialize to one JSON object (the `"ev"` key carries the kind).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("ev", Json::from(self.kind()));
        match self {
            TraceEvent::Meta {
                seed,
                temperature,
                max_batch,
                queue_capacity,
                prefill_chunk,
                admission,
                kv_budget_mb,
                slo_ttft_ms,
                lookahead,
                prefill_tokens,
                max_preemptions,
                faults,
                fault_seed,
                shards,
                shard_plan,
                replicate_hot,
                quant_tier,
                quant_bits,
                error_budget,
                cache_partition,
                adaptive,
            } => {
                o.set("seed", Json::Num(*seed as f64));
                o.set("temperature", Json::Num(*temperature));
                o.set("max_batch", Json::from(*max_batch));
                o.set("queue_capacity", Json::from(*queue_capacity));
                o.set("prefill_chunk", Json::from(*prefill_chunk));
                o.set("admission", Json::from(admission.as_str()));
                o.set("kv_budget_mb", Json::from(*kv_budget_mb));
                o.set("slo_ttft_ms", Json::Num(*slo_ttft_ms));
                o.set("lookahead", Json::from(*lookahead));
                o.set("prefill_tokens", Json::from(*prefill_tokens));
                o.set("max_preemptions", Json::from(*max_preemptions));
                o.set("faults", Json::from(faults.as_str()));
                o.set("fault_seed", Json::Num(*fault_seed as f64));
                o.set("shards", Json::from(*shards));
                o.set("shard_plan", Json::from(shard_plan.as_str()));
                o.set("replicate_hot", Json::Num(*replicate_hot));
                o.set("quant_tier", Json::from(*quant_tier));
                o.set("quant_bits", Json::from(*quant_bits));
                o.set("error_budget", Json::Num(*error_budget));
                o.set("cache_partition", Json::from(cache_partition.as_str()));
                o.set("adaptive", Json::from(*adaptive));
            }
            TraceEvent::RequestArrived { req, t_us, prompt, max_new, width, slo_us, deadline_us } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set(
                    "prompt",
                    Json::Arr(prompt.iter().map(|&t| Json::from(t as usize)).collect()),
                );
                o.set("max_new", Json::from(*max_new));
                o.set("width", Json::from(*width));
                if let Some(d) = slo_us {
                    o.set("slo_us", Json::Num(*d));
                }
                if let Some(d) = deadline_us {
                    o.set("deadline_us", Json::Num(*d));
                }
            }
            TraceEvent::RequestRejected { req, t_us, reason, kind } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("reason", Json::from(reason.as_str()));
                o.set("kind", Json::from(kind.as_str()));
            }
            TraceEvent::RequestAdmitted { req, t_us, kv_reserved, queue_delay_us } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("kv_reserved", Json::Num(*kv_reserved as f64));
                o.set("queue_delay_us", Json::Num(*queue_delay_us));
            }
            TraceEvent::KvBudget { t_us, used_bytes, borrowed_slots } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("used_bytes", Json::Num(*used_bytes as f64));
                o.set("borrowed_slots", Json::from(*borrowed_slots));
            }
            TraceEvent::PrefillChunk { req, t_us, start, len, is_last } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("start", Json::from(*start));
                o.set("len", Json::from(*len));
                o.set("is_last", Json::from(*is_last));
            }
            TraceEvent::TokenEmitted { req, t_us, token, index } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("token", Json::from(*token as usize));
                o.set("index", Json::from(*index));
            }
            TraceEvent::RequestFinished { req, t_us, tokens, ttft_us, queue_delay_us } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("tokens", Json::from(*tokens));
                o.set("ttft_us", Json::Num(*ttft_us));
                o.set("queue_delay_us", Json::Num(*queue_delay_us));
            }
            TraceEvent::RequestFailed { req, t_us, reason, kind } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("reason", Json::from(reason.as_str()));
                o.set("kind", Json::from(kind.as_str()));
            }
            TraceEvent::RequestCancelled { req, t_us, phase } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("phase", Json::from(phase.as_str()));
            }
            TraceEvent::RequestPreempted { req, t_us, kv_released, preemptions, tokens_done } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("kv_released", Json::Num(*kv_released as f64));
                o.set("preemptions", Json::from(*preemptions));
                o.set("tokens_done", Json::from(*tokens_done));
            }
            TraceEvent::RequestRequeued { req, t_us } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
            }
            TraceEvent::ConfigReloaded {
                t_us,
                admission,
                kv_budget_mb,
                prefill_chunk,
                prefill_tokens,
                slo_ttft_ms,
                max_preemptions,
            } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("admission", Json::from(admission.as_str()));
                o.set("kv_budget_mb", Json::from(*kv_budget_mb));
                o.set("prefill_chunk", Json::from(*prefill_chunk));
                o.set("prefill_tokens", Json::from(*prefill_tokens));
                o.set("slo_ttft_ms", Json::Num(*slo_ttft_ms));
                o.set("max_preemptions", Json::from(*max_preemptions));
            }
            TraceEvent::DrainStarted { t_us } => {
                o.set("t_us", Json::Num(*t_us));
            }
            TraceEvent::FaultInjected { t_us, kind, delay_us } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("kind", Json::from(kind.as_str()));
                o.set("delay_us", Json::Num(*delay_us));
            }
            TraceEvent::ShardAssigned { req, t_us, shard } => {
                o.set("req", Json::Num(*req as f64));
                o.set("t_us", Json::Num(*t_us));
                o.set("shard", Json::from(*shard));
            }
            TraceEvent::ReplicaScaled { t_us, layer, expert, replicas } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("replicas", Json::from(*replicas));
            }
            TraceEvent::PlanChosen { t_us, plan, shards, bottleneck } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("plan", Json::from(plan.as_str()));
                o.set("shards", Json::from(*shards));
                o.set("bottleneck", Json::from(bottleneck.as_str()));
            }
            TraceEvent::CacheLookup { t_us, layer, expert, hit, prefetch_hit } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("hit", Json::from(*hit));
                o.set("prefetch_hit", Json::from(*prefetch_hit));
            }
            TraceEvent::CacheEvict { t_us, layer, expert } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
            }
            TraceEvent::CacheTransfer { t_us, layer, expert, bytes } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("bytes", Json::Num(*bytes as f64));
            }
            TraceEvent::CachePrefetch { t_us, layer, expert, ready_us } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("ready_us", Json::Num(*ready_us));
            }
            TraceEvent::TierPromoted { t_us, layer, expert, ready_us } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("ready_us", Json::Num(*ready_us));
            }
            TraceEvent::TierDemoted { t_us, layer, expert } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
            }
            TraceEvent::QuantHit { t_us, layer, expert, err } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("err", Json::Num(*err));
            }
            TraceEvent::QuantCorrected { t_us, layer, expert } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
            }
            TraceEvent::PrefetchIssued { t_us, layer, target_layer, expert, distance, ready_us } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("target_layer", Json::from(*target_layer));
                o.set("expert", Json::from(*expert));
                o.set("distance", Json::from(*distance));
                o.set("ready_us", Json::Num(*ready_us));
            }
            TraceEvent::PrefetchOverlapped { t_us, layer, expert, wait_us } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
                o.set("wait_us", Json::Num(*wait_us));
            }
            TraceEvent::PrefetchCancelled { t_us, layer, expert } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("expert", Json::from(*expert));
            }
            TraceEvent::ExecDispatch { t_us, layer, chunks, cpu_experts, gpu_experts } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("chunks", Json::from(*chunks));
                o.set("cpu_experts", Json::from(*cpu_experts));
                o.set("gpu_experts", Json::from(*gpu_experts));
            }
            TraceEvent::ExecJoin { t_us, layer, stolen } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("layer", Json::from(*layer));
                o.set("stolen", Json::Num(*stolen as f64));
            }
            TraceEvent::ControllerAdjusted { t_us, pass, lookahead, reward, adjustments } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("pass", Json::from(pass.as_str()));
                o.set("lookahead", Json::from(*lookahead));
                o.set("reward", Json::Num(*reward));
                o.set("adjustments", Json::Num(*adjustments as f64));
            }
            TraceEvent::SloEstimateUpdated { t_us, ttft_ms, itl_ms, samples } => {
                o.set("t_us", Json::Num(*t_us));
                o.set("ttft_ms", Json::Num(*ttft_ms));
                o.set("itl_ms", Json::Num(*itl_ms));
                o.set("samples", Json::Num(*samples as f64));
            }
            TraceEvent::SinkDropped { count } => {
                o.set("count", Json::Num(*count as f64));
            }
            TraceEvent::Unknown { kind } => {
                o.set("ev", Json::from(kind.as_str()));
            }
        }
        o
    }

    /// One JSONL line (compact JSON + newline).
    pub fn encode_line(&self) -> String {
        format!("{}\n", self.to_json())
    }

    /// Decode from a parsed JSON object.  Infallible and lenient: an
    /// unknown or missing `"ev"` yields [`TraceEvent::Unknown`]; unknown
    /// fields are ignored; missing fields default (0 / "" / false) —
    /// forward compatibility for old parsers reading newer logs.
    pub fn from_json(v: &Json) -> TraceEvent {
        let kind = v.get("ev").ok().and_then(|k| k.as_str().ok()).unwrap_or("").to_string();
        match kind.as_str() {
            "meta" => TraceEvent::Meta {
                seed: j64(v, "seed", 0),
                temperature: jf(v, "temperature", 0.0),
                max_batch: ju(v, "max_batch", 0),
                queue_capacity: ju(v, "queue_capacity", 0),
                prefill_chunk: ju(v, "prefill_chunk", 0),
                admission: js(v, "admission"),
                kv_budget_mb: ju(v, "kv_budget_mb", 0),
                slo_ttft_ms: jf(v, "slo_ttft_ms", 0.0),
                lookahead: ju(v, "lookahead", 0),
                prefill_tokens: ju(v, "prefill_tokens", 0),
                max_preemptions: ju(v, "max_preemptions", 0),
                faults: js(v, "faults"),
                fault_seed: j64(v, "fault_seed", 0),
                shards: ju(v, "shards", 1).max(1),
                shard_plan: js(v, "shard_plan"),
                replicate_hot: jf(v, "replicate_hot", 0.0),
                quant_tier: jb(v, "quant_tier", false),
                quant_bits: ju(v, "quant_bits", 8),
                error_budget: jf(v, "error_budget", 0.0),
                cache_partition: js(v, "cache_partition"),
                adaptive: jb(v, "adaptive", false),
            },
            "request_arrived" => TraceEvent::RequestArrived {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                prompt: v
                    .get("prompt")
                    .ok()
                    .and_then(|p| p.as_arr().ok())
                    .map(|a| a.iter().filter_map(|t| t.as_f64().ok().map(|n| n as u32)).collect())
                    .unwrap_or_default(),
                max_new: ju(v, "max_new", 0),
                width: ju(v, "width", 1),
                slo_us: v.get("slo_us").ok().and_then(|d| d.as_f64().ok()),
                deadline_us: v.get("deadline_us").ok().and_then(|d| d.as_f64().ok()),
            },
            "request_rejected" => TraceEvent::RequestRejected {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                reason: js(v, "reason"),
                kind: js(v, "kind"),
            },
            "request_admitted" => TraceEvent::RequestAdmitted {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                kv_reserved: j64(v, "kv_reserved", 0),
                queue_delay_us: jf(v, "queue_delay_us", 0.0),
            },
            "kv_budget" => TraceEvent::KvBudget {
                t_us: jf(v, "t_us", 0.0),
                used_bytes: j64(v, "used_bytes", 0),
                borrowed_slots: ju(v, "borrowed_slots", 0),
            },
            "prefill_chunk" => TraceEvent::PrefillChunk {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                start: ju(v, "start", 0),
                len: ju(v, "len", 0),
                is_last: jb(v, "is_last", false),
            },
            "token" => TraceEvent::TokenEmitted {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                token: ju(v, "token", 0) as u32,
                index: ju(v, "index", 0),
            },
            "request_finished" => TraceEvent::RequestFinished {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                tokens: ju(v, "tokens", 0),
                ttft_us: jf(v, "ttft_us", 0.0),
                queue_delay_us: jf(v, "queue_delay_us", 0.0),
            },
            "request_failed" => TraceEvent::RequestFailed {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                reason: js(v, "reason"),
                kind: js(v, "kind"),
            },
            "request_cancelled" => TraceEvent::RequestCancelled {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                phase: js(v, "phase"),
            },
            "request_preempted" => TraceEvent::RequestPreempted {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                kv_released: j64(v, "kv_released", 0),
                preemptions: ju(v, "preemptions", 0),
                tokens_done: ju(v, "tokens_done", 0),
            },
            "request_requeued" => TraceEvent::RequestRequeued {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
            },
            "config_reloaded" => TraceEvent::ConfigReloaded {
                t_us: jf(v, "t_us", 0.0),
                admission: js(v, "admission"),
                kv_budget_mb: ju(v, "kv_budget_mb", 0),
                prefill_chunk: ju(v, "prefill_chunk", 0),
                prefill_tokens: ju(v, "prefill_tokens", 0),
                slo_ttft_ms: jf(v, "slo_ttft_ms", 0.0),
                max_preemptions: ju(v, "max_preemptions", 0),
            },
            "drain_started" => TraceEvent::DrainStarted { t_us: jf(v, "t_us", 0.0) },
            "fault_injected" => TraceEvent::FaultInjected {
                t_us: jf(v, "t_us", 0.0),
                kind: js(v, "kind"),
                delay_us: jf(v, "delay_us", 0.0),
            },
            "shard_assigned" => TraceEvent::ShardAssigned {
                req: j64(v, "req", 0),
                t_us: jf(v, "t_us", 0.0),
                shard: ju(v, "shard", 0),
            },
            "replica_scaled" => TraceEvent::ReplicaScaled {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                replicas: ju(v, "replicas", 1),
            },
            "plan_chosen" => TraceEvent::PlanChosen {
                t_us: jf(v, "t_us", 0.0),
                plan: js(v, "plan"),
                shards: ju(v, "shards", 1),
                bottleneck: js(v, "bottleneck"),
            },
            "cache_lookup" => TraceEvent::CacheLookup {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                hit: jb(v, "hit", false),
                prefetch_hit: jb(v, "prefetch_hit", false),
            },
            "cache_evict" => TraceEvent::CacheEvict {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
            },
            "cache_transfer" => TraceEvent::CacheTransfer {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                bytes: j64(v, "bytes", 0),
            },
            "cache_prefetch" => TraceEvent::CachePrefetch {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                ready_us: jf(v, "ready_us", 0.0),
            },
            "tier_promoted" => TraceEvent::TierPromoted {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                ready_us: jf(v, "ready_us", 0.0),
            },
            "tier_demoted" => TraceEvent::TierDemoted {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
            },
            "quant_hit" => TraceEvent::QuantHit {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                err: jf(v, "err", 0.0),
            },
            "quant_corrected" => TraceEvent::QuantCorrected {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
            },
            "prefetch_issued" => TraceEvent::PrefetchIssued {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                target_layer: ju(v, "target_layer", 0),
                expert: ju(v, "expert", 0),
                distance: ju(v, "distance", 0),
                ready_us: jf(v, "ready_us", 0.0),
            },
            "prefetch_overlapped" => TraceEvent::PrefetchOverlapped {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
                wait_us: jf(v, "wait_us", 0.0),
            },
            "prefetch_cancelled" => TraceEvent::PrefetchCancelled {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                expert: ju(v, "expert", 0),
            },
            "exec_dispatch" => TraceEvent::ExecDispatch {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                chunks: ju(v, "chunks", 0),
                cpu_experts: ju(v, "cpu_experts", 0),
                gpu_experts: ju(v, "gpu_experts", 0),
            },
            "exec_join" => TraceEvent::ExecJoin {
                t_us: jf(v, "t_us", 0.0),
                layer: ju(v, "layer", 0),
                stolen: j64(v, "stolen", 0),
            },
            "controller_adjusted" => TraceEvent::ControllerAdjusted {
                t_us: jf(v, "t_us", 0.0),
                pass: js(v, "pass"),
                lookahead: ju(v, "lookahead", 0),
                reward: jf(v, "reward", 0.0),
                adjustments: j64(v, "adjustments", 0),
            },
            "slo_estimate_updated" => TraceEvent::SloEstimateUpdated {
                t_us: jf(v, "t_us", 0.0),
                ttft_ms: jf(v, "ttft_ms", 0.0),
                itl_ms: jf(v, "itl_ms", 0.0),
                samples: j64(v, "samples", 0),
            },
            "sink_dropped" => TraceEvent::SinkDropped { count: j64(v, "count", 0) },
            _ => TraceEvent::Unknown { kind },
        }
    }

    /// Parse one JSONL line.  Errors only on non-JSON input; any valid
    /// JSON object decodes (possibly to [`TraceEvent::Unknown`]).
    pub fn parse_line(line: &str) -> anyhow::Result<TraceEvent> {
        Ok(TraceEvent::from_json(&Json::parse(line.trim())?))
    }

    /// One instance of every variant — the schema catalog the round-trip
    /// tests iterate (keep in sync with the enum; `kind()` is the
    /// compiler-checked list).
    pub fn examples() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Meta {
                seed: 7,
                temperature: 0.75,
                max_batch: 8,
                queue_capacity: 64,
                prefill_chunk: 16,
                admission: "slo".into(),
                kv_budget_mb: 256,
                slo_ttft_ms: 250.0,
                lookahead: 2,
                prefill_tokens: 128,
                max_preemptions: 2,
                faults: "stall=0.05:30000,err=0.01".into(),
                fault_seed: 13,
                shards: 3,
                shard_plan: "auto".into(),
                replicate_hot: 0.25,
                quant_tier: true,
                quant_bits: 4,
                error_budget: 0.02,
                cache_partition: "layer".into(),
                adaptive: true,
            },
            TraceEvent::RequestArrived {
                req: 1,
                t_us: 1_234.5,
                prompt: vec![3, 1, 4, 1, 5],
                max_new: 24,
                width: 4,
                slo_us: Some(250_000.0),
                deadline_us: Some(900_000.0),
            },
            TraceEvent::RequestRejected {
                req: 2,
                t_us: 1_300.0,
                reason: "queue full".into(),
                kind: "queue_full".into(),
            },
            TraceEvent::RequestAdmitted {
                req: 1,
                t_us: 2_000.0,
                kv_reserved: 1 << 20,
                queue_delay_us: 765.5,
            },
            TraceEvent::KvBudget { t_us: 2_000.0, used_bytes: 1 << 20, borrowed_slots: 1 },
            TraceEvent::PrefillChunk { req: 1, t_us: 2_500.0, start: 0, len: 16, is_last: false },
            TraceEvent::TokenEmitted { req: 1, t_us: 3_000.0, token: 42, index: 0 },
            TraceEvent::RequestFinished {
                req: 1,
                t_us: 9_000.0,
                tokens: 24,
                ttft_us: 1_765.5,
                queue_delay_us: 765.5,
            },
            TraceEvent::RequestFailed {
                req: 3,
                t_us: 9_100.0,
                reason: "server shutting down".into(),
                kind: "shutdown".into(),
            },
            TraceEvent::RequestCancelled { req: 4, t_us: 9_150.0, phase: "decoding".into() },
            TraceEvent::RequestPreempted {
                req: 5,
                t_us: 9_200.0,
                kv_released: 6 << 20,
                preemptions: 1,
                tokens_done: 7,
            },
            TraceEvent::RequestRequeued { req: 5, t_us: 9_200.0 },
            TraceEvent::ConfigReloaded {
                t_us: 9_300.0,
                admission: "slo".into(),
                kv_budget_mb: 128,
                prefill_chunk: 32,
                prefill_tokens: 64,
                slo_ttft_ms: 400.0,
                max_preemptions: 1,
            },
            TraceEvent::DrainStarted { t_us: 9_400.0 },
            TraceEvent::FaultInjected { t_us: 9_500.0, kind: "stall".into(), delay_us: 30_000.0 },
            TraceEvent::ShardAssigned { req: 6, t_us: 9_600.0, shard: 2 },
            TraceEvent::ReplicaScaled { t_us: 9_700.0, layer: 3, expert: 5, replicas: 2 },
            TraceEvent::PlanChosen {
                t_us: 0.0,
                plan: "layer".into(),
                shards: 3,
                bottleneck: "cpu-bw,pcie,gpu".into(),
            },
            TraceEvent::CacheLookup {
                t_us: 2_500.0,
                layer: 3,
                expert: 5,
                hit: true,
                prefetch_hit: true,
            },
            TraceEvent::CacheEvict { t_us: 2_600.0, layer: 0, expert: 7 },
            TraceEvent::CacheTransfer { t_us: 2_600.0, layer: 3, expert: 6, bytes: 1 << 24 },
            TraceEvent::CachePrefetch { t_us: 2_700.0, layer: 4, expert: 2, ready_us: 3_400.0 },
            TraceEvent::TierPromoted { t_us: 2_750.0, layer: 4, expert: 2, ready_us: 3_500.0 },
            TraceEvent::TierDemoted { t_us: 2_760.0, layer: 0, expert: 7 },
            TraceEvent::QuantHit { t_us: 2_770.0, layer: 3, expert: 5, err: 0.004 },
            TraceEvent::QuantCorrected { t_us: 2_780.0, layer: 3, expert: 5 },
            TraceEvent::PrefetchIssued {
                t_us: 2_700.0,
                layer: 3,
                target_layer: 4,
                expert: 2,
                distance: 1,
                ready_us: 3_400.0,
            },
            TraceEvent::PrefetchOverlapped { t_us: 3_300.0, layer: 4, expert: 2, wait_us: 100.0 },
            TraceEvent::PrefetchCancelled { t_us: 3_300.0, layer: 4, expert: 2 },
            TraceEvent::ExecDispatch {
                t_us: 2_500.0,
                layer: 3,
                chunks: 4,
                cpu_experts: 2,
                gpu_experts: 6,
            },
            TraceEvent::ExecJoin { t_us: 2_900.0, layer: 3, stolen: 2 },
            TraceEvent::ControllerAdjusted {
                t_us: 4_100.0,
                pass: "decode".into(),
                lookahead: 2,
                reward: 9.0,
                adjustments: 3,
            },
            TraceEvent::SloEstimateUpdated {
                t_us: 9_000.0,
                ttft_ms: 1.8,
                itl_ms: 0.4,
                samples: 5,
            },
            TraceEvent::SinkDropped { count: 17 },
            TraceEvent::Unknown { kind: "from_the_future".into() },
        ]
    }
}

/// Encode a wire-protocol server event ([`crate::server::Event`]) as the
/// JSON object `server/net.rs` writes — the single encoder shared by the
/// TCP surface, so the wire protocol and the event log cannot drift.
/// `Done` lines carry the full [`crate::metrics::GenMetrics::to_json`]
/// payload (including per-request `cache` and `experts` counters) plus
/// `"done": true`.
pub fn wire_event_json(ev: &crate::server::Event) -> Json {
    let mut o = Json::obj();
    match ev {
        crate::server::Event::Queued(id) => o.set("queued", Json::Num(*id as f64)),
        crate::server::Event::Token(t) => o.set("token", Json::from(*t as usize)),
        crate::server::Event::Done(m) => {
            o = m.to_json();
            o.set("done", Json::Bool(true));
        }
        crate::server::Event::Failed { reason, message, .. } => {
            o.set("error", Json::from(message.as_str()));
            o.set("reason", Json::from(reason.label()));
        }
        crate::server::Event::ControlAck { op } => o.set("ok", Json::from(*op)),
    }
    o
}

/// Lenient field readers: absent or mistyped fields yield the default.
fn jf(v: &Json, k: &str, d: f64) -> f64 {
    v.get(k).ok().and_then(|x| x.as_f64().ok()).unwrap_or(d)
}

fn ju(v: &Json, k: &str, d: usize) -> usize {
    jf(v, k, d as f64) as usize
}

fn j64(v: &Json, k: &str, d: u64) -> u64 {
    jf(v, k, d as f64) as u64
}

fn jb(v: &Json, k: &str, d: bool) -> bool {
    v.get(k).ok().and_then(|x| x.as_bool().ok()).unwrap_or(d)
}

fn js(v: &Json, k: &str) -> String {
    v.get(k).ok().and_then(|x| x.as_str().ok()).unwrap_or("").to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_round_trips() {
        for ev in TraceEvent::examples() {
            let line = ev.encode_line();
            let back = TraceEvent::parse_line(&line)
                .unwrap_or_else(|e| panic!("parse {line:?}: {e}"));
            assert_eq!(back, ev, "round trip changed {line:?}");
        }
    }

    #[test]
    fn examples_cover_distinct_kinds() {
        let kinds: std::collections::BTreeSet<&str> =
            TraceEvent::examples().iter().map(|e| e.kind()).collect();
        assert_eq!(kinds.len(), TraceEvent::examples().len(), "duplicate kind in examples");
    }

    #[test]
    fn unknown_variant_and_fields_are_forward_compatible() {
        // A newer writer's variant this build doesn't know.
        let ev = TraceEvent::parse_line(r#"{"ev":"warp_drive","flux":3.5}"#).unwrap();
        assert_eq!(ev, TraceEvent::Unknown { kind: "warp_drive".into() });
        // A known variant with extra fields: parsed, extras ignored.
        let ev =
            TraceEvent::parse_line(r#"{"ev":"cache_evict","t_us":5,"layer":1,"expert":2,"new_field":"x"}"#)
                .unwrap();
        assert_eq!(ev, TraceEvent::CacheEvict { t_us: 5.0, layer: 1, expert: 2 });
        // Missing fields default instead of erroring.
        let ev = TraceEvent::parse_line(r#"{"ev":"token","req":9}"#).unwrap();
        assert_eq!(ev, TraceEvent::TokenEmitted { req: 9, t_us: 0.0, token: 0, index: 0 });
        // Only non-JSON errors.
        assert!(TraceEvent::parse_line("not json").is_err());
    }

    #[test]
    fn slo_us_key_is_omitted_when_none() {
        let ev = TraceEvent::RequestArrived {
            req: 0,
            t_us: 0.0,
            prompt: vec![1],
            max_new: 1,
            width: 1,
            slo_us: None,
            deadline_us: None,
        };
        let j = ev.to_json();
        assert!(j.get("slo_us").is_err());
        assert!(j.get("deadline_us").is_err());
        assert_eq!(TraceEvent::from_json(&j), ev);
    }

    #[test]
    fn wire_encoding_matches_protocol() {
        let j = wire_event_json(&crate::server::Event::Token(7));
        assert_eq!(j.get("token").unwrap().as_usize().unwrap(), 7);
        let j = wire_event_json(&crate::server::Event::Queued(3));
        assert_eq!(j.get("queued").unwrap().as_usize().unwrap(), 3);
        let j = wire_event_json(&crate::server::Event::error(
            crate::server::FailReason::Backend,
            "boom",
        ));
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(j.get("reason").unwrap().as_str().unwrap(), "backend");
        let j = wire_event_json(&crate::server::Event::ControlAck { op: "drain" });
        assert_eq!(j.get("ok").unwrap().as_str().unwrap(), "drain");
        let m = crate::metrics::GenMetrics {
            enqueue_us: 0.0,
            first_token_us: 10.0,
            token_done_us: vec![10.0, 20.0],
            prompt_tokens: 1,
            ..Default::default()
        };
        let j = wire_event_json(&crate::server::Event::Done(m));
        assert!(j.get("done").unwrap().as_bool().unwrap());
        assert!(j.get("mean_itl_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
