//! Per-request flame summaries from a trace: where each request's
//! wall-clock went — queue wait, prefill chunk cadence, decode ITLs — and
//! what the shared caches did during its window.
//!
//! Cache / prefetch / exec events carry no request id (the caches are
//! shared across the batch), so they are attributed to every request
//! *active* (admitted, not yet finished) at their timestamp — the same
//! overlap-counting semantics as the per-request
//! [`CacheStats::delta_since`](crate::expertcache::CacheStats::delta_since)
//! stamping in [`GenMetrics`](crate::metrics::GenMetrics).

use super::TraceEvent;
use crate::util::stats::{mean, percentile};

/// Flame summary of one request's lifecycle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestSummary {
    pub req: u64,
    pub prompt_tokens: usize,
    pub width: usize,
    pub arrived_us: f64,
    pub admitted_us: f64,
    pub finished_us: f64,
    /// Arrival to admission (0 when never admitted).
    pub queue_us: f64,
    pub prefill_chunks: usize,
    /// Admission to last prefill chunk completing.
    pub prefill_us: f64,
    pub tokens: usize,
    /// Decode inter-token latencies (successive token timestamps).
    pub itl: Vec<f64>,
    /// Shared-cache activity overlapping this request's active window.
    pub cache_hits: usize,
    pub cache_misses: usize,
    pub prefetch_hits: usize,
    pub overlapped: usize,
    pub failed: bool,
    /// Machine-readable terminal reason for failed requests (mirrors the
    /// `kind` field of reject/fail events; "cancelled" for cancels).
    pub fail_reason: String,
    /// Times this request was preempted and requeued.
    pub preemptions: usize,
    /// Adaptive-controller adjustments landing inside this request's
    /// active window (0 on static runs).
    pub ctl_adjustments: usize,
}

impl RequestSummary {
    pub fn end_to_end_us(&self) -> f64 {
        (self.finished_us - self.arrived_us).max(0.0)
    }
}

/// Fold a parsed event stream into per-request flame summaries, in
/// request-id order.
pub fn summarize(events: &[TraceEvent]) -> Vec<RequestSummary> {
    let mut reqs: Vec<RequestSummary> = Vec::new();
    let mut token_t: Vec<Vec<f64>> = Vec::new();
    let find =
        |reqs: &[RequestSummary], id: u64| -> Option<usize> { reqs.iter().position(|r| r.req == id) };
    // A request is "active" between admission and finish/failure; shared
    // cache events at time t are attributed to every active request.
    let mut active: Vec<u64> = Vec::new();
    let charge = |reqs: &mut [RequestSummary], active: &[u64], f: &dyn Fn(&mut RequestSummary)| {
        for id in active {
            if let Some(i) = reqs.iter().position(|r| r.req == *id) {
                f(&mut reqs[i]);
            }
        }
    };
    for ev in events {
        match ev {
            TraceEvent::RequestArrived { req, t_us, prompt, width, .. } => {
                reqs.push(RequestSummary {
                    req: *req,
                    prompt_tokens: prompt.len(),
                    width: *width,
                    arrived_us: *t_us,
                    ..RequestSummary::default()
                });
                token_t.push(Vec::new());
            }
            TraceEvent::RequestAdmitted { req, t_us, queue_delay_us, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].admitted_us = *t_us;
                    reqs[i].queue_us = *queue_delay_us;
                    // Preempted requests are re-admitted; keep one entry.
                    if !active.contains(req) {
                        active.push(*req);
                    }
                }
            }
            TraceEvent::PrefillChunk { req, t_us, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].prefill_chunks += 1;
                    reqs[i].prefill_us = (*t_us - reqs[i].admitted_us).max(0.0);
                }
            }
            TraceEvent::TokenEmitted { req, t_us, index, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    if *index == token_t[i].len() {
                        token_t[i].push(*t_us);
                    } else if *index < token_t[i].len() {
                        token_t[i][*index] = *t_us;
                    }
                }
            }
            TraceEvent::RequestFinished { req, t_us, tokens, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].finished_us = *t_us;
                    reqs[i].tokens = *tokens;
                }
                active.retain(|id| id != req);
            }
            TraceEvent::RequestRejected { req, t_us, kind, .. }
            | TraceEvent::RequestFailed { req, t_us, kind, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].failed = true;
                    reqs[i].finished_us = *t_us;
                    reqs[i].fail_reason = kind.clone();
                }
                active.retain(|id| id != req);
            }
            TraceEvent::RequestCancelled { req, t_us, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].failed = true;
                    reqs[i].finished_us = *t_us;
                    reqs[i].fail_reason = "cancelled".into();
                }
                active.retain(|id| id != req);
            }
            TraceEvent::RequestPreempted { req, preemptions, .. } => {
                if let Some(i) = find(&reqs, *req) {
                    reqs[i].preemptions = *preemptions;
                }
                // Back to the queue: shared cache traffic while waiting
                // for re-admission is not this request's.
                active.retain(|id| id != req);
            }
            TraceEvent::CacheLookup { hit, prefetch_hit, .. } => {
                let (h, p) = (*hit, *prefetch_hit);
                charge(&mut reqs, &active, &|r| {
                    if h {
                        r.cache_hits += 1;
                    } else {
                        r.cache_misses += 1;
                    }
                    if p {
                        r.prefetch_hits += 1;
                    }
                });
            }
            TraceEvent::PrefetchOverlapped { .. } => {
                charge(&mut reqs, &active, &|r| r.overlapped += 1);
            }
            TraceEvent::ControllerAdjusted { .. } => {
                charge(&mut reqs, &active, &|r| r.ctl_adjustments += 1);
            }
            _ => {}
        }
    }
    for (i, r) in reqs.iter_mut().enumerate() {
        r.itl = token_t[i].windows(2).map(|w| w[1] - w[0]).collect();
        if r.tokens == 0 {
            r.tokens = token_t[i].len();
        }
    }
    reqs
}

/// Render summaries as a fixed-width flame table (one row per request)
/// plus an aggregate footer.
pub fn render(summaries: &[RequestSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>4} {:>6} {:>3} {:>9} {:>9} {:>7} {:>9} {:>9} {:>9} {:>6} {:>6} {:>5} {:>5} {:>4} {:>4} {:<13}\n",
        "req",
        "prompt",
        "w",
        "queue_ms",
        "prefil_ms",
        "chunks",
        "itl_p50",
        "itl_p99",
        "e2e_ms",
        "hits",
        "miss",
        "pfhit",
        "ovl",
        "pre",
        "ctl",
        "outcome",
    ));
    for r in summaries {
        if r.failed {
            let reason = if r.fail_reason.is_empty() { "FAILED" } else { r.fail_reason.as_str() };
            out.push_str(&format!(
                "{:>4} {:>6} {:>3} {:>9.1} {:>9} {:>7} {:>9} {:>9} {:>9} {:>6} {:>6} {:>5} {:>5} {:>4} {:>4} {:<13}\n",
                r.req, r.prompt_tokens, r.width, r.queue_us / 1e3,
                "-", "-", "-", "-", "-", "-", "-", "-", "-", r.preemptions, r.ctl_adjustments, reason,
            ));
            continue;
        }
        out.push_str(&format!(
            "{:>4} {:>6} {:>3} {:>9.1} {:>9.1} {:>7} {:>9.1} {:>9.1} {:>9.1} {:>6} {:>6} {:>5} {:>5} {:>4} {:>4} {:<13}\n",
            r.req,
            r.prompt_tokens,
            r.width,
            r.queue_us / 1e3,
            r.prefill_us / 1e3,
            r.prefill_chunks,
            percentile(&r.itl, 50.0) / 1e3,
            percentile(&r.itl, 99.0) / 1e3,
            r.end_to_end_us() / 1e3,
            r.cache_hits,
            r.cache_misses,
            r.prefetch_hits,
            r.overlapped,
            r.preemptions,
            r.ctl_adjustments,
            "ok",
        ));
    }
    let done: Vec<&RequestSummary> = summaries.iter().filter(|r| !r.failed).collect();
    let all_itl: Vec<f64> = done.iter().flat_map(|r| r.itl.iter().copied()).collect();
    let queues: Vec<f64> = done.iter().map(|r| r.queue_us).collect();
    // Terminal-reason histogram for the failed set, alphabetical.
    let mut reasons: std::collections::BTreeMap<&str, usize> = std::collections::BTreeMap::new();
    for r in summaries.iter().filter(|r| r.failed) {
        let k = if r.fail_reason.is_empty() { "unknown" } else { r.fail_reason.as_str() };
        *reasons.entry(k).or_insert(0) += 1;
    }
    let reason_str = if reasons.is_empty() {
        String::new()
    } else {
        format!(
            " | failures: {}",
            reasons.iter().map(|(k, n)| format!("{k}={n}")).collect::<Vec<_>>().join(" ")
        )
    };
    out.push_str(&format!(
        "\n{} requests ({} failed, {} preemptions) | queue mean {:.1} ms | ITL p50 {:.1} / p99 {:.1} ms | tokens {}{}\n",
        summaries.len(),
        summaries.len() - done.len(),
        summaries.iter().map(|r| r.preemptions).sum::<usize>(),
        mean(&queues) / 1e3,
        percentile(&all_itl, 50.0) / 1e3,
        percentile(&all_itl, 99.0) / 1e3,
        done.iter().map(|r| r.tokens).sum::<usize>(),
        reason_str,
    ));
    out
}

/// One-line adaptive-control footer for `trace-summary`: final effective
/// lookahead and adjustment count per pass kind, plus the last learned
/// SLO estimate. Empty string when the trace carries no controller or
/// estimator events (static runs print nothing extra).
pub fn control_footer(events: &[TraceEvent]) -> String {
    // Last ControllerAdjusted per pass kind wins: it carries the final
    // effective lookahead and the cumulative adjustment count.
    let mut per_pass: std::collections::BTreeMap<&str, (usize, u64)> =
        std::collections::BTreeMap::new();
    let mut slo: Option<(f64, f64, u64)> = None;
    for ev in events {
        match ev {
            TraceEvent::ControllerAdjusted { pass, lookahead, adjustments, .. } => {
                per_pass.insert(pass.as_str(), (*lookahead, *adjustments));
            }
            TraceEvent::SloEstimateUpdated { ttft_ms, itl_ms, samples, .. } => {
                slo = Some((*ttft_ms, *itl_ms, *samples));
            }
            _ => {}
        }
    }
    if per_pass.is_empty() && slo.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = per_pass
        .iter()
        .map(|(pass, (la, adj))| format!("{pass} lookahead={la} (adjusted {adj}x)"))
        .collect();
    if let Some((ttft, itl, n)) = slo {
        parts.push(format!("slo est ttft {ttft:.1} ms / itl {itl:.2} ms ({n} samples)"));
    }
    format!("adaptive: {}\n", parts.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrived(req: u64, t: f64) -> TraceEvent {
        TraceEvent::RequestArrived {
            req,
            t_us: t,
            prompt: vec![1, 2, 3],
            max_new: 3,
            width: 1,
            slo_us: None,
            deadline_us: None,
        }
    }

    #[test]
    fn summarize_builds_flame_rows() {
        let events = vec![
            arrived(0, 100.0),
            TraceEvent::RequestAdmitted {
                req: 0,
                t_us: 300.0,
                kv_reserved: 64,
                queue_delay_us: 200.0,
            },
            TraceEvent::PrefillChunk { req: 0, t_us: 900.0, start: 0, len: 2, is_last: false },
            TraceEvent::CacheLookup { t_us: 950.0, layer: 0, expert: 1, hit: true, prefetch_hit: false },
            TraceEvent::PrefillChunk { req: 0, t_us: 1500.0, start: 2, len: 1, is_last: true },
            TraceEvent::TokenEmitted { req: 0, t_us: 1500.0, token: 7, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 2500.0, token: 8, index: 1 },
            TraceEvent::TokenEmitted { req: 0, t_us: 4000.0, token: 9, index: 2 },
            TraceEvent::RequestFinished {
                req: 0,
                t_us: 4000.0,
                tokens: 3,
                ttft_us: 1400.0,
                queue_delay_us: 200.0,
            },
            // After the finish: must not be attributed to request 0.
            TraceEvent::CacheLookup { t_us: 4100.0, layer: 0, expert: 2, hit: false, prefetch_hit: false },
        ];
        let s = summarize(&events);
        assert_eq!(s.len(), 1);
        let r = &s[0];
        assert_eq!(r.queue_us, 200.0);
        assert_eq!(r.prefill_chunks, 2);
        assert_eq!(r.prefill_us, 1200.0);
        assert_eq!(r.tokens, 3);
        assert_eq!(r.itl, vec![1000.0, 1500.0]);
        assert_eq!((r.cache_hits, r.cache_misses), (1, 0));
        assert!(!r.failed);
        let table = render(&s);
        assert!(table.contains("req"), "{table}");
        assert!(table.lines().count() >= 3);
    }

    #[test]
    fn shared_events_attribute_to_all_active_requests() {
        let events = vec![
            arrived(0, 0.0),
            arrived(1, 0.0),
            TraceEvent::RequestAdmitted { req: 0, t_us: 10.0, kv_reserved: 0, queue_delay_us: 10.0 },
            TraceEvent::RequestAdmitted { req: 1, t_us: 20.0, kv_reserved: 0, queue_delay_us: 20.0 },
            TraceEvent::PrefetchOverlapped { t_us: 30.0, layer: 1, expert: 2, wait_us: 5.0 },
            TraceEvent::RequestFinished { req: 0, t_us: 40.0, tokens: 1, ttft_us: 30.0, queue_delay_us: 10.0 },
            // Only request 1 is still active here.
            TraceEvent::CacheLookup { t_us: 50.0, layer: 0, expert: 0, hit: false, prefetch_hit: false },
        ];
        let s = summarize(&events);
        assert_eq!(s[0].overlapped, 1);
        assert_eq!(s[1].overlapped, 1);
        assert_eq!(s[0].cache_misses, 0);
        assert_eq!(s[1].cache_misses, 1);
    }

    #[test]
    fn failed_requests_render_their_terminal_reason() {
        let events = vec![
            arrived(0, 0.0),
            TraceEvent::RequestRejected {
                req: 0,
                t_us: 0.0,
                reason: "queue full".into(),
                kind: "queue_full".into(),
            },
            arrived(1, 0.0),
            TraceEvent::RequestAdmitted { req: 1, t_us: 5.0, kv_reserved: 0, queue_delay_us: 5.0 },
            TraceEvent::RequestCancelled { req: 1, t_us: 9.0, phase: "decoding".into() },
        ];
        let s = summarize(&events);
        assert!(s[0].failed && s[0].fail_reason == "queue_full");
        assert!(s[1].failed && s[1].fail_reason == "cancelled");
        let table = render(&s);
        assert!(table.contains("queue_full"), "{table}");
        assert!(table.contains("cancelled"), "{table}");
        assert!(table.contains("failures: cancelled=1 queue_full=1"), "{table}");
    }

    #[test]
    fn controller_events_charge_the_ctl_column_and_footer() {
        let events = vec![
            arrived(0, 0.0),
            TraceEvent::RequestAdmitted { req: 0, t_us: 10.0, kv_reserved: 0, queue_delay_us: 10.0 },
            TraceEvent::ControllerAdjusted {
                t_us: 20.0,
                pass: "decode".into(),
                lookahead: 3,
                reward: 5.0,
                adjustments: 1,
            },
            TraceEvent::ControllerAdjusted {
                t_us: 30.0,
                pass: "decode".into(),
                lookahead: 2,
                reward: 7.0,
                adjustments: 2,
            },
            TraceEvent::RequestFinished { req: 0, t_us: 40.0, tokens: 1, ttft_us: 30.0, queue_delay_us: 10.0 },
            TraceEvent::SloEstimateUpdated { t_us: 40.0, ttft_ms: 1.5, itl_ms: 0.25, samples: 1 },
        ];
        let s = summarize(&events);
        assert_eq!(s[0].ctl_adjustments, 2);
        assert!(render(&s).contains("ctl"));
        let footer = control_footer(&events);
        assert!(footer.contains("decode lookahead=2 (adjusted 2x)"), "{footer}");
        assert!(footer.contains("slo est ttft 1.5 ms"), "{footer}");
        // Static traces stay silent.
        assert_eq!(control_footer(&events[..2]), "");
    }

    #[test]
    fn preemption_requeues_and_counts_without_double_charging() {
        let events = vec![
            arrived(0, 0.0),
            TraceEvent::RequestAdmitted { req: 0, t_us: 10.0, kv_reserved: 64, queue_delay_us: 10.0 },
            TraceEvent::RequestPreempted {
                req: 0,
                t_us: 50.0,
                kv_released: 64,
                preemptions: 1,
                tokens_done: 1,
            },
            // Shared traffic while parked must not charge request 0.
            TraceEvent::CacheLookup { t_us: 60.0, layer: 0, expert: 0, hit: false, prefetch_hit: false },
            TraceEvent::RequestRequeued { req: 0, t_us: 50.0 },
            TraceEvent::RequestAdmitted { req: 0, t_us: 90.0, kv_reserved: 64, queue_delay_us: 90.0 },
            TraceEvent::CacheLookup { t_us: 95.0, layer: 0, expert: 0, hit: true, prefetch_hit: false },
            TraceEvent::RequestFinished { req: 0, t_us: 120.0, tokens: 3, ttft_us: 40.0, queue_delay_us: 90.0 },
        ];
        let s = summarize(&events);
        assert_eq!(s[0].preemptions, 1);
        assert_eq!(s[0].cache_misses, 0);
        assert_eq!(s[0].cache_hits, 1);
        assert!(!s[0].failed);
        assert!(render(&s).contains("1 preemptions"), "{}", render(&s));
    }
}
