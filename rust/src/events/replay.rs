//! Trace record/replay: fold a recorded JSONL event stream back into the
//! workload and serving configuration that produced it, re-run that
//! workload through the deterministic [`SimBackend`], and diff the token
//! streams.
//!
//! Determinism argument: the trace records every request's exact virtual
//! arrival time, prompt, and sampling-relevant config (seed, temperature,
//! scheduler knobs) in its [`TraceEvent::Meta`] line.  Re-submitting the
//! same arrivals under the same config to a fresh [`SimBackend`] replays
//! the same admission decisions, chunk boundaries, batch compositions,
//! and RNG stream — so the replayed token streams are bit-identical to
//! the recorded ones.  A non-empty [`diff_replay`] therefore means either
//! the log is from a different build/config, or the scheduler has lost
//! determinism — both worth failing CI over.

use super::TraceEvent;
use crate::config::serving::{AdmissionKind, ServingConfig};
use crate::metrics::GenMetrics;
use crate::server::sim::SimBackend;
use crate::server::{serve_lifecycle, ControlMsg, Event, ReloadSpec, Request};
use anyhow::{Context, Result};
use std::path::Path;

/// Parse a JSONL trace file (skipping blank lines).  Unknown event kinds
/// parse as [`TraceEvent::Unknown`] — logs from newer builds still load.
pub fn read_log(path: impl AsRef<Path>) -> Result<Vec<TraceEvent>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    text.lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, l)| {
            TraceEvent::parse_line(l)
                .with_context(|| format!("{}:{}", path.display(), i + 1))
        })
        .collect()
}

/// One request reconstructed from a trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecordedRequest {
    pub id: u64,
    pub arrive_us: f64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub width: usize,
    pub slo_us: Option<f64>,
    /// Enforced end-to-end deadline (relative virtual µs), when recorded.
    pub deadline_us: Option<f64>,
    /// Virtual time the request was cancelled (from
    /// [`TraceEvent::RequestCancelled`]); replay re-sends the cancel at
    /// this exact time so the control applies at the same iteration.
    pub cancel_at_us: Option<f64>,
    /// Client-visible token stream (beam groups: the winning beam).
    pub tokens: Vec<u32>,
    /// Completion time of each streamed token (virtual µs).
    pub token_t_us: Vec<f64>,
    pub finished: bool,
    /// Terminal error: rejected at ingest, failed mid-flight, cancelled,
    /// or drained at shutdown.
    pub failed: bool,
}

/// A trace folded into replayable form.
#[derive(Clone, Debug, Default)]
pub struct RecordedTrace {
    /// The run's `meta` line (always [`TraceEvent::Meta`] when present).
    pub meta: Option<TraceEvent>,
    /// Requests in ingest order (= `req` id order: ids are assigned at
    /// ingest).
    pub requests: Vec<RecordedRequest>,
    /// Control-plane actions in trace order: `(t_us, msg)`.  Reloads are
    /// folded from the FULL post-reload [`TraceEvent::ConfigReloaded`]
    /// snapshot (replay re-applies the snapshot, so one event suffices
    /// regardless of which fields the original delta carried); drains
    /// from [`TraceEvent::DrainStarted`].  Cancels live on their request
    /// (`cancel_at_us`), not here, because they are addressed by id.
    pub controls: Vec<(f64, ControlMsg)>,
}

/// Fold a parsed event stream into per-request records.
pub fn fold_trace(events: &[TraceEvent]) -> RecordedTrace {
    let mut trace = RecordedTrace::default();
    for ev in events {
        match ev {
            TraceEvent::Meta { .. } => trace.meta = Some(ev.clone()),
            TraceEvent::RequestArrived {
                req,
                t_us,
                prompt,
                max_new,
                width,
                slo_us,
                deadline_us,
            } => {
                trace.requests.push(RecordedRequest {
                    id: *req,
                    arrive_us: *t_us,
                    prompt: prompt.clone(),
                    max_new: *max_new,
                    width: *width,
                    slo_us: *slo_us,
                    deadline_us: *deadline_us,
                    ..RecordedRequest::default()
                });
            }
            TraceEvent::TokenEmitted { req, t_us, token, index } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    if *index == r.tokens.len() {
                        r.tokens.push(*token);
                        r.token_t_us.push(*t_us);
                    } else if *index < r.tokens.len() {
                        r.tokens[*index] = *token;
                        r.token_t_us[*index] = *t_us;
                    }
                }
            }
            TraceEvent::RequestFinished { req, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.finished = true;
                }
            }
            TraceEvent::RequestRejected { req, .. } | TraceEvent::RequestFailed { req, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.failed = true;
                }
            }
            TraceEvent::RequestCancelled { req, t_us, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.failed = true;
                    r.cancel_at_us = Some(*t_us);
                }
            }
            TraceEvent::ConfigReloaded {
                t_us,
                admission,
                kv_budget_mb,
                prefill_chunk,
                prefill_tokens,
                slo_ttft_ms,
                max_preemptions,
            } => {
                let spec = ReloadSpec {
                    admission: AdmissionKind::by_name(admission).ok(),
                    kv_budget_mb: Some(*kv_budget_mb),
                    prefill_chunk: Some(*prefill_chunk),
                    prefill_tokens: Some(*prefill_tokens),
                    slo_ttft_ms: Some(*slo_ttft_ms),
                    max_preemptions: Some(*max_preemptions),
                };
                trace.controls.push((*t_us, ControlMsg::Reload(spec)));
            }
            TraceEvent::DrainStarted { t_us } => {
                trace.controls.push((*t_us, ControlMsg::Drain));
            }
            _ => {}
        }
    }
    trace
}

impl RecordedTrace {
    /// Reconstruct the [`ServingConfig`] the trace's `meta` line records.
    /// Knobs the meta line does not carry keep their defaults — they do
    /// not affect SimBackend scheduling or sampling.
    pub fn serving_config(&self) -> Result<ServingConfig> {
        let Some(TraceEvent::Meta {
            seed,
            temperature,
            max_batch,
            queue_capacity,
            prefill_chunk,
            admission,
            kv_budget_mb,
            slo_ttft_ms,
            lookahead,
            prefill_tokens,
            max_preemptions,
            faults,
            fault_seed,
        }) = &self.meta
        else {
            anyhow::bail!("trace has no meta line; cannot reconstruct the serving config");
        };
        Ok(ServingConfig {
            seed: *seed,
            temperature: *temperature,
            max_batch: *max_batch,
            queue_capacity: *queue_capacity,
            prefill_chunk: *prefill_chunk,
            admission: AdmissionKind::by_name(admission)
                .with_context(|| format!("meta admission {admission:?}"))?,
            kv_budget_mb: *kv_budget_mb,
            slo_ttft_ms: *slo_ttft_ms,
            pipeline_lookahead: *lookahead,
            prefill_tokens: *prefill_tokens,
            max_preemptions: *max_preemptions,
            faults: if faults.is_empty() { None } else { Some(faults.clone()) },
            fault_seed: *fault_seed,
            // A replay never overwrites the source trace.
            events_out: None,
            ..ServingConfig::default()
        })
    }
}

/// Outcome of one replayed request.
#[derive(Clone, Debug, Default)]
pub struct ReplayOutcome {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub metrics: Option<GenMetrics>,
    pub error: Option<String>,
}

/// Re-run the recorded workload through a fresh [`SimBackend`] under the
/// trace's own serving config, entirely in virtual time.
pub fn replay_trace(rec: &RecordedTrace) -> Result<Vec<ReplayOutcome>> {
    let serving = rec.serving_config()?;
    let (tx, rx) = std::sync::mpsc::channel();
    let mut control_rx = Vec::new();
    let receivers: Vec<_> = rec
        .requests
        .iter()
        .map(|r| {
            let (etx, erx) = std::sync::mpsc::channel();
            let mut q = Request::new(r.prompt.clone(), r.max_new, etx);
            q.width = r.width;
            q.slo_us = r.slo_us;
            q.deadline_us = r.deadline_us;
            q.arrive_at_us = Some(r.arrive_us);
            tx.send(q).expect("loop not started yet");
            // Re-send the recorded cancel at its recorded time: the
            // scheduler parks it until the virtual clock reaches it, so
            // it applies at the same iteration boundary as the original.
            if let Some(ct) = r.cancel_at_us {
                let (ctx, crx) = std::sync::mpsc::channel();
                let mut c = Request::control(ControlMsg::Cancel { req: r.id }, ctx);
                c.arrive_at_us = Some(ct);
                tx.send(c).expect("loop not started yet");
                control_rx.push(crx);
            }
            (r.id, erx)
        })
        .collect();
    for (t, msg) in &rec.controls {
        let (ctx, crx) = std::sync::mpsc::channel();
        let mut c = Request::control(msg.clone(), ctx);
        c.arrive_at_us = Some(*t);
        tx.send(c).expect("loop not started yet");
        control_rx.push(crx);
    }
    let mut sentinel = Request::shutdown_sentinel();
    sentinel.arrive_at_us = Some(1e15); // fires once the loop idles out
    tx.send(sentinel).expect("loop not started yet");

    let mut backend = SimBackend::new(serving);
    serve_lifecycle(&mut backend, rx)?;
    drop(tx);
    drop(control_rx);

    Ok(receivers
        .into_iter()
        .map(|(id, rx)| {
            let mut out = ReplayOutcome { id, ..ReplayOutcome::default() };
            for ev in rx.try_iter() {
                match ev {
                    Event::Queued(_) | Event::ControlAck { .. } => {}
                    Event::Token(t) => out.tokens.push(t),
                    Event::Done(m) => out.metrics = Some(m),
                    Event::Failed { message, .. } => out.error = Some(message),
                }
            }
            out
        })
        .collect())
}

/// Compare a recorded trace against its replay.  Empty = bit-identical
/// client-visible outcome (same token streams, same terminal states).
pub fn diff_replay(rec: &RecordedTrace, replayed: &[ReplayOutcome]) -> Vec<String> {
    let mut diffs = Vec::new();
    if rec.requests.len() != replayed.len() {
        diffs.push(format!(
            "request count diverged: recorded {} vs replayed {}",
            rec.requests.len(),
            replayed.len()
        ));
        return diffs;
    }
    for (r, o) in rec.requests.iter().zip(replayed) {
        if r.id != o.id {
            diffs.push(format!("request order diverged: recorded id {} vs replayed {}", r.id, o.id));
            continue;
        }
        if r.failed {
            if o.error.is_none() {
                diffs.push(format!("req {}: recorded a terminal error, replay succeeded", r.id));
            }
            continue;
        }
        if let Some(e) = &o.error {
            diffs.push(format!("req {}: replay failed ({e}), recording succeeded", r.id));
            continue;
        }
        if r.tokens != o.tokens {
            diffs.push(format!(
                "req {}: token stream diverged ({} recorded vs {} replayed tokens{})",
                r.id,
                r.tokens.len(),
                o.tokens.len(),
                r.tokens
                    .iter()
                    .zip(&o.tokens)
                    .position(|(a, b)| a != b)
                    .map(|i| format!(", first mismatch at index {i}"))
                    .unwrap_or_default()
            ));
        }
    }
    diffs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TraceEvent {
        TraceEvent::Meta {
            seed: 7,
            temperature: 0.5,
            max_batch: 4,
            queue_capacity: 16,
            prefill_chunk: 8,
            admission: "sjf".to_string(),
            kv_budget_mb: 64,
            slo_ttft_ms: 400.0,
            lookahead: 2,
            prefill_tokens: 0,
            max_preemptions: 0,
            faults: String::new(),
            fault_seed: 0,
        }
    }

    #[test]
    fn fold_reconstructs_requests_and_token_streams() {
        let events = vec![
            meta(),
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 10.0,
                prompt: vec![1, 2],
                max_new: 2,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 50.0, token: 9, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 80.0, token: 4, index: 1 },
            TraceEvent::RequestFinished {
                req: 0,
                t_us: 80.0,
                tokens: 2,
                ttft_us: 40.0,
                queue_delay_us: 0.0,
            },
            TraceEvent::RequestArrived {
                req: 1,
                t_us: 20.0,
                prompt: vec![3],
                max_new: 1,
                width: 1,
                slo_us: Some(9e5),
                deadline_us: None,
            },
            TraceEvent::RequestRejected {
                req: 1,
                t_us: 20.0,
                reason: "queue full".into(),
                kind: "queue_full".into(),
            },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests.len(), 2);
        assert_eq!(t.requests[0].tokens, vec![9, 4]);
        assert_eq!(t.requests[0].token_t_us, vec![50.0, 80.0]);
        assert!(t.requests[0].finished && !t.requests[0].failed);
        assert!(t.requests[1].failed && !t.requests[1].finished);
        assert_eq!(t.requests[1].slo_us, Some(9e5));
        let cfg = t.serving_config().unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.admission, AdmissionKind::ShortestFirst);
        assert_eq!(cfg.prefill_chunk, 8);
        assert_eq!(cfg.pipeline_lookahead, 2);
        assert!(cfg.events_out.is_none());
    }

    #[test]
    fn fold_captures_cancels_and_control_timeline() {
        let events = vec![
            meta(),
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 4,
                width: 1,
                slo_us: None,
                deadline_us: Some(5e5),
            },
            TraceEvent::RequestCancelled { req: 0, t_us: 120.0, phase: "decoding".into() },
            TraceEvent::ConfigReloaded {
                t_us: 200.0,
                admission: "fcfs".into(),
                kv_budget_mb: 32,
                prefill_chunk: 4,
                prefill_tokens: 16,
                slo_ttft_ms: 250.0,
                max_preemptions: 2,
            },
            TraceEvent::DrainStarted { t_us: 300.0 },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests[0].deadline_us, Some(5e5));
        assert!(t.requests[0].failed);
        assert_eq!(t.requests[0].cancel_at_us, Some(120.0));
        assert_eq!(t.controls.len(), 2);
        assert_eq!(t.controls[0].0, 200.0);
        match &t.controls[0].1 {
            ControlMsg::Reload(spec) => {
                assert_eq!(spec.admission, Some(AdmissionKind::Fcfs));
                assert_eq!(spec.kv_budget_mb, Some(32));
                assert_eq!(spec.prefill_tokens, Some(16));
                assert_eq!(spec.max_preemptions, Some(2));
            }
            other => panic!("expected reload, got {other:?}"),
        }
        assert!(matches!(t.controls[1].1, ControlMsg::Drain));
    }

    #[test]
    fn beam_retire_reemission_overwrites_in_place() {
        // Beam winners are streamed at retire with indexes from 0; the
        // fold must not double-count them against interim emissions.
        let events = vec![
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 2,
                width: 2,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 99.0, token: 5, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 99.0, token: 6, index: 1 },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests[0].tokens, vec![5, 6]);
    }

    #[test]
    fn metaless_trace_cannot_replay() {
        let t = fold_trace(&[]);
        assert!(t.serving_config().is_err());
    }

    #[test]
    fn diff_flags_divergence_and_accepts_identity() {
        let events = vec![
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 2,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 1.0, token: 7, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 2.0, token: 8, index: 1 },
            TraceEvent::RequestFinished {
                req: 0,
                t_us: 2.0,
                tokens: 2,
                ttft_us: 1.0,
                queue_delay_us: 0.0,
            },
        ];
        let rec = fold_trace(&events);
        let good = vec![ReplayOutcome { id: 0, tokens: vec![7, 8], ..Default::default() }];
        assert!(diff_replay(&rec, &good).is_empty());
        let bad = vec![ReplayOutcome { id: 0, tokens: vec![7, 9], ..Default::default() }];
        let d = diff_replay(&rec, &bad);
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("index 1"), "{d:?}");
        assert_eq!(diff_replay(&rec, &[]).len(), 1);
    }
}
