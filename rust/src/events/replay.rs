//! Trace record/replay: fold a recorded JSONL event stream back into the
//! workload and serving configuration that produced it, re-run that
//! workload through the deterministic [`SimBackend`], and diff the token
//! streams.
//!
//! Determinism argument: the trace records every request's exact virtual
//! arrival time, prompt, and sampling-relevant config (seed, temperature,
//! scheduler knobs) in its [`TraceEvent::Meta`] line.  Re-submitting the
//! same arrivals under the same config to a fresh [`SimBackend`] replays
//! the same admission decisions, chunk boundaries, batch compositions,
//! and RNG stream — so the replayed token streams are bit-identical to
//! the recorded ones.  A non-empty [`diff_replay`] therefore means either
//! the log is from a different build/config, or the scheduler has lost
//! determinism — both worth failing CI over.

use super::TraceEvent;
use crate::config::serving::{AdmissionKind, CachePartition, ServingConfig, ShardPlan};
use crate::metrics::GenMetrics;
use crate::server::sim::SimBackend;
use crate::server::{serve_lifecycle, ControlMsg, Event, ReloadSpec, Request, ServeBackend};
use anyhow::{Context, Result};
use std::path::Path;

/// Parse a JSONL trace file (skipping blank lines).  Unknown event kinds
/// parse as [`TraceEvent::Unknown`] — logs from newer builds still load.
pub fn read_log(path: impl AsRef<Path>) -> Result<Vec<TraceEvent>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    text.lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, l)| {
            TraceEvent::parse_line(l)
                .with_context(|| format!("{}:{}", path.display(), i + 1))
        })
        .collect()
}

/// One request reconstructed from a trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecordedRequest {
    pub id: u64,
    pub arrive_us: f64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub width: usize,
    pub slo_us: Option<f64>,
    /// Enforced end-to-end deadline (relative virtual µs), when recorded.
    pub deadline_us: Option<f64>,
    /// Virtual time the request was cancelled (from
    /// [`TraceEvent::RequestCancelled`]); replay re-sends the cancel at
    /// this exact time so the control applies at the same iteration.
    pub cancel_at_us: Option<f64>,
    /// Owning engine from the router's [`TraceEvent::ShardAssigned`]
    /// line (`None` on single-engine traces, which predate the fleet).
    pub shard: Option<usize>,
    /// Client-visible token stream (beam groups: the winning beam).
    pub tokens: Vec<u32>,
    /// Completion time of each streamed token (virtual µs).
    pub token_t_us: Vec<f64>,
    pub finished: bool,
    /// Terminal error: rejected at ingest, failed mid-flight, cancelled,
    /// or drained at shutdown.
    pub failed: bool,
}

/// A trace folded into replayable form.
#[derive(Clone, Debug, Default)]
pub struct RecordedTrace {
    /// The run's `meta` line (always [`TraceEvent::Meta`] when present).
    pub meta: Option<TraceEvent>,
    /// Requests in ingest order (= `req` id order: ids are assigned at
    /// ingest).
    pub requests: Vec<RecordedRequest>,
    /// Control-plane actions in trace order: `(t_us, msg)`.  Reloads are
    /// folded from the FULL post-reload [`TraceEvent::ConfigReloaded`]
    /// snapshot (replay re-applies the snapshot, so one event suffices
    /// regardless of which fields the original delta carried); drains
    /// from [`TraceEvent::DrainStarted`].  Cancels live on their request
    /// (`cancel_at_us`), not here, because they are addressed by id.
    pub controls: Vec<(f64, ControlMsg)>,
}

/// Fold a parsed event stream into per-request records.
pub fn fold_trace(events: &[TraceEvent]) -> RecordedTrace {
    let mut trace = RecordedTrace::default();
    // The router assigns shards at routing time, which can precede the
    // owning engine's RequestArrived line — collect them on the side.
    let mut shards = std::collections::HashMap::new();
    for ev in events {
        match ev {
            TraceEvent::Meta { .. } => trace.meta = Some(ev.clone()),
            TraceEvent::ShardAssigned { req, shard, .. } => {
                shards.insert(*req, *shard);
            }
            TraceEvent::RequestArrived {
                req,
                t_us,
                prompt,
                max_new,
                width,
                slo_us,
                deadline_us,
            } => {
                trace.requests.push(RecordedRequest {
                    id: *req,
                    arrive_us: *t_us,
                    prompt: prompt.clone(),
                    max_new: *max_new,
                    width: *width,
                    slo_us: *slo_us,
                    deadline_us: *deadline_us,
                    ..RecordedRequest::default()
                });
            }
            TraceEvent::TokenEmitted { req, t_us, token, index } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    if *index == r.tokens.len() {
                        r.tokens.push(*token);
                        r.token_t_us.push(*t_us);
                    } else if *index < r.tokens.len() {
                        r.tokens[*index] = *token;
                        r.token_t_us[*index] = *t_us;
                    }
                }
            }
            TraceEvent::RequestFinished { req, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.finished = true;
                }
            }
            TraceEvent::RequestRejected { req, .. } | TraceEvent::RequestFailed { req, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.failed = true;
                }
            }
            TraceEvent::RequestCancelled { req, t_us, .. } => {
                if let Some(r) = trace.requests.iter_mut().find(|r| r.id == *req) {
                    r.failed = true;
                    r.cancel_at_us = Some(*t_us);
                }
            }
            TraceEvent::ConfigReloaded {
                t_us,
                admission,
                kv_budget_mb,
                prefill_chunk,
                prefill_tokens,
                slo_ttft_ms,
                max_preemptions,
            } => {
                let spec = ReloadSpec {
                    admission: AdmissionKind::by_name(admission).ok(),
                    kv_budget_mb: Some(*kv_budget_mb),
                    prefill_chunk: Some(*prefill_chunk),
                    prefill_tokens: Some(*prefill_tokens),
                    slo_ttft_ms: Some(*slo_ttft_ms),
                    max_preemptions: Some(*max_preemptions),
                };
                trace.controls.push((*t_us, ControlMsg::Reload(spec)));
            }
            TraceEvent::DrainStarted { t_us } => {
                trace.controls.push((*t_us, ControlMsg::Drain));
            }
            _ => {}
        }
    }
    for r in &mut trace.requests {
        r.shard = shards.get(&r.id).copied();
    }
    trace
}

impl RecordedTrace {
    /// Reconstruct the [`ServingConfig`] the trace's `meta` line records.
    /// Knobs the meta line does not carry keep their defaults — they do
    /// not affect SimBackend scheduling or sampling.
    pub fn serving_config(&self) -> Result<ServingConfig> {
        let Some(TraceEvent::Meta {
            seed,
            temperature,
            max_batch,
            queue_capacity,
            prefill_chunk,
            admission,
            kv_budget_mb,
            slo_ttft_ms,
            lookahead,
            prefill_tokens,
            max_preemptions,
            faults,
            fault_seed,
            shards,
            shard_plan,
            replicate_hot,
            quant_tier,
            quant_bits,
            error_budget,
            cache_partition,
            adaptive,
        }) = &self.meta
        else {
            anyhow::bail!("trace has no meta line; cannot reconstruct the serving config");
        };
        Ok(ServingConfig {
            seed: *seed,
            temperature: *temperature,
            max_batch: *max_batch,
            queue_capacity: *queue_capacity,
            prefill_chunk: *prefill_chunk,
            admission: AdmissionKind::by_name(admission)
                .with_context(|| format!("meta admission {admission:?}"))?,
            kv_budget_mb: *kv_budget_mb,
            slo_ttft_ms: *slo_ttft_ms,
            pipeline_lookahead: *lookahead,
            prefill_tokens: *prefill_tokens,
            max_preemptions: *max_preemptions,
            faults: if faults.is_empty() { None } else { Some(faults.clone()) },
            fault_seed: *fault_seed,
            shards: (*shards).max(1),
            // Legacy single-engine traces predate the field and record "".
            shard_plan: if shard_plan.is_empty() {
                ShardPlan::Auto
            } else {
                ShardPlan::by_name(shard_plan)
                    .with_context(|| format!("meta shard_plan {shard_plan:?}"))?
            },
            replicate_hot: *replicate_hot,
            quant_tier: *quant_tier,
            quant_bits: (*quant_bits).clamp(2, 16) as u32,
            error_budget: *error_budget,
            // Legacy traces predate the field and record "".
            cache_partition: CachePartition::by_name(cache_partition)
                .with_context(|| format!("meta cache_partition {cache_partition:?}"))?,
            // Legacy traces decode false: replay stays static, like the run.
            adaptive: *adaptive,
            // A replay never overwrites the source trace.
            events_out: None,
            ..ServingConfig::default()
        })
    }

    /// Engine count the trace was recorded under (1 for legacy traces).
    pub fn recorded_shards(&self) -> usize {
        match &self.meta {
            Some(TraceEvent::Meta { shards, .. }) => (*shards).max(1),
            _ => 1,
        }
    }
}

/// Outcome of one replayed request.
#[derive(Clone, Debug, Default)]
pub struct ReplayOutcome {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub metrics: Option<GenMetrics>,
    pub error: Option<String>,
}

/// Re-run the recorded workload through fresh [`SimBackend`]s under the
/// trace's own serving config, entirely in virtual time.
pub fn replay_trace(rec: &RecordedTrace) -> Result<Vec<ReplayOutcome>> {
    replay_with_config(rec, rec.serving_config()?)
}

/// Fold the per-shard copies of each broadcast control back into one
/// action per broadcast: a fleet recording carries one `config_reloaded`
/// / `drain_started` line PER SHARD (the router broadcasts, every
/// engine's lifecycle logs its own application).  Copies are grouped by
/// op kind and per-shard sequence position; each group replays at the
/// EARLIEST recorded application time, which every shard's own
/// iteration-boundary clock then rounds back up to exactly its recorded
/// application point.  Counts that don't divide evenly (a shard died
/// before a control reached it) keep every copy rather than guess.
fn dedup_broadcast_controls(
    controls: &[(f64, ControlMsg)],
    recorded_shards: usize,
) -> Vec<(f64, ControlMsg)> {
    if recorded_shards <= 1 || controls.is_empty() {
        return controls.to_vec();
    }
    let mut by_kind: std::collections::BTreeMap<&'static str, Vec<&(f64, ControlMsg)>> =
        std::collections::BTreeMap::new();
    for c in controls {
        by_kind.entry(c.1.op()).or_default().push(c);
    }
    let mut out = Vec::new();
    for group in by_kind.into_values() {
        if group.len() % recorded_shards != 0 {
            out.extend(group.into_iter().cloned());
            continue;
        }
        let per_shard = group.len() / recorded_shards;
        for j in 0..per_shard {
            let copies: Vec<_> = (0..recorded_shards).map(|s| group[s * per_shard + j]).collect();
            let t = copies.iter().map(|c| c.0).fold(f64::INFINITY, f64::min);
            out.push((t, copies[0].1.clone()));
        }
    }
    out.sort_by(|a, b| a.0.total_cmp(&b.0));
    out
}

/// Re-run the recorded workload under an arbitrary serving config — the
/// substrate of `trace-replay --config-override` A/B runs.  Under the
/// trace's own config this reproduces the recording bit-for-bit (the
/// pin/plan derivations below are pure functions of the recorded
/// prompts and placements, shared with the live fleet driver); under an
/// override the client-visible streams may legitimately change, which
/// is why A/B comparisons diff aggregates ([`aggregate_outcomes`]), not
/// tokens.
pub fn replay_with_config(
    rec: &RecordedTrace,
    serving: ServingConfig,
) -> Result<Vec<ReplayOutcome>> {
    use crate::config::HardwareConfig;
    use crate::latency::LatencyModel;
    use crate::server::fleet::{pin_worthwhile, plan_shards};
    use crate::server::sim::{
        sim_arrival_horizon_s, sim_demand_profile, SIM_FLEET_GPU_CAPACITY, SIM_FLEET_MAX_PINS,
    };

    let n = serving.shards.max(1);
    let recorded = rec.recorded_shards();
    // Recorded placement is honored when the engine count is unchanged;
    // otherwise fall back to deterministic round-robin by request id.
    let shard_of: Vec<usize> = rec
        .requests
        .iter()
        .map(|r| match r.shard {
            Some(s) if n == recorded && s < n => s,
            _ => (r.id % n as u64) as usize,
        })
        .collect();
    let mut per_shard = vec![0usize; n];
    for &s in &shard_of {
        per_shard[s] += 1;
    }

    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        txs.push(tx);
        rxs.push(rx);
    }
    let mut control_rx = Vec::new();
    let receivers: Vec<_> = rec
        .requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let (etx, erx) = std::sync::mpsc::channel();
            let mut q = Request::new(r.prompt.clone(), r.max_new, etx);
            q.id = Some(r.id);
            q.width = r.width;
            q.slo_us = r.slo_us;
            q.deadline_us = r.deadline_us;
            q.arrive_at_us = Some(r.arrive_us);
            txs[shard_of[i]].send(q).expect("loop not started yet");
            // Re-send the recorded cancel at its recorded time: the
            // scheduler parks it until the virtual clock reaches it, so
            // it applies at the same iteration boundary as the original.
            if let Some(ct) = r.cancel_at_us {
                let (ctx, crx) = std::sync::mpsc::channel();
                let mut c = Request::control(ControlMsg::Cancel { req: r.id }, ctx);
                c.arrive_at_us = Some(ct);
                txs[shard_of[i]].send(c).expect("loop not started yet");
                control_rx.push(crx);
            }
            (r.id, erx)
        })
        .collect();
    for (t, msg) in dedup_broadcast_controls(&rec.controls, recorded) {
        for tx in &txs {
            let (ctx, crx) = std::sync::mpsc::channel();
            let mut c = Request::control(msg.clone(), ctx);
            c.arrive_at_us = Some(t);
            tx.send(c).expect("loop not started yet");
            control_rx.push(crx);
        }
    }
    for tx in &txs {
        let mut sentinel = Request::shutdown_sentinel();
        sentinel.arrive_at_us = Some(1e15); // fires once the loop idles out
        tx.send(sentinel).expect("loop not started yet");
    }

    // Same plan/pin derivation as the live fleet driver (`sim.rs`):
    // demand profile and per-shard rates are pure functions of the
    // recorded prompts and placements, so the pins reproduce exactly.
    let profile = sim_demand_profile(rec.requests.iter().map(|r| r.prompt.as_slice()));
    let model = LatencyModel::from_hardware(&HardwareConfig::env1());
    let quant_bits = serving.quant_tier.then_some(serving.quant_bits);
    let plan =
        plan_shards(&profile, &model, n, serving.shard_plan, SIM_FLEET_GPU_CAPACITY, quant_bits);
    let horizon_s = sim_arrival_horizon_s(rec.requests.iter().map(|r| r.arrive_us));
    for (s, rx) in rxs.into_iter().enumerate() {
        let mut backend = SimBackend::new(serving.clone());
        if n > 1 {
            let shard_rate = per_shard[s] as f64 / horizon_s;
            pin_worthwhile(
                backend.expert_cache_mut(),
                &profile,
                &plan,
                s,
                shard_rate,
                horizon_s,
                &model,
                SIM_FLEET_MAX_PINS,
            );
        }
        serve_lifecycle(&mut backend, rx)?;
    }
    drop(txs);
    drop(control_rx);

    Ok(receivers
        .into_iter()
        .map(|(id, rx)| {
            let mut out = ReplayOutcome { id, ..ReplayOutcome::default() };
            for ev in rx.try_iter() {
                match ev {
                    Event::Queued(_) | Event::ControlAck { .. } => {}
                    Event::Token(t) => out.tokens.push(t),
                    Event::Done(m) => out.metrics = Some(m),
                    Event::Failed { message, .. } => out.error = Some(message),
                }
            }
            out
        })
        .collect())
}

/// Parse a `--config-override` spec (`key=value`, comma-separated, CLI
/// flag spellings — underscores also accepted) onto a serving config.
pub fn apply_config_overrides(cfg: &mut ServingConfig, spec: &str) -> Result<()> {
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (key, val) = part.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--config-override: expected key=value in {part:?}")
        })?;
        let key = key.trim().replace('_', "-");
        let val = val.trim();
        let parse_usize = |v: &str| -> Result<usize> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("--config-override: bad value {v:?} in {part:?}"))
        };
        let parse_f64 = |v: &str| -> Result<f64> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("--config-override: bad value {v:?} in {part:?}"))
        };
        match key.as_str() {
            "shards" => cfg.shards = parse_usize(val)?.max(1),
            "shard-plan" => cfg.shard_plan = ShardPlan::by_name(val)?,
            "replicate-hot" => cfg.replicate_hot = parse_f64(val)?,
            "admission" => cfg.admission = AdmissionKind::by_name(val)?,
            "max-batch" => cfg.max_batch = parse_usize(val)?,
            "queue-capacity" => cfg.queue_capacity = parse_usize(val)?,
            "prefill-chunk" => cfg.prefill_chunk = parse_usize(val)?,
            "prefill-tokens" => cfg.prefill_tokens = parse_usize(val)?,
            "kv-budget-mb" => cfg.kv_budget_mb = parse_usize(val)?,
            "slo-ttft-ms" => cfg.slo_ttft_ms = parse_f64(val)?,
            "max-preemptions" => cfg.max_preemptions = parse_usize(val)?,
            "lookahead" => cfg.pipeline_lookahead = parse_usize(val)?,
            "quant-tier" => {
                cfg.quant_tier = match val {
                    "on" => true,
                    "off" => false,
                    other => anyhow::bail!(
                        "--config-override: quant-tier must be on or off, got {other:?}"
                    ),
                }
            }
            "quant-bits" => {
                let bits = parse_usize(val)?;
                anyhow::ensure!((2..=16).contains(&bits), "quant-bits must be in [2, 16]");
                cfg.quant_bits = bits as u32;
            }
            "error-budget" => cfg.error_budget = parse_f64(val)?.max(0.0),
            "cache-partition" => cfg.cache_partition = CachePartition::by_name(val)?,
            "adaptive" => {
                cfg.adaptive = match val {
                    "on" => true,
                    "off" => false,
                    other => anyhow::bail!(
                        "--config-override: adaptive must be on or off, got {other:?}"
                    ),
                }
            }
            _ => anyhow::bail!("--config-override: unknown key {key:?}"),
        }
    }
    Ok(())
}

/// Aggregate client-visible metrics of one replay run — the surface
/// `trace-replay --config-override` A/B comparisons diff (token streams
/// legitimately change under a different config; throughput and latency
/// aggregates are what stays comparable).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplayAggregate {
    pub completed: usize,
    pub failed: usize,
    pub output_tokens: usize,
    pub mean_ttft_ms: f64,
    pub mean_itl_ms: f64,
    /// Last token completion on any engine's clock (virtual seconds).
    pub last_token_s: f64,
}

pub fn aggregate_outcomes(outcomes: &[ReplayOutcome]) -> ReplayAggregate {
    let mut a = ReplayAggregate::default();
    let (mut ttft_sum, mut ttft_n) = (0.0, 0usize);
    let (mut itl_sum, mut itl_n) = (0.0, 0usize);
    for o in outcomes {
        if o.error.is_some() {
            a.failed += 1;
            continue;
        }
        a.completed += 1;
        a.output_tokens += o.tokens.len();
        if let Some(m) = &o.metrics {
            ttft_sum += m.ttft_us();
            ttft_n += 1;
            for itl in m.itl_us() {
                itl_sum += itl;
                itl_n += 1;
            }
            if let Some(&t) = m.token_done_us.last() {
                a.last_token_s = a.last_token_s.max(t / 1e6);
            }
        }
    }
    if ttft_n > 0 {
        a.mean_ttft_ms = ttft_sum / ttft_n as f64 / 1e3;
    }
    if itl_n > 0 {
        a.mean_itl_ms = itl_sum / itl_n as f64 / 1e3;
    }
    a
}

/// Human-readable baseline → override deltas, one line per metric.
pub fn diff_aggregates(base: &ReplayAggregate, over: &ReplayAggregate) -> Vec<String> {
    fn pct(b: f64, o: f64) -> String {
        if b.abs() < 1e-12 {
            return "n/a".to_string();
        }
        format!("{:+.1}%", (o - b) / b * 100.0)
    }
    vec![
        format!("completed: {} -> {}", base.completed, over.completed),
        format!("failed: {} -> {}", base.failed, over.failed),
        format!("output_tokens: {} -> {}", base.output_tokens, over.output_tokens),
        format!(
            "mean_ttft_ms: {:.2} -> {:.2} ({})",
            base.mean_ttft_ms,
            over.mean_ttft_ms,
            pct(base.mean_ttft_ms, over.mean_ttft_ms)
        ),
        format!(
            "mean_itl_ms: {:.2} -> {:.2} ({})",
            base.mean_itl_ms,
            over.mean_itl_ms,
            pct(base.mean_itl_ms, over.mean_itl_ms)
        ),
        format!(
            "last_token_s: {:.3} -> {:.3} ({})",
            base.last_token_s,
            over.last_token_s,
            pct(base.last_token_s, over.last_token_s)
        ),
    ]
}

/// Compare a recorded trace against its replay.  Empty = bit-identical
/// client-visible outcome (same token streams, same terminal states).
pub fn diff_replay(rec: &RecordedTrace, replayed: &[ReplayOutcome]) -> Vec<String> {
    let mut diffs = Vec::new();
    if rec.requests.len() != replayed.len() {
        diffs.push(format!(
            "request count diverged: recorded {} vs replayed {}",
            rec.requests.len(),
            replayed.len()
        ));
        return diffs;
    }
    for (r, o) in rec.requests.iter().zip(replayed) {
        if r.id != o.id {
            diffs.push(format!("request order diverged: recorded id {} vs replayed {}", r.id, o.id));
            continue;
        }
        if r.failed {
            if o.error.is_none() {
                diffs.push(format!("req {}: recorded a terminal error, replay succeeded", r.id));
            }
            continue;
        }
        if let Some(e) = &o.error {
            diffs.push(format!("req {}: replay failed ({e}), recording succeeded", r.id));
            continue;
        }
        if r.tokens != o.tokens {
            diffs.push(format!(
                "req {}: token stream diverged ({} recorded vs {} replayed tokens{})",
                r.id,
                r.tokens.len(),
                o.tokens.len(),
                r.tokens
                    .iter()
                    .zip(&o.tokens)
                    .position(|(a, b)| a != b)
                    .map(|i| format!(", first mismatch at index {i}"))
                    .unwrap_or_default()
            ));
        }
    }
    diffs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TraceEvent {
        TraceEvent::Meta {
            seed: 7,
            temperature: 0.5,
            max_batch: 4,
            queue_capacity: 16,
            prefill_chunk: 8,
            admission: "sjf".to_string(),
            kv_budget_mb: 64,
            slo_ttft_ms: 400.0,
            lookahead: 2,
            prefill_tokens: 0,
            max_preemptions: 0,
            faults: String::new(),
            fault_seed: 0,
            shards: 1,
            shard_plan: "auto".to_string(),
            replicate_hot: 0.0,
            quant_tier: false,
            quant_bits: 8,
            error_budget: 0.0,
            cache_partition: String::new(),
            adaptive: false,
        }
    }

    #[test]
    fn fold_reconstructs_requests_and_token_streams() {
        let events = vec![
            meta(),
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 10.0,
                prompt: vec![1, 2],
                max_new: 2,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 50.0, token: 9, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 80.0, token: 4, index: 1 },
            TraceEvent::RequestFinished {
                req: 0,
                t_us: 80.0,
                tokens: 2,
                ttft_us: 40.0,
                queue_delay_us: 0.0,
            },
            TraceEvent::RequestArrived {
                req: 1,
                t_us: 20.0,
                prompt: vec![3],
                max_new: 1,
                width: 1,
                slo_us: Some(9e5),
                deadline_us: None,
            },
            TraceEvent::RequestRejected {
                req: 1,
                t_us: 20.0,
                reason: "queue full".into(),
                kind: "queue_full".into(),
            },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests.len(), 2);
        assert_eq!(t.requests[0].tokens, vec![9, 4]);
        assert_eq!(t.requests[0].token_t_us, vec![50.0, 80.0]);
        assert!(t.requests[0].finished && !t.requests[0].failed);
        assert!(t.requests[1].failed && !t.requests[1].finished);
        assert_eq!(t.requests[1].slo_us, Some(9e5));
        let cfg = t.serving_config().unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.admission, AdmissionKind::ShortestFirst);
        assert_eq!(cfg.prefill_chunk, 8);
        assert_eq!(cfg.pipeline_lookahead, 2);
        assert!(cfg.events_out.is_none());
    }

    #[test]
    fn fold_captures_cancels_and_control_timeline() {
        let events = vec![
            meta(),
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 4,
                width: 1,
                slo_us: None,
                deadline_us: Some(5e5),
            },
            TraceEvent::RequestCancelled { req: 0, t_us: 120.0, phase: "decoding".into() },
            TraceEvent::ConfigReloaded {
                t_us: 200.0,
                admission: "fcfs".into(),
                kv_budget_mb: 32,
                prefill_chunk: 4,
                prefill_tokens: 16,
                slo_ttft_ms: 250.0,
                max_preemptions: 2,
            },
            TraceEvent::DrainStarted { t_us: 300.0 },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests[0].deadline_us, Some(5e5));
        assert!(t.requests[0].failed);
        assert_eq!(t.requests[0].cancel_at_us, Some(120.0));
        assert_eq!(t.controls.len(), 2);
        assert_eq!(t.controls[0].0, 200.0);
        match &t.controls[0].1 {
            ControlMsg::Reload(spec) => {
                assert_eq!(spec.admission, Some(AdmissionKind::Fcfs));
                assert_eq!(spec.kv_budget_mb, Some(32));
                assert_eq!(spec.prefill_tokens, Some(16));
                assert_eq!(spec.max_preemptions, Some(2));
            }
            other => panic!("expected reload, got {other:?}"),
        }
        assert!(matches!(t.controls[1].1, ControlMsg::Drain));
    }

    #[test]
    fn beam_retire_reemission_overwrites_in_place() {
        // Beam winners are streamed at retire with indexes from 0; the
        // fold must not double-count them against interim emissions.
        let events = vec![
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 2,
                width: 2,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 99.0, token: 5, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 99.0, token: 6, index: 1 },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests[0].tokens, vec![5, 6]);
    }

    #[test]
    fn metaless_trace_cannot_replay() {
        let t = fold_trace(&[]);
        assert!(t.serving_config().is_err());
        assert_eq!(t.recorded_shards(), 1);
    }

    #[test]
    fn fold_assigns_shards_from_router_events() {
        // The router emits shard_assigned at routing time, BEFORE the
        // owning engine logs the arrival — the fold must still land it.
        let events = vec![
            TraceEvent::ShardAssigned { req: 0, t_us: 5.0, shard: 2 },
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 10.0,
                prompt: vec![1],
                max_new: 1,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::RequestArrived {
                req: 1,
                t_us: 20.0,
                prompt: vec![2],
                max_new: 1,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
        ];
        let t = fold_trace(&events);
        assert_eq!(t.requests[0].shard, Some(2));
        assert_eq!(t.requests[1].shard, None, "unrouted request keeps no shard");
    }

    #[test]
    fn meta_roundtrips_fleet_fields_into_the_config() {
        let mut t = fold_trace(&[meta()]);
        let Some(TraceEvent::Meta { shards, shard_plan, replicate_hot, .. }) = &mut t.meta else {
            unreachable!()
        };
        *shards = 3;
        *shard_plan = "hash".to_string();
        *replicate_hot = 0.2;
        let cfg = t.serving_config().unwrap();
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.shard_plan, ShardPlan::Hash);
        assert!((cfg.replicate_hot - 0.2).abs() < 1e-12);
        assert_eq!(t.recorded_shards(), 3);
        // Legacy traces record no shard_plan; fold must not choke.
        let Some(TraceEvent::Meta { shard_plan, .. }) = &mut t.meta else { unreachable!() };
        *shard_plan = String::new();
        assert_eq!(t.serving_config().unwrap().shard_plan, ShardPlan::Auto);
    }

    #[test]
    fn meta_roundtrips_adaptive_flag() {
        let mut t = fold_trace(&[meta()]);
        assert!(!t.serving_config().unwrap().adaptive, "legacy traces replay static");
        let Some(TraceEvent::Meta { adaptive, .. }) = &mut t.meta else { unreachable!() };
        *adaptive = true;
        assert!(t.serving_config().unwrap().adaptive);
        let mut cfg = ServingConfig::default();
        apply_config_overrides(&mut cfg, "adaptive=on").unwrap();
        assert!(cfg.adaptive);
        assert!(apply_config_overrides(&mut cfg, "adaptive=2").is_err());
    }

    #[test]
    fn broadcast_controls_fold_back_to_one_per_action() {
        // 2-shard recording, shards logged sequentially: each shard saw
        // the same reload-then-drain sequence at its own clock times.
        let reload = ControlMsg::Reload(ReloadSpec::default());
        let controls = vec![
            (100.0, reload.clone()),
            (300.0, ControlMsg::Drain),
            (120.0, reload.clone()),
            (310.0, ControlMsg::Drain),
        ];
        let d = dedup_broadcast_controls(&controls, 2);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].0, 100.0, "earliest application time wins");
        assert_eq!(d[0].1.op(), "reload");
        assert_eq!(d[1].0, 300.0);
        assert_eq!(d[1].1.op(), "drain");
        // Non-divisible counts are kept verbatim, not guessed at.
        assert_eq!(dedup_broadcast_controls(&controls[..3], 2).len(), 3);
        // Single-engine recordings pass through untouched.
        assert_eq!(dedup_broadcast_controls(&controls, 1).len(), 4);
    }

    #[test]
    fn config_overrides_parse_and_reject_junk() {
        let mut cfg = ServingConfig::default();
        apply_config_overrides(
            &mut cfg,
            "shards=3, shard-plan=layer, replicate_hot=0.25, admission=sjf, kv-budget-mb=64",
        )
        .unwrap();
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.shard_plan, ShardPlan::Layer);
        assert!((cfg.replicate_hot - 0.25).abs() < 1e-12);
        assert_eq!(cfg.admission, AdmissionKind::ShortestFirst);
        assert_eq!(cfg.kv_budget_mb, 64);
        assert!(apply_config_overrides(&mut cfg, "shards").is_err());
        assert!(apply_config_overrides(&mut cfg, "wedge=1").is_err());
        assert!(apply_config_overrides(&mut cfg, "shards=zero").is_err());
        assert!(apply_config_overrides(&mut cfg, "").is_ok(), "empty spec is a no-op");
    }

    #[test]
    fn aggregates_summarize_and_diff() {
        let outcomes = vec![
            ReplayOutcome {
                id: 0,
                tokens: vec![1, 2],
                metrics: Some(GenMetrics {
                    enqueue_us: 0.0,
                    first_token_us: 1_000.0,
                    token_done_us: vec![1_000.0, 3_000.0],
                    ..GenMetrics::default()
                }),
                error: None,
            },
            ReplayOutcome { id: 1, error: Some("cancelled".into()), ..Default::default() },
        ];
        let a = aggregate_outcomes(&outcomes);
        assert_eq!(a.completed, 1);
        assert_eq!(a.failed, 1);
        assert_eq!(a.output_tokens, 2);
        assert!((a.mean_itl_ms - 2.0).abs() < 1e-9);
        assert!((a.last_token_s - 0.003).abs() < 1e-12);
        let d = diff_aggregates(&a, &a);
        assert_eq!(d.len(), 6);
        assert!(d[0].contains("1 -> 1"), "{d:?}");
    }

    #[test]
    fn diff_flags_divergence_and_accepts_identity() {
        let events = vec![
            TraceEvent::RequestArrived {
                req: 0,
                t_us: 0.0,
                prompt: vec![1],
                max_new: 2,
                width: 1,
                slo_us: None,
                deadline_us: None,
            },
            TraceEvent::TokenEmitted { req: 0, t_us: 1.0, token: 7, index: 0 },
            TraceEvent::TokenEmitted { req: 0, t_us: 2.0, token: 8, index: 1 },
            TraceEvent::RequestFinished {
                req: 0,
                t_us: 2.0,
                tokens: 2,
                ttft_us: 1.0,
                queue_delay_us: 0.0,
            },
        ];
        let rec = fold_trace(&events);
        let good = vec![ReplayOutcome { id: 0, tokens: vec![7, 8], ..Default::default() }];
        assert!(diff_replay(&rec, &good).is_empty());
        let bad = vec![ReplayOutcome { id: 0, tokens: vec![7, 9], ..Default::default() }];
        let d = diff_replay(&rec, &bad);
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("index 1"), "{d:?}");
        assert_eq!(diff_replay(&rec, &[]).len(), 1);
    }
}
