//! Synthetic workload generation — the stand-in for ShareGPT / LMSYS-Chat-1M
//! (DESIGN.md §2: the datasets contribute prompt-length distributions and
//! routing statistics, both of which are parameters here).
//!
//! Token content is Zipf-distributed over the vocabulary (natural-language
//! rank-frequency), with a per-dataset seed/skew so the two "datasets" of
//! the paper's Appendix D induce different routing mixes.

use crate::util::rng::{Rng, Zipf};

/// A named synthetic dataset profile.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    /// Zipf exponent over token ranks.
    pub zipf_a: f64,
    /// Permutation seed: which concrete token each rank maps to (this is
    /// what shifts routing between datasets while keeping marginals).
    pub perm_seed: u64,
}

impl Dataset {
    /// ShareGPT-like: the calibration dataset (matches the Python-side
    /// `zipf_tokens(a=1.2)` used to build the popularity profile).
    pub fn sharegpt() -> Dataset {
        Dataset { name: "sharegpt", zipf_a: 1.2, perm_seed: 0 }
    }

    /// LMSYS-Chat-1M-like: same marginal family, different token mapping
    /// and slightly flatter distribution (Appendix D sensitivity).
    pub fn lmsys() -> Dataset {
        Dataset { name: "lmsys", zipf_a: 1.05, perm_seed: 777 }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
        match name {
            "sharegpt" => Ok(Self::sharegpt()),
            "lmsys" => Ok(Self::lmsys()),
            other => anyhow::bail!("unknown dataset {other:?} (have sharegpt, lmsys)"),
        }
    }
}

/// Generates prompts from a dataset profile.
pub struct WorkloadGen {
    dataset: Dataset,
    zipf: Zipf,
    perm: Vec<u32>,
    rng: Rng,
}

impl WorkloadGen {
    pub fn new(dataset: Dataset, vocab: usize, seed: u64) -> WorkloadGen {
        let zipf = Zipf::new(vocab, dataset.zipf_a);
        // Rank -> token permutation; identity for perm_seed 0 (matching the
        // Python calibration sampler exactly).
        let mut perm: Vec<u32> = (0..vocab as u32).collect();
        if dataset.perm_seed != 0 {
            let mut prng = Rng::new(dataset.perm_seed);
            prng.shuffle(&mut perm);
        }
        WorkloadGen { dataset, zipf, perm, rng: Rng::new(seed) }
    }

    pub fn dataset_name(&self) -> &'static str {
        self.dataset.name
    }

    /// Sample a prompt of exactly `len` tokens (the paper evaluates fixed
    /// input lengths: "we randomly select samples ... with N tokens or more
    /// of prompt and use the initial N tokens").
    pub fn prompt(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.perm[self.zipf.sample(&mut self.rng)]).collect()
    }

    /// Sample `n` prompts.
    pub fn prompts(&mut self, n: usize, len: usize) -> Vec<Vec<u32>> {
        (0..n).map(|_| self.prompt(len)).collect()
    }
}

/// Drifting-popularity prompt generator: like [`WorkloadGen`], but the
/// rank->token permutation is re-drawn every `phase_len` prompts, so the
/// induced expert-routing distribution shifts in phases.  This is the
/// non-stationary regime where static popularity placement decays and
/// dynamic cache policies differentiate (HybriMoE / MoE-Lightning — see
/// PAPERS.md); used by the cache ablation and tests.
pub struct DriftingWorkloadGen {
    zipf: Zipf,
    vocab: usize,
    phase_len: usize,
    emitted: usize,
    base_seed: u64,
    perm: Vec<u32>,
    rng: Rng,
}

impl DriftingWorkloadGen {
    pub fn new(vocab: usize, zipf_a: f64, phase_len: usize, seed: u64) -> DriftingWorkloadGen {
        assert!(phase_len > 0, "phase_len must be positive");
        DriftingWorkloadGen {
            zipf: Zipf::new(vocab, zipf_a),
            vocab,
            phase_len,
            emitted: 0,
            base_seed: seed,
            perm: Self::perm_for(vocab, seed, 0),
            rng: Rng::new(seed ^ 0xD81F7),
        }
    }

    fn perm_for(vocab: usize, seed: u64, phase: u64) -> Vec<u32> {
        let mut perm: Vec<u32> = (0..vocab as u32).collect();
        let mut prng = Rng::new(seed ^ phase.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xBEEF);
        prng.shuffle(&mut perm);
        perm
    }

    /// Index of the current preference phase.
    pub fn phase(&self) -> u64 {
        (self.emitted / self.phase_len) as u64
    }

    pub fn prompt(&mut self, len: usize) -> Vec<u32> {
        let phase = self.phase();
        if self.emitted > 0 && self.emitted % self.phase_len == 0 {
            self.perm = Self::perm_for(self.vocab, self.base_seed, phase);
        }
        self.emitted += 1;
        (0..len).map(|_| self.perm[self.zipf.sample(&mut self.rng)]).collect()
    }
}

/// Drifting per-layer expert routing trace for cache-policy ablations
/// (`expertcache::sim`) — routing statistics without a model in the loop.
///
/// Each decode step activates `top_k` distinct experts per layer.  Layer 0
/// draws from a Zipf preference over a per-phase expert permutation; each
/// later layer follows a per-phase deterministic shift of the previous
/// layer's choices — strong cross-layer transition structure, like the
/// diagonal-dominant transition profiles the calibration pass measures.
/// Every `phase_len` steps the permutation and shifts are re-drawn: the
/// popularity AND transition structure drift together.
pub struct DriftingExpertTrace {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    zipf: Zipf,
    phase_len: usize,
    steps: usize,
    base_seed: u64,
    perm: Vec<usize>,
    shifts: Vec<usize>,
    rng: Rng,
}

impl DriftingExpertTrace {
    pub fn new(
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
        phase_len: usize,
        seed: u64,
    ) -> DriftingExpertTrace {
        assert!(n_layers > 0, "need at least one layer");
        assert!(n_experts > 1, "need at least two experts");
        assert!((1..=n_experts).contains(&top_k), "top_k out of range");
        assert!(phase_len > 0, "phase_len must be positive");
        let mut t = DriftingExpertTrace {
            n_layers,
            n_experts,
            top_k,
            zipf: Zipf::new(n_experts, 1.2),
            phase_len,
            steps: 0,
            base_seed: seed,
            perm: Vec::new(),
            shifts: Vec::new(),
            rng: Rng::new(seed ^ 0x7ACE),
        };
        t.roll_phase(0);
        t
    }

    pub fn phase(&self) -> u64 {
        (self.steps / self.phase_len) as u64
    }

    fn roll_phase(&mut self, phase: u64) {
        let mut prng = Rng::new(self.base_seed ^ phase.wrapping_mul(0x9E3779B97F4A7C15));
        let mut perm: Vec<usize> = (0..self.n_experts).collect();
        prng.shuffle(&mut perm);
        self.perm = perm;
        self.shifts = (0..self.n_layers.saturating_sub(1))
            .map(|_| 1 + prng.below((self.n_experts - 1) as u64) as usize)
            .collect();
    }

    /// One decode step: token counts per expert for every layer (`top_k`
    /// experts with one token each, the decode regime).
    pub fn step(&mut self) -> Vec<Vec<usize>> {
        if self.steps > 0 && self.steps % self.phase_len == 0 {
            self.roll_phase(self.phase());
        }
        self.steps += 1;

        // Layer 0: top_k distinct experts by permuted Zipf preference.
        let mut chosen: Vec<usize> = Vec::with_capacity(self.top_k);
        let mut guard = 0;
        while chosen.len() < self.top_k && guard < 64 * self.top_k {
            let e = self.perm[self.zipf.sample(&mut self.rng)];
            if !chosen.contains(&e) {
                chosen.push(e);
            }
            guard += 1;
        }
        for e in 0..self.n_experts {
            if chosen.len() >= self.top_k {
                break;
            }
            if !chosen.contains(&e) {
                chosen.push(e);
            }
        }

        let mut out = vec![vec![0usize; self.n_experts]; self.n_layers];
        for &e in &chosen {
            out[0][e] = 1;
        }
        for l in 1..self.n_layers {
            chosen = chosen.iter().map(|&e| (e + self.shifts[l - 1]) % self.n_experts).collect();
            for &e in &chosen {
                out[l][e] = 1;
            }
        }
        out
    }
}

/// Open-loop Poisson arrival process in virtual time — the stand-in for
/// production request traffic driving the lifecycle scheduler
/// ([`crate::server::lifecycle`]): arrivals are independent of service
/// completions, so queueing delay under load is actually measured instead
/// of being hidden by a closed loop.  Deterministic per seed.
pub struct PoissonArrivals {
    /// Mean arrival rate (requests per virtual second).
    rate_per_s: f64,
    t_us: f64,
    rng: Rng,
}

impl PoissonArrivals {
    pub fn new(rate_per_s: f64, seed: u64) -> PoissonArrivals {
        assert!(rate_per_s > 0.0, "arrival rate must be positive");
        PoissonArrivals { rate_per_s, t_us: 0.0, rng: Rng::new(seed ^ 0xA221) }
    }

    /// Next absolute arrival time (virtual µs); exponential inter-arrival
    /// gaps with mean `1e6 / rate_per_s`.
    pub fn next_arrival_us(&mut self) -> f64 {
        // Inverse-CDF; f64() is in [0, 1), so 1 - u is in (0, 1] and the
        // log never sees 0.
        let u = self.rng.f64();
        self.t_us += -(1.0 - u).ln() / self.rate_per_s * 1e6;
        self.t_us
    }

    /// The first `n` arrival times.
    pub fn times_us(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_arrival_us()).collect()
    }
}

/// The paper's scenario (a) grid: input {32,64,128,256} x output
/// {64,128,256,512}, minus the (256,512) cell = 15 configurations.
pub fn scenario_a_grid() -> Vec<(usize, usize)> {
    let mut grid = Vec::new();
    for &inp in &[32usize, 64, 128, 256] {
        for &out in &[64usize, 128, 256, 512] {
            grid.push((inp, out));
        }
    }
    grid.truncate(15); // the paper reports 15 configurations
    grid
}

/// Scenario (b) prefill lengths.
pub const SCENARIO_B_LENGTHS: &[usize] = &[512, 1024, 2048, 4096];

/// Scenario (c) beam widths (input 32, output 64).
pub const SCENARIO_C_WIDTHS: &[usize] = &[4, 8, 12, 16];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_in_vocab_and_right_length() {
        let mut g = WorkloadGen::new(Dataset::sharegpt(), 512, 1);
        for p in g.prompts(20, 33) {
            assert_eq!(p.len(), 33);
            assert!(p.iter().all(|&t| t < 512));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        let mut b = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        assert_eq!(a.prompt(64), b.prompt(64));
    }

    #[test]
    fn datasets_differ() {
        let mut a = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        let mut b = WorkloadGen::new(Dataset::lmsys(), 512, 9);
        assert_ne!(a.prompt(64), b.prompt(64));
    }

    #[test]
    fn zipf_skew_visible() {
        let mut g = WorkloadGen::new(Dataset::sharegpt(), 512, 3);
        let toks = g.prompt(5000);
        let top_quarter = toks.iter().filter(|&&t| t < 128).count();
        assert!(top_quarter > 3000, "zipf skew missing: {top_quarter}");
    }

    #[test]
    fn grid_is_15() {
        assert_eq!(scenario_a_grid().len(), 15);
    }

    #[test]
    fn poisson_arrivals_monotone_and_mean_matches_rate() {
        let mut p = PoissonArrivals::new(50.0, 7); // 50 req/s => 20 ms mean gap
        let times = p.times_us(4000);
        assert!(times.windows(2).all(|w| w[1] > w[0]), "arrivals must be increasing");
        let mean_gap =
            times.last().unwrap() / times.len() as f64;
        assert!((mean_gap - 20_000.0).abs() < 1_500.0, "mean gap {mean_gap}");
    }

    #[test]
    fn poisson_arrivals_deterministic_per_seed() {
        let mut a = PoissonArrivals::new(10.0, 3);
        let mut b = PoissonArrivals::new(10.0, 3);
        assert_eq!(a.times_us(50), b.times_us(50));
        let mut c = PoissonArrivals::new(10.0, 4);
        assert_ne!(a.times_us(50), c.times_us(50));
    }

    #[test]
    fn drifting_prompts_shift_between_phases() {
        let mut g = DriftingWorkloadGen::new(256, 1.2, 3, 5);
        assert_eq!(g.phase(), 0);
        let early = g.prompt(2000);
        g.prompt(64);
        g.prompt(64); // phase boundary next
        assert_eq!(g.phase(), 1);
        let late = g.prompt(2000);
        assert!(early.iter().all(|&t| t < 256));
        // Distinct permutations => the set of dominant tokens differs.
        let top32 = |p: &[u32]| {
            let mut c = vec![0usize; 256];
            for &t in p {
                c[t as usize] += 1;
            }
            let mut idx: Vec<usize> = (0..256).collect();
            idx.sort_by_key(|&i| std::cmp::Reverse(c[i]));
            let mut s = idx[..32].to_vec();
            s.sort_unstable();
            s
        };
        assert_ne!(top32(&early), top32(&late), "phase shift did not change preference");
    }

    #[test]
    fn drifting_prompts_deterministic_per_seed() {
        let mut a = DriftingWorkloadGen::new(128, 1.2, 4, 9);
        let mut b = DriftingWorkloadGen::new(128, 1.2, 4, 9);
        for _ in 0..10 {
            assert_eq!(a.prompt(32), b.prompt(32));
        }
    }

    #[test]
    fn expert_trace_shape_and_topk() {
        let mut t = DriftingExpertTrace::new(4, 8, 2, 50, 0);
        for _ in 0..120 {
            let routing = t.step();
            assert_eq!(routing.len(), 4);
            for layer in &routing {
                assert_eq!(layer.len(), 8);
                assert_eq!(layer.iter().sum::<usize>(), 2, "top_k experts per layer");
            }
        }
        assert_eq!(t.phase(), 2);
    }

    #[test]
    fn expert_trace_has_transition_structure() {
        // Within a phase, layer l's actives determine layer l+1's by a
        // fixed shift — the structure TransitionAware exploits.
        let mut t = DriftingExpertTrace::new(3, 8, 2, 1000, 7);
        let shifts_of = |cur: &[usize], next: &[usize]| -> Vec<usize> {
            let c: Vec<usize> =
                cur.iter().enumerate().filter(|(_, &s)| s > 0).map(|(e, _)| e).collect();
            (0..8).filter(|&d| c.iter().all(|&e| next[(e + d) % 8] > 0)).collect()
        };
        // One shift must explain every step of the phase (spurious
        // candidates from symmetric active sets die in the intersection).
        let mut common: Vec<usize> = (0..8).collect();
        for _ in 0..10 {
            let r = t.step();
            let valid = shifts_of(&r[0], &r[1]);
            assert!(!valid.is_empty(), "no shift relation between layers");
            common.retain(|d| valid.contains(d));
        }
        assert!(!common.is_empty(), "no stable within-phase shift");
    }
}
