//! Synthetic workload generation — the stand-in for ShareGPT / LMSYS-Chat-1M
//! (DESIGN.md §2: the datasets contribute prompt-length distributions and
//! routing statistics, both of which are parameters here).
//!
//! Token content is Zipf-distributed over the vocabulary (natural-language
//! rank-frequency), with a per-dataset seed/skew so the two "datasets" of
//! the paper's Appendix D induce different routing mixes.

use crate::util::rng::{Rng, Zipf};

/// A named synthetic dataset profile.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    /// Zipf exponent over token ranks.
    pub zipf_a: f64,
    /// Permutation seed: which concrete token each rank maps to (this is
    /// what shifts routing between datasets while keeping marginals).
    pub perm_seed: u64,
}

impl Dataset {
    /// ShareGPT-like: the calibration dataset (matches the Python-side
    /// `zipf_tokens(a=1.2)` used to build the popularity profile).
    pub fn sharegpt() -> Dataset {
        Dataset { name: "sharegpt", zipf_a: 1.2, perm_seed: 0 }
    }

    /// LMSYS-Chat-1M-like: same marginal family, different token mapping
    /// and slightly flatter distribution (Appendix D sensitivity).
    pub fn lmsys() -> Dataset {
        Dataset { name: "lmsys", zipf_a: 1.05, perm_seed: 777 }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
        match name {
            "sharegpt" => Ok(Self::sharegpt()),
            "lmsys" => Ok(Self::lmsys()),
            other => anyhow::bail!("unknown dataset {other:?} (have sharegpt, lmsys)"),
        }
    }
}

/// Generates prompts from a dataset profile.
pub struct WorkloadGen {
    dataset: Dataset,
    zipf: Zipf,
    perm: Vec<u32>,
    rng: Rng,
}

impl WorkloadGen {
    pub fn new(dataset: Dataset, vocab: usize, seed: u64) -> WorkloadGen {
        let zipf = Zipf::new(vocab, dataset.zipf_a);
        // Rank -> token permutation; identity for perm_seed 0 (matching the
        // Python calibration sampler exactly).
        let mut perm: Vec<u32> = (0..vocab as u32).collect();
        if dataset.perm_seed != 0 {
            let mut prng = Rng::new(dataset.perm_seed);
            prng.shuffle(&mut perm);
        }
        WorkloadGen { dataset, zipf, perm, rng: Rng::new(seed) }
    }

    pub fn dataset_name(&self) -> &'static str {
        self.dataset.name
    }

    /// Sample a prompt of exactly `len` tokens (the paper evaluates fixed
    /// input lengths: "we randomly select samples ... with N tokens or more
    /// of prompt and use the initial N tokens").
    pub fn prompt(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.perm[self.zipf.sample(&mut self.rng)]).collect()
    }

    /// Sample `n` prompts.
    pub fn prompts(&mut self, n: usize, len: usize) -> Vec<Vec<u32>> {
        (0..n).map(|_| self.prompt(len)).collect()
    }
}

/// The paper's scenario (a) grid: input {32,64,128,256} x output
/// {64,128,256,512}, minus the (256,512) cell = 15 configurations.
pub fn scenario_a_grid() -> Vec<(usize, usize)> {
    let mut grid = Vec::new();
    for &inp in &[32usize, 64, 128, 256] {
        for &out in &[64usize, 128, 256, 512] {
            grid.push((inp, out));
        }
    }
    grid.truncate(15); // the paper reports 15 configurations
    grid
}

/// Scenario (b) prefill lengths.
pub const SCENARIO_B_LENGTHS: &[usize] = &[512, 1024, 2048, 4096];

/// Scenario (c) beam widths (input 32, output 64).
pub const SCENARIO_C_WIDTHS: &[usize] = &[4, 8, 12, 16];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_in_vocab_and_right_length() {
        let mut g = WorkloadGen::new(Dataset::sharegpt(), 512, 1);
        for p in g.prompts(20, 33) {
            assert_eq!(p.len(), 33);
            assert!(p.iter().all(|&t| t < 512));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        let mut b = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        assert_eq!(a.prompt(64), b.prompt(64));
    }

    #[test]
    fn datasets_differ() {
        let mut a = WorkloadGen::new(Dataset::sharegpt(), 512, 9);
        let mut b = WorkloadGen::new(Dataset::lmsys(), 512, 9);
        assert_ne!(a.prompt(64), b.prompt(64));
    }

    #[test]
    fn zipf_skew_visible() {
        let mut g = WorkloadGen::new(Dataset::sharegpt(), 512, 3);
        let toks = g.prompt(5000);
        let top_quarter = toks.iter().filter(|&&t| t < 128).count();
        assert!(top_quarter > 3000, "zipf skew missing: {top_quarter}");
    }

    #[test]
    fn grid_is_15() {
        assert_eq!(scenario_a_grid().len(), 15);
    }
}
