//! In-house property-based testing harness (`proptest` is unavailable
//! offline).
//!
//! A property is a closure over a [`Gen`] (seeded random source + helpers).
//! [`check`] runs it for `cases` random seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically, and retries
//! the property with "smaller" size hints to produce a reduced example.
//!
//! ```no_run
//! use fiddler::testkit::{check, Gen};
//! check("sort is idempotent", 256, |g: &mut Gen| {
//!     let mut v = g.vec_usize(0..64, 0..100);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Random-input generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size multiplier in (0, 1]; shrink passes re-run with smaller sizes.
    size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), size: 1.0, seed }
    }

    fn scaled(&self, n: usize) -> usize {
        ((n as f64) * self.size).ceil() as usize
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        let span = r.end - r.start;
        let scaled_span = self.scaled(span).max(1).min(span);
        r.start + self.rng.below(scaled_span as u64) as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        self.rng.choice(xs)
    }

    /// Vec of usizes with random length in `len` and values in `val`.
    pub fn vec_usize(&mut self, len: Range<usize>, val: Range<usize>) -> Vec<usize> {
        let n = if len.start == len.end {
            len.start
        } else {
            self.usize_in(len)
        };
        (0..n).map(|_| self.usize_in(val.clone())).collect()
    }

    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = if len.start == len.end {
            len.start
        } else {
            self.usize_in(len)
        };
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// Run a property for `cases` random cases.  Panics (failing the enclosing
/// #[test]) with the seed and a shrunk-size report if any case fails.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: u64, prop: F) {
    // Fixed base seed: runs are reproducible; vary FIDDLER_TEST_SEED to widen.
    let base = std::env::var("FIDDLER_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1DD1E5u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if result.is_err() {
            // Shrink: retry at reduced size multipliers and report the
            // smallest size that still fails.
            let mut smallest_failing = 1.0;
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let fails = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed);
                    g.size = size;
                    prop(&mut g);
                }))
                .is_err();
                if fails {
                    smallest_failing = size;
                }
            }
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}, \
                 reproduces at size multiplier {smallest_failing}); \
                 set FIDDLER_TEST_SEED={seed} to replay as case 0"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 64, |g| {
            let a = g.usize_in(0..1000);
            let b = g.usize_in(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("always false", 8, |g| {
                let _ = g.u64();
                panic!("nope");
            });
        }));
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("always false"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.vec_usize(1..10, 0..100), b.vec_usize(1..10, 0..100));
    }

    #[test]
    fn vec_len_respects_bounds() {
        let mut g = Gen::new(3);
        for _ in 0..200 {
            let v = g.vec_usize(2..5, 0..10);
            assert!((2..5).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 10));
        }
    }
}
