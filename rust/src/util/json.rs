//! Minimal JSON parser/writer (the `serde` facade is unavailable offline).
//!
//! Covers everything the repo needs: manifests, goldens, config files, and
//! result export.  Numbers are stored as `f64` (all our integers fit 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json type error: expected {expected} at {path}")]
    Type { expected: &'static str, path: String },
    #[error("json missing key {0}")]
    Missing(String),
}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing garbage".into()));
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type { expected: "number", path: String::new() }),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type { expected: "bool", path: String::new() }),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type { expected: "string", path: String::new() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Type { expected: "array", path: String::new() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::Type { expected: "object", path: String::new() }),
        }
    }

    /// Object field access; error carries the key name.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Convenience: `get` chained over a dotted path.
    pub fn at(&self, path: &str) -> Result<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Ok(cur)
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
    }

    /// Vector of f32 from a numeric array.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected value"),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| {
                                        JsonError::Parse(self.i, "bad \\u".into())
                                    })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            // BMP only (sufficient for our ASCII manifests).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError::Parse(self.i, "bad utf8".into()))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, format!("bad number {text:?}")))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Load and parse a JSON file.
pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
    Ok(Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"x":[1,2.5,true,null,"s\"t"],"y":{"z":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
