//! Minimal CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults keep call sites terse.
//!
//! Parsing is schema-free and greedy: `--flag` followed by a non-`--` token
//! consumes it as a value, so boolean switches must come last or use
//! `--flag=true`-style. All in-repo call sites follow this convention.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    /// Flags that appeared without a value (`--verbose`).
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — the first element is NOT
    /// skipped; use `from_env` for real argv.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(rest) = item.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.switches.push(rest.to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--widths 4,8,12,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer {p:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_key_value_forms() {
        let a = args("serve pos1 --model mixtral-tiny --env=env1 --verbose");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.get("model"), Some("mixtral-tiny"));
        assert_eq!(a.get("env"), Some("env1"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = args("--n 42 --rate 1.5 --widths 4,8,12");
        assert_eq!(a.usize_or("n", 0), 42);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert!((a.f64_or("rate", 0.0) - 1.5).abs() < 1e-12);
        assert_eq!(a.usize_list_or("widths", &[]), vec![4, 8, 12]);
        assert_eq!(a.usize_list_or("none", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn negative_numbers_not_swallowed_as_flags() {
        let a = args("--offset -3");
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
