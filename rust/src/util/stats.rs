//! Small statistics helpers shared by metrics, latency calibration and the
//! in-house bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares fit y = a + b*x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

/// Summary statistics bundle used by reporters.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }
}
