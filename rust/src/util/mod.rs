//! Shared utility substrates: PRNG, statistics, JSON, CLI parsing.
//!
//! These exist as in-repo modules because the offline crate set ships only
//! the `xla` dependency closure (no serde/clap/rand/criterion/proptest).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

/// Ranking key for descending `total_cmp` sorts over scores/probabilities:
/// NaN ranks strictly LAST.  Raw `total_cmp` would rank a positive NaN
/// above +inf — letting a poisoned logit win a beam slot or a NaN router
/// prob win expert selection; `partial_cmp(..).unwrap()` panicked.  Shared
/// by beam selection (driver + lifecycle scheduler) and router top-k so
/// they can never disagree on NaN handling.
pub fn rank_key(v: f32) -> f32 {
    if v.is_nan() {
        f32::NEG_INFINITY
    } else {
        v
    }
}

/// Round `n` up to the nearest value in `buckets` (ascending).  Returns the
/// largest bucket if `n` exceeds all of them (callers must then split).
pub fn round_up_bucket(n: usize, buckets: &[usize]) -> usize {
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
    for &b in buckets {
        if n <= b {
            return b;
        }
    }
    *buckets.last().expect("empty bucket list")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rounding() {
        let b = [1, 2, 4, 8];
        assert_eq!(round_up_bucket(1, &b), 1);
        assert_eq!(round_up_bucket(3, &b), 4);
        assert_eq!(round_up_bucket(8, &b), 8);
        assert_eq!(round_up_bucket(9, &b), 8); // saturates; caller splits
    }
}
