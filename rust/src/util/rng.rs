//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `SplitMix64` seeds `Xoshiro256**`; helpers cover the distributions the
//! workload generators and property tests need (uniform, normal, Zipf,
//! choice, shuffle).  Everything is reproducible from a single `u64` seed.

/// SplitMix64: used to expand a seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from explicit (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(a) sampler over ranks [0, n): P(r) ∝ 1/(r+1)^a.
///
/// Matches `python/compile/goldens.zipf_tokens` by construction so the
/// calibration traces on both sides induce the same routing statistics.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, a: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(a);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let vals: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(50, 1.2);
        let mut r = Rng::new(13);
        let mut counts = [0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 5 * c[0]);
    }
}
