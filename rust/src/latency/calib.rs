//! Latency-model calibration (paper §3.3 "Initialization": "We also measure
//! the latency to copy weights and execute experts on either the CPU or the
//! GPU with different input sizes to inform the decision at runtime").
//!
//! Two sources of samples:
//!
//! * paper mode — synthesize samples from a [`HardwareConfig`]'s analytic
//!   curves plus measurement noise, then fit (used by the figure drivers:
//!   the fitted model reproduces the paper's environments);
//! * measured mode — time the *actual* PJRT expert executable at each batch
//!   bucket on this host (exercised by tests and `fiddler calibrate`;
//!   demonstrates the machinery end-to-end, though host timings do not
//!   resemble the paper's testbed).

use super::LatencyModel;
use crate::config::HardwareConfig;
use crate::exec::ExecutorPool;
use crate::util::rng::Rng;
use crate::util::stats::linear_fit;

/// One measured (input size, latency µs) sample.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub tokens: usize,
    pub us: f64,
}

/// Fit an affine CPU model and a constant GPU model from samples.
pub fn fit(
    cpu_samples: &[Sample],
    gpu_samples: &[Sample],
    transfer_us: f64,
) -> LatencyModel {
    assert!(cpu_samples.len() >= 2, "need >= 2 CPU samples");
    assert!(!gpu_samples.is_empty(), "need >= 1 GPU sample");
    let xs: Vec<f64> = cpu_samples.iter().map(|s| s.tokens as f64).collect();
    let ys: Vec<f64> = cpu_samples.iter().map(|s| s.us).collect();
    let (c0, c1) = linear_fit(&xs, &ys);

    // GPU: constant = mean of multi-batch samples; single-batch extra from
    // the s == 1 samples if present.
    let multi: Vec<f64> =
        gpu_samples.iter().filter(|s| s.tokens > 1).map(|s| s.us).collect();
    let single: Vec<f64> =
        gpu_samples.iter().filter(|s| s.tokens == 1).map(|s| s.us).collect();
    let g = if multi.is_empty() {
        crate::util::stats::mean(&single)
    } else {
        crate::util::stats::mean(&multi)
    };
    let extra = if single.is_empty() || multi.is_empty() {
        0.0
    } else {
        (crate::util::stats::mean(&single) - g).max(0.0)
    };

    LatencyModel {
        gpu_const_us: g,
        gpu_single_extra_us: extra,
        cpu_base_us: c0.max(0.0),
        cpu_per_token_us: c1.max(0.0),
        transfer_us,
        act_roundtrip_per_token_us: 0.0,
    }
}

/// Synthesize noisy samples from an analytic latency model, as if measured
/// on the paper's testbed (32 repeats per point, like Appendix A).
pub fn synth_samples_from(
    ideal: &LatencyModel,
    sizes: &[usize],
    noise_frac: f64,
    seed: u64,
) -> (Vec<Sample>, Vec<Sample>) {
    let mut rng = Rng::new(seed);
    let mut cpu = Vec::new();
    let mut gpu = Vec::new();
    for &s in sizes {
        for _ in 0..32 {
            let jitter = 1.0 + noise_frac * rng.normal();
            cpu.push(Sample { tokens: s, us: ideal.cpu_lat(s) * jitter.max(0.5) });
            let jitter = 1.0 + noise_frac * rng.normal();
            gpu.push(Sample { tokens: s, us: ideal.gpu_lat(s) * jitter.max(0.5) });
        }
    }
    (cpu, gpu)
}

/// Synthesize noisy samples from a hardware config's analytic curves.
pub fn synth_samples(
    hw: &HardwareConfig,
    sizes: &[usize],
    noise_frac: f64,
    seed: u64,
) -> (Vec<Sample>, Vec<Sample>) {
    synth_samples_from(&LatencyModel::from_hardware(hw), sizes, noise_frac, seed)
}

const CALIB_SIZES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// Calibrate a latency model for `hw` from synthesized noisy measurements —
/// the initialization-phase procedure of §3.3.
pub fn calibrate_paper_env(hw: &HardwareConfig, seed: u64) -> LatencyModel {
    let (cpu, gpu) = synth_samples(hw, &CALIB_SIZES, 0.03, seed);
    fit(&cpu, &gpu, hw.weight_transfer_us())
}

/// Calibrate the multi-core CPU expert curve: §3.3's initialization
/// measurement repeated with the parallel executor running `threads`
/// workers, so the fitted `cpu_lat(s)` — and with it Algorithm 1's
/// CPU/GPU crossover — reflects the faster CPU path.
pub fn calibrate_multicore(hw: &HardwareConfig, threads: usize, seed: u64) -> LatencyModel {
    let ideal = LatencyModel::from_hardware_threaded(hw, threads);
    let (cpu, gpu) = synth_samples_from(&ideal, &CALIB_SIZES, 0.03, seed);
    fit(&cpu, &gpu, hw.weight_transfer_us())
}

/// Time the host expert kernel through a real [`ExecutorPool`] at each
/// input size — the *measured* (not modeled) multicore calibration
/// source.  The timed region is exactly the engine's layer-join
/// discipline: priority dispatch, chunked rows, work-stealing join.
/// Synthetic weights (`hidden x ffn`), so no artifacts are needed.
pub fn measure_pool_expert(
    pool: &ExecutorPool,
    sizes: &[usize],
    repeats: usize,
    hidden: usize,
    ffn: usize,
    seed: u64,
) -> Vec<Sample> {
    use crate::exec::{run_cpu_experts, CpuExpertTask};
    use crate::runtime::Tensor;
    use std::sync::Arc;

    let mut rng = Rng::new(seed);
    let w1 = Arc::new(Tensor::randn(&mut rng, vec![hidden, ffn], 0.2));
    let w3 = Arc::new(Tensor::randn(&mut rng, vec![hidden, ffn], 0.2));
    let w2 = Arc::new(Tensor::randn(&mut rng, vec![ffn, hidden], 0.2));
    let mut out = Vec::new();
    for &s in sizes {
        let tasks = [CpuExpertTask {
            expert: 0,
            x: Tensor::randn(&mut rng, vec![s, hidden], 0.5),
            w1: Arc::clone(&w1),
            w3: Arc::clone(&w3),
            w2: Arc::clone(&w2),
        }];
        let _ = run_cpu_experts(pool, &tasks); // warm thread-local scratch
        for _ in 0..repeats {
            let t0 = std::time::Instant::now();
            let _ = run_cpu_experts(pool, &tasks);
            out.push(Sample { tokens: s, us: t0.elapsed().as_nanos() as f64 / 1e3 });
        }
    }
    out
}

/// Measured multicore speedup of the executor pool on THIS host: wall
/// time of a prefill-sized expert through a 1-thread pool over a
/// `threads`-wide pool.  Can come out below 1 on oversubscribed hosts —
/// [`LatencyModel::from_hardware_threaded_with_speedup`] clamps.
pub fn measure_pool_speedup(threads: usize, seed: u64) -> f64 {
    let threads = threads.max(1);
    if threads == 1 {
        return 1.0;
    }
    const SIZE: usize = 192; // several MIN_CHUNK_ROWS chunks per worker
    const REPEATS: usize = 3;
    let (hidden, ffn) = (128, 256);
    let serial = ExecutorPool::new(1);
    let parallel = ExecutorPool::new(threads);
    let ts = measure_pool_expert(&serial, &[SIZE], REPEATS, hidden, ffn, seed);
    let tp = measure_pool_expert(&parallel, &[SIZE], REPEATS, hidden, ffn, seed);
    let ms = crate::util::stats::mean(&ts.iter().map(|x| x.us).collect::<Vec<_>>());
    let mp = crate::util::stats::mean(&tp.iter().map(|x| x.us).collect::<Vec<_>>());
    if ms > 0.0 && mp > 0.0 {
        ms / mp
    } else {
        1.0
    }
}

/// Measured-mode multicore calibration (`FIDDLER_MEASURED_CALIB=1`, and
/// `fiddler calibrate --measured-pool`): the paper-environment CPU curve
/// scaled by the speedup the executor pool *realized* on this host,
/// replacing [`crate::latency::cpu_parallel_speedup`]'s assumed
/// contention curve.
pub fn calibrate_multicore_measured(
    hw: &HardwareConfig,
    threads: usize,
    seed: u64,
) -> LatencyModel {
    LatencyModel::from_hardware_threaded_with_speedup(
        hw,
        threads,
        measure_pool_speedup(threads, seed),
    )
}

/// Measured mode: time the ACTUAL expert executable on this host at each
/// batch bucket and fit the affine model.  Exercises the full calibration
/// machinery end to end (`fiddler calibrate --measured=1`); the numbers
/// describe this host, not the paper's testbed.
pub fn measure_host_expert(
    rt: &crate::runtime::Runtime,
    ws: &crate::runtime::WeightStore,
    sizes: &[usize],
    repeats: usize,
) -> anyhow::Result<Vec<Sample>> {
    use crate::runtime::Tensor;
    let cfg = &ws.config;
    let (w1, w3, w2) = (
        ws.expert(0, 0, "w1").clone(),
        ws.expert(0, 0, "w3").clone(),
        ws.expert(0, 0, "w2").clone(),
    );
    let mut out = Vec::new();
    for &s in sizes {
        let op = format!("expert_b{s}");
        if !rt.has_op(&op) {
            continue;
        }
        let x = Tensor::zeros(vec![s, cfg.hidden]);
        let args: Vec<crate::runtime::Arg> = vec![
            x.into(),
            w1.clone().into(),
            w3.clone().into(),
            w2.clone().into(),
        ];
        rt.execute(&op, &args)?; // compile + warm
        for _ in 0..repeats {
            let t0 = std::time::Instant::now();
            rt.execute(&op, &args)?;
            out.push(Sample { tokens: s, us: t0.elapsed().as_micros() as f64 });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_analytic_model() {
        let hw = HardwareConfig::env1();
        let ideal = LatencyModel::from_hardware(&hw);
        let fitted = calibrate_paper_env(&hw, 42);
        // Within a few percent despite 3% measurement noise.
        let rel = |a: f64, b: f64| (a - b).abs() / b;
        assert!(rel(fitted.cpu_per_token_us, ideal.cpu_per_token_us) < 0.10);
        assert!(rel(fitted.gpu_const_us, ideal.gpu_const_us) < 0.05);
        assert!(rel(fitted.transfer_us, ideal.transfer_us) < 1e-12);
        // And the decision-relevant quantity — the crossover — agrees.
        let a = fitted.crossover_tokens() as f64;
        let b = ideal.crossover_tokens() as f64;
        assert!((a - b).abs() / b < 0.25, "crossover {a} vs {b}");
    }

    #[test]
    fn multicore_fit_tracks_threaded_curve() {
        let hw = HardwareConfig::env1();
        let threads = 8;
        let ideal = LatencyModel::from_hardware_threaded(&hw, threads);
        let fitted = calibrate_multicore(&hw, threads, 11);
        // The fit folds the activation round-trip into the slope (its own
        // act term is 0), so compare against the ideal's combined slope.
        let want_slope = ideal.cpu_per_token_us + ideal.act_roundtrip_per_token_us;
        let rel = |a: f64, b: f64| (a - b).abs() / b;
        assert!(
            rel(fitted.cpu_per_token_us, want_slope) < 0.10,
            "fitted slope {} vs ideal {}",
            fitted.cpu_per_token_us,
            want_slope
        );
        // The multi-core fit must sit clearly below the single-core fit and
        // push the crossover out.
        let single = calibrate_paper_env(&hw, 11);
        assert!(fitted.cpu_per_token_us < single.cpu_per_token_us);
        assert!(
            fitted.crossover_tokens() > single.crossover_tokens(),
            "multicore crossover {} not beyond single-core {}",
            fitted.crossover_tokens(),
            single.crossover_tokens()
        );
    }

    #[test]
    fn measured_pool_samples_grow_with_input_size() {
        // Wall-clock measurement, so only the coarse shape is asserted:
        // samples exist for every size and a 16x bigger input is not
        // cheaper than a tiny one on the serial pool.
        let pool = ExecutorPool::new(1);
        let samples = measure_pool_expert(&pool, &[4, 64], 3, 64, 128, 7);
        assert_eq!(samples.len(), 6);
        let small: Vec<f64> =
            samples.iter().filter(|s| s.tokens == 4).map(|s| s.us).collect();
        let big: Vec<f64> =
            samples.iter().filter(|s| s.tokens == 64).map(|s| s.us).collect();
        assert!(crate::util::stats::mean(&big) >= crate::util::stats::mean(&small) * 0.5);
        assert!(samples.iter().all(|s| s.us > 0.0));
    }

    #[test]
    fn measured_calibration_yields_a_sane_model() {
        // The measured speedup is whatever this host delivers; the model
        // built from it must stay within the clamp contract: never slower
        // than single-core, never faster than linear in threads.
        let hw = HardwareConfig::env1();
        let threads = 2;
        let sp = measure_pool_speedup(threads, 5);
        assert!(sp.is_finite() && sp > 0.0, "speedup {sp}");
        let m = calibrate_multicore_measured(&hw, threads, 5);
        let serial = LatencyModel::from_hardware(&hw);
        assert!(m.cpu_per_token_us <= serial.cpu_per_token_us + 1e-9);
        assert!(m.cpu_per_token_us >= serial.cpu_per_token_us / threads as f64 - 1e-9);
        // GPU-side and link terms untouched by CPU calibration.
        assert!((m.gpu_const_us - serial.gpu_const_us).abs() < 1e-12);
        assert!((m.transfer_us - serial.transfer_us).abs() < 1e-12);
        // threads == 1 short-circuits to the serial model exactly.
        assert_eq!(measure_pool_speedup(1, 5), 1.0);
    }

    #[test]
    fn fit_detects_single_batch_overhead() {
        let gpu = vec![
            Sample { tokens: 1, us: 110.0 },
            Sample { tokens: 2, us: 100.0 },
            Sample { tokens: 16, us: 100.0 },
        ];
        let cpu = vec![
            Sample { tokens: 1, us: 10.0 },
            Sample { tokens: 2, us: 20.0 },
        ];
        let m = fit(&cpu, &gpu, 500.0);
        assert!((m.gpu_single_extra_us - 10.0).abs() < 1e-9);
        assert!((m.gpu_lat(1) - 110.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn fit_requires_samples() {
        fit(&[], &[Sample { tokens: 1, us: 1.0 }], 1.0);
    }
}
