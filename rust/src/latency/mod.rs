//! Latency model — the quantities consumed by Algorithm 1 (paper §3.3).
//!
//! `gpu_lat(s)` is constant in the input size (GPU expert execution is
//! memory-bound on the weight read), `cpu_lat(s)` is affine in the input
//! size (one DRAM pass over the weights + per-token compute; the paper's
//! pure-linear model is the `c0 = 0` special case), and `transfer_lat()` is
//! the PCIe weight-copy time.  Constants come either from the per-env
//! hardware config (paper-derived, Appendix A) or from [`calib`] fitting
//! measured samples.

pub mod calib;

use crate::config::HardwareConfig;

/// The latency model of one (CPU, GPU, link) triple, in microseconds.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// GPU expert execution with weights resident (constant part).
    pub gpu_const_us: f64,
    /// Extra GPU latency at batch size 1 (Appendix A: PyTorch dispatches a
    /// different single-batch kernel, ~10% slower).
    pub gpu_single_extra_us: f64,
    /// CPU expert execution: `cpu_base_us + cpu_per_token_us * s`.
    pub cpu_base_us: f64,
    pub cpu_per_token_us: f64,
    /// CPU->GPU weight copy for one expert.
    pub transfer_us: f64,
    /// Activation round-trip per token (GPU->CPU and back), charged to the
    /// CPU path; <1% of expert latency by construction (Appendix A).
    pub act_roundtrip_per_token_us: f64,
}

/// Bytes of one paper-scale token's activation vector (hidden 4096, bf16).
const TOKEN_ACT_BYTES: usize = 4096 * 2;

/// Fractional GPU-time overhead of executing a LOW-BIT resident expert:
/// the kernel upcasts int8/int4 tiles to fp on the fly before the GEMM.
/// Calibrated against the host-side dequant sweep (`quant::expert_ffn_host_q8`
/// measures the dequant pass at 10–15% of the blocked GEMM at decode
/// widths; GPU tensor-core upcast paths land in the same band).  Constant
/// in `bits` — the upcast touches every weight once either way.
pub const DEQUANT_OVERHEAD_FRAC: f64 = 0.12;

/// Effective speedup of the CPU expert path with `threads` workers.
///
/// The expert GEMV is DRAM-bandwidth bound, so scaling is sublinear:
/// linear-with-contention, `t / (1 + C*(t-1))`, which gives ~5.1x at 8
/// threads and saturates toward `1/C` = 12.5x as the memory controllers
/// fill up.  `threads = 1` is exactly 1.0 (the single-core model).
pub fn cpu_parallel_speedup(threads: usize) -> f64 {
    const CONTENTION: f64 = 0.08;
    let t = threads.max(1) as f64;
    t / (1.0 + CONTENTION * (t - 1.0))
}

impl LatencyModel {
    pub fn from_hardware(hw: &HardwareConfig) -> LatencyModel {
        LatencyModel {
            gpu_const_us: hw.gpu_expert_compute_us,
            gpu_single_extra_us: hw.gpu_single_batch_extra_us,
            cpu_base_us: hw.cpu_expert_base_us,
            cpu_per_token_us: hw.cpu_expert_per_token_us,
            transfer_us: hw.weight_transfer_us(),
            // Each CPU-planned token ships its activation GPU->CPU and the
            // result back: two copies of one token's activation, in
            // µs/token (Appendix A measures this at <1% of expert latency).
            act_roundtrip_per_token_us: 2.0 * hw.act_copy_us(TOKEN_ACT_BYTES),
        }
    }

    /// Latency model for a `threads`-wide CPU expert executor: the CPU
    /// curve (weight pass + per-token compute) scales by the sublinear
    /// multi-core speedup, capped at the environment's core count; GPU,
    /// transfer, and activation-copy terms are unaffected.  This is what
    /// Algorithm 1 consults when the engine runs the parallel executor —
    /// a faster CPU pushes the crossover out and keeps more experts off
    /// the PCIe link.
    pub fn from_hardware_threaded(hw: &HardwareConfig, threads: usize) -> LatencyModel {
        let t = threads.max(1).min(hw.cpu_cores.max(1));
        Self::from_hardware_threaded_with_speedup(hw, threads, cpu_parallel_speedup(t))
    }

    /// [`LatencyModel::from_hardware_threaded`] with an explicit speedup —
    /// the *measured* calibration path
    /// ([`calib::measure_pool_speedup`] / `FIDDLER_MEASURED_CALIB=1`):
    /// scale the CPU curve by the speedup the executor pool actually
    /// realized on this host instead of the assumed contention curve.
    /// Clamped to `[1, effective threads]` — the pool cannot exceed linear
    /// scaling, and a pool measured slower than serial must not push
    /// Algorithm 1's crossover below the serial model's (the engine would
    /// be planning against a slowdown the layer join never charges).
    pub fn from_hardware_threaded_with_speedup(
        hw: &HardwareConfig,
        threads: usize,
        speedup: f64,
    ) -> LatencyModel {
        let mut m = Self::from_hardware(hw);
        let t = threads.max(1).min(hw.cpu_cores.max(1));
        let s = if speedup.is_finite() { speedup.clamp(1.0, t as f64) } else { 1.0 };
        m.cpu_base_us /= s;
        m.cpu_per_token_us /= s;
        m
    }

    /// Expected GPU latency for an expert with `s` input tokens, weights
    /// already resident (paper's `gpu_lat(s)` — constant).
    pub fn gpu_lat(&self, s: usize) -> f64 {
        debug_assert!(s > 0);
        if s == 1 {
            self.gpu_const_us + self.gpu_single_extra_us
        } else {
            self.gpu_const_us
        }
    }

    /// Expected CPU latency for an expert with `s` input tokens, including
    /// the (negligible) activation round-trip (paper's `cpu_lat(s)`).
    pub fn cpu_lat(&self, s: usize) -> f64 {
        debug_assert!(s > 0);
        self.cpu_base_us
            + self.cpu_per_token_us * s as f64
            + self.act_roundtrip_per_token_us * s as f64
    }

    /// Expected CPU->GPU weight transfer latency (paper's `transfer_lat()`).
    pub fn transfer_lat(&self) -> f64 {
        self.transfer_us
    }

    /// Expected GPU latency for an expert executed FROM ITS LOW-BIT
    /// RESIDENT COPY: the fp compute plus the on-the-fly dequant overhead
    /// ([`DEQUANT_OVERHEAD_FRAC`]).  The third priced option of the
    /// tiered Algorithm 1 ([`crate::scheduler::decide_expert_tiered`]).
    pub fn quant_gpu_lat(&self, s: usize) -> f64 {
        self.gpu_lat(s) * (1.0 + DEQUANT_OVERHEAD_FRAC)
    }

    /// PCIe latency to land a `bits`-wide copy of one expert on the GPU —
    /// the cheap quantized admit.  The fp baseline is 16-bit, so the
    /// volume (and the serialized-lane occupancy) scales by `bits / 16`.
    pub fn quant_transfer_lat(&self, bits: u32) -> f64 {
        self.transfer_us * bits.max(1) as f64 / 16.0
    }

    /// Input size at which copying weights to the GPU becomes cheaper than
    /// computing on the CPU: the crossover in Figure 1 / §3.2.
    pub fn crossover_tokens(&self) -> usize {
        let mut s = 1;
        while s < 1 << 20 {
            if self.cpu_lat(s) > self.gpu_lat(s) + self.transfer_lat() {
                return s;
            }
            s += 1;
        }
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    #[test]
    fn gpu_latency_constant_in_batch() {
        let m = m();
        assert_eq!(m.gpu_lat(2), m.gpu_lat(1000));
        // batch-1 overhead ~10% (Appendix A)
        let extra = m.gpu_lat(1) / m.gpu_lat(2);
        assert!(extra > 1.0 && extra < 1.25, "extra={extra}");
    }

    #[test]
    fn cpu_latency_increases_linearly() {
        let m = m();
        let d1 = m.cpu_lat(11) - m.cpu_lat(10);
        let d2 = m.cpu_lat(101) - m.cpu_lat(100);
        assert!((d1 - d2).abs() < 1e-9, "not affine");
        assert!(m.cpu_lat(100) > m.cpu_lat(1));
    }

    #[test]
    fn crossover_in_decode_beam_range() {
        // The regime the paper describes: single-token decode should prefer
        // the CPU; long prefill (>= hundreds of tokens per expert) the GPU.
        for hw in [HardwareConfig::env1(), HardwareConfig::env2()] {
            let m = LatencyModel::from_hardware(&hw);
            let x = m.crossover_tokens();
            assert!(x > 2, "{}: crossover {x} too small — decode would use GPU", hw.name);
            assert!(x < 256, "{}: crossover {x} too large — prefill would use CPU", hw.name);
        }
    }

    #[test]
    fn quant_costs_sit_between_resident_and_demand_paths() {
        for hw in [HardwareConfig::env1(), HardwareConfig::env2()] {
            let m = LatencyModel::from_hardware(&hw);
            for s in [1usize, 4, 32] {
                // Dequant overhead is real but small: a quantized hit
                // always undercuts the synchronous fp transfer, and beats
                // the CPU once the affine per-token term kicks in.
                assert!(m.quant_gpu_lat(s) > m.gpu_lat(s));
                assert!(m.quant_gpu_lat(s) < m.transfer_lat() + m.gpu_lat(s));
                if s >= 4 {
                    assert!(m.quant_gpu_lat(s) < m.cpu_lat(s),
                        "{}: quant hit not profitable at s={s}", hw.name);
                }
            }
            // The three-way argmin is NOT degenerate: env2's beefy CPU
            // wins single-token decode even against a resident low-bit
            // copy (dequant overhead tips it), while env1's does not.
            if hw.name == "env2" {
                assert!(m.cpu_lat(1) < m.quant_gpu_lat(1));
            } else {
                assert!(m.quant_gpu_lat(1) < m.cpu_lat(1));
            }
            // Low-bit admits ride the same lane at proportional volume.
            assert!((m.quant_transfer_lat(8) - m.transfer_us / 2.0).abs() < 1e-9);
            assert!((m.quant_transfer_lat(4) - m.transfer_us / 4.0).abs() < 1e-9);
            assert!(m.quant_transfer_lat(16) <= m.transfer_us + 1e-9);
        }
    }

    #[test]
    fn activation_roundtrip_under_one_percent() {
        let m = m();
        assert!(m.act_roundtrip_per_token_us < 0.01 * m.cpu_lat(1));
    }

    #[test]
    fn threaded_model_single_thread_is_identity() {
        let hw = HardwareConfig::env1();
        let m1 = LatencyModel::from_hardware_threaded(&hw, 1);
        let m0 = LatencyModel::from_hardware(&hw);
        assert!((m1.cpu_base_us - m0.cpu_base_us).abs() < 1e-12);
        assert!((m1.cpu_per_token_us - m0.cpu_per_token_us).abs() < 1e-12);
        assert!((m1.cpu_lat(17) - m0.cpu_lat(17)).abs() < 1e-9);
    }

    #[test]
    fn threaded_model_scales_sublinearly_and_moves_crossover_out() {
        let hw = HardwareConfig::env1();
        let m1 = LatencyModel::from_hardware_threaded(&hw, 1);
        let m8 = LatencyModel::from_hardware_threaded(&hw, 8);
        // Faster, but less than 8x (bandwidth contention).
        assert!(m8.cpu_per_token_us < m1.cpu_per_token_us);
        assert!(m8.cpu_per_token_us > m1.cpu_per_token_us / 8.0);
        // GPU-side and link terms untouched.
        assert!((m8.gpu_const_us - m1.gpu_const_us).abs() < 1e-12);
        assert!((m8.transfer_us - m1.transfer_us).abs() < 1e-12);
        assert!(
            (m8.act_roundtrip_per_token_us - m1.act_roundtrip_per_token_us).abs() < 1e-12
        );
        // The decision-relevant consequence: the CPU stays the right
        // choice for larger inputs (Algorithm 1 crossover moves out).
        assert!(m8.crossover_tokens() > m1.crossover_tokens());
    }

    #[test]
    fn explicit_speedup_is_clamped_and_applied() {
        let hw = HardwareConfig::env1();
        let base = LatencyModel::from_hardware(&hw);
        // A measured 3x at 4 threads scales the CPU curve by exactly 3.
        let m = LatencyModel::from_hardware_threaded_with_speedup(&hw, 4, 3.0);
        assert!((m.cpu_per_token_us - base.cpu_per_token_us / 3.0).abs() < 1e-9);
        assert!((m.cpu_base_us - base.cpu_base_us / 3.0).abs() < 1e-9);
        // Sub-serial and non-finite measurements clamp to the serial model.
        for bad in [0.3, f64::NAN, f64::INFINITY] {
            let m = LatencyModel::from_hardware_threaded_with_speedup(&hw, 4, bad);
            let capped = bad.is_finite() && bad > 4.0;
            if capped {
                assert!((m.cpu_per_token_us - base.cpu_per_token_us / 4.0).abs() < 1e-9);
            } else {
                assert!((m.cpu_per_token_us - base.cpu_per_token_us).abs() < 1e-9);
            }
        }
        // Superlinear claims cap at the thread count.
        let m = LatencyModel::from_hardware_threaded_with_speedup(&hw, 4, 40.0);
        assert!((m.cpu_per_token_us - base.cpu_per_token_us / 4.0).abs() < 1e-9);
    }

    #[test]
    fn parallel_speedup_monotone_and_capped_by_cores() {
        let mut prev = 0.0;
        for t in 1..64 {
            let s = cpu_parallel_speedup(t);
            assert!(s > prev, "speedup not monotone at {t}");
            assert!(s <= t as f64 + 1e-12, "superlinear speedup at {t}");
            prev = s;
        }
        // Requesting more threads than the env has cores changes nothing.
        let hw = HardwareConfig::env1();
        let at_cores = LatencyModel::from_hardware_threaded(&hw, hw.cpu_cores);
        let beyond = LatencyModel::from_hardware_threaded(&hw, hw.cpu_cores * 4);
        assert!((at_cores.cpu_per_token_us - beyond.cpu_per_token_us).abs() < 1e-12);
    }
}
