//! Serving metrics: TTFT, ITL, tokens/s — all in *virtual* time (µs), as
//! reported by the simulated substrate (DESIGN.md §2).

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Timing record of one generation (all timestamps virtual µs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GenMetrics {
    pub enqueue_us: f64,
    /// Time the scheduler admitted the request (prefill start).  Equal to
    /// `enqueue_us` for direct engine-level generation; under the serving
    /// scheduler the difference is the queue delay.
    pub admitted_us: f64,
    /// Time the first output token is ready (end of prefill + first decode).
    pub first_token_us: f64,
    /// Completion time of each generated token.
    pub token_done_us: Vec<f64>,
    pub prompt_tokens: usize,
    /// Expert-cache counters attributed to this generation.  Engine-level
    /// generation stamps the engine's cumulative snapshot; the serving
    /// scheduler stamps the *delta* between admission and completion
    /// ([`crate::expertcache::CacheStats::delta_since`]) — i.e. all cache
    /// activity during this request's window, which excludes history from
    /// before admission but still includes concurrently-batched requests
    /// (the cache is shared, so overlapping windows overlap-count).
    pub cache: Option<crate::expertcache::CacheStats>,
    /// Expert-execution counters (resident / transferred / CPU /
    /// prefetch-overlapped) attributed to this generation, with the same
    /// windowing semantics as `cache`
    /// ([`crate::moe::ExpertEvents::delta_since`]).
    pub experts: Option<crate::moe::ExpertEvents>,
    /// Terminal reason label when the request did not finish normally
    /// (`"deadline"`, `"cancelled"`, `"queue_full"`, ... — see
    /// [`crate::server::FailReason`]); `None` for completed requests.
    pub fail_reason: Option<String>,
    /// How many times the serving scheduler preempted and requeued this
    /// request (KV dropped and recomputed on readmission); 0 outside the
    /// preemption path.
    pub preemptions: usize,
}

impl GenMetrics {
    /// Time To First Token (paper scenario b metric).
    pub fn ttft_us(&self) -> f64 {
        self.first_token_us - self.enqueue_us
    }

    /// Time spent queued before the scheduler admitted the request
    /// (0 for engine-level generation, which never queues).
    pub fn queue_delay_us(&self) -> f64 {
        (self.admitted_us - self.enqueue_us).max(0.0)
    }

    /// Inter-token latencies (paper Fig. 12).
    pub fn itl_us(&self) -> Vec<f64> {
        self.token_done_us.windows(2).map(|w| w[1] - w[0]).collect()
    }

    pub fn mean_itl_us(&self) -> f64 {
        let itl = self.itl_us();
        crate::util::stats::mean(&itl)
    }

    /// End-to-end tokens/second (paper scenarios a, c: generated tokens
    /// divided by end-to-end latency including prefill).
    pub fn tokens_per_s(&self) -> f64 {
        let n = self.token_done_us.len();
        if n == 0 {
            return 0.0;
        }
        let total_s = (self.token_done_us[n - 1] - self.enqueue_us) / 1e6;
        n as f64 / total_s
    }

    pub fn end_to_end_us(&self) -> f64 {
        self.token_done_us.last().copied().unwrap_or(self.first_token_us)
            - self.enqueue_us
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("prompt_tokens", Json::from(self.prompt_tokens));
        o.set("output_tokens", Json::from(self.token_done_us.len()));
        o.set("ttft_us", Json::Num(self.ttft_us()));
        o.set("queue_delay_us", Json::Num(self.queue_delay_us()));
        o.set("mean_itl_us", Json::Num(self.mean_itl_us()));
        o.set("tokens_per_s", Json::Num(self.tokens_per_s()));
        if let Some(c) = &self.cache {
            o.set("cache", c.to_json());
        }
        if let Some(e) = &self.experts {
            o.set("experts", e.to_json());
        }
        if let Some(r) = &self.fail_reason {
            o.set("fail_reason", Json::Str(r.clone()));
        }
        if self.preemptions > 0 {
            o.set("preemptions", Json::from(self.preemptions));
        }
        o
    }
}

/// Aggregation over many generations (one figure cell).
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    pub tps: Vec<f64>,
    pub ttft_us: Vec<f64>,
    pub itl_us: Vec<f64>,
    pub queue_delay_us: Vec<f64>,
}

impl Aggregate {
    pub fn push(&mut self, m: &GenMetrics) {
        self.tps.push(m.tokens_per_s());
        self.ttft_us.push(m.ttft_us());
        self.itl_us.extend(m.itl_us());
        self.queue_delay_us.push(m.queue_delay_us());
    }

    pub fn tps_summary(&self) -> Summary {
        Summary::of(&self.tps)
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttft_us)
    }

    pub fn itl_summary(&self) -> Summary {
        Summary::of(&self.itl_us)
    }

    pub fn queue_delay_summary(&self) -> Summary {
        Summary::of(&self.queue_delay_us)
    }
}

/// Simple fixed-width table printer for the figure drivers.
pub struct TableReporter {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableReporter {
    pub fn new(headers: &[&str]) -> TableReporter {
        TableReporter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> GenMetrics {
        GenMetrics {
            enqueue_us: 100.0,
            admitted_us: 250.0,
            first_token_us: 600.0,
            token_done_us: vec![600.0, 1100.0, 1600.0, 2100.0],
            prompt_tokens: 8,
            ..Default::default()
        }
    }

    #[test]
    fn ttft_and_itl() {
        let m = m();
        assert_eq!(m.ttft_us(), 500.0);
        assert_eq!(m.itl_us(), vec![500.0, 500.0, 500.0]);
        assert_eq!(m.mean_itl_us(), 500.0);
        assert_eq!(m.queue_delay_us(), 150.0);
        // Engine-level metrics never set admitted_us: delay clamps to 0.
        let direct = GenMetrics { enqueue_us: 100.0, ..Default::default() };
        assert_eq!(direct.queue_delay_us(), 0.0);
    }

    #[test]
    fn tokens_per_s_end_to_end() {
        let m = m();
        // 4 tokens over 2000 µs = 2000 tok/s
        assert!((m.tokens_per_s() - 2000.0).abs() < 1e-9);
        assert_eq!(m.end_to_end_us(), 2000.0);
    }

    #[test]
    fn aggregate_summaries() {
        let mut a = Aggregate::default();
        a.push(&m());
        a.push(&m());
        assert_eq!(a.tps.len(), 2);
        assert_eq!(a.itl_us.len(), 6);
        assert!((a.ttft_summary().mean - 500.0).abs() < 1e-9);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = TableReporter::new(&["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn empty_generation_is_safe() {
        let m = GenMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert!(m.itl_us().is_empty());
    }

    #[test]
    fn cache_stats_surface_in_json() {
        let mut m = m();
        assert!(m.to_json().get("cache").is_err(), "no cache stats => no key");
        let c = crate::expertcache::CacheStats { hits: 3, misses: 1, ..Default::default() };
        m.cache = Some(c);
        let j = m.to_json();
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_usize().unwrap(), 3);
        assert!((cache.get("hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn terminal_reason_surfaces_in_json() {
        let mut m = m();
        assert!(m.to_json().get("fail_reason").is_err(), "completed => no key");
        assert!(m.to_json().get("preemptions").is_err(), "no preemptions => no key");
        m.fail_reason = Some("deadline".into());
        m.preemptions = 2;
        let j = m.to_json();
        assert_eq!(j.get("fail_reason").unwrap().as_str().unwrap(), "deadline");
        assert_eq!(j.get("preemptions").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn expert_events_surface_in_json() {
        let mut m = m();
        assert!(m.to_json().get("experts").is_err(), "no counters => no key");
        m.experts = Some(crate::moe::ExpertEvents {
            resident: 6,
            transferred: 1,
            cpu: 1,
            quant: 0,
            prefetch_overlapped: 2,
        });
        let j = m.to_json();
        let e = j.get("experts").unwrap();
        assert_eq!(e.get("prefetch_overlapped").unwrap().as_usize().unwrap(), 2);
        assert!((e.get("hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
    }
}
