//! Thread-based serving front end with continuous batching.
//!
//! A single worker thread owns the backend (the PJRT client is not shared
//! across threads); clients submit [`Request`]s through a channel and
//! receive streamed tokens on a per-request channel.  Scheduling lives in
//! [`lifecycle`]: iteration-level (Orca-style) continuous batching with a
//! `Queued → Prefilling → Decoding → Finished/Failed` state machine per
//! request, chunked prefill, pluggable admission policies, a KV-memory
//! budget arbitrating against expert residency, and beam groups decoding
//! inside the shared batch.

pub mod lifecycle;
pub mod net;
pub mod sim;

pub use lifecycle::{serve_lifecycle, ServeBackend};

use crate::coordinator::Engine;
use crate::metrics::GenMetrics;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A generation request.
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Beam width: 1 = ordinary sampled generation; >1 = beam search
    /// through the same serve loop (paper scenario c).  Beam requests
    /// stream the winning beam's tokens when the group finishes.
    pub width: usize,
    /// Relative TTFT service-level objective (virtual µs from enqueue);
    /// `None` uses the server's `--slo-ttft-ms` default.  Orders admission
    /// in `--admission slo` mode.
    pub slo_us: Option<f64>,
    /// Open-loop drivers: absolute virtual arrival time.  The scheduler
    /// holds the request until the virtual clock reaches it (and fast-
    /// forwards idle time to it), so Poisson traces replay exactly.
    pub arrive_at_us: Option<f64>,
    /// Streamed output: one event per token, then `Done`.
    pub stream: Sender<Event>,
    /// Shutdown sentinel: in-flight sequences drain, queued-but-never-
    /// admitted requests get a terminal [`Event::Error`], then the loop
    /// exits.  Needed because auxiliary front ends (TCP accept loop) hold
    /// Sender clones, so channel disconnection alone cannot signal
    /// shutdown.
    pub shutdown: bool,
}

impl Request {
    pub fn new(prompt: Vec<u32>, max_new: usize, stream: Sender<Event>) -> Request {
        Request {
            prompt,
            max_new,
            width: 1,
            slo_us: None,
            arrive_at_us: None,
            stream,
            shutdown: false,
        }
    }

    /// A beam-search request (`width` beams, winning beam streamed at the
    /// end).
    pub fn beam(
        prompt: Vec<u32>,
        max_new: usize,
        width: usize,
        stream: Sender<Event>,
    ) -> Request {
        Request { width, ..Request::new(prompt, max_new, stream) }
    }

    /// The shutdown sentinel.
    pub fn shutdown_sentinel() -> Request {
        let (tx, _rx) = channel();
        Request { shutdown: true, ..Request::new(Vec::new(), 0, tx) }
    }
}

#[derive(Clone, Debug)]
pub enum Event {
    Token(u32),
    Done(GenMetrics),
    Error(String),
}

/// Run the serving loop until `requests` disconnects and all work drains.
/// Thin wrapper over the request-lifecycle scheduler
/// ([`lifecycle::serve_lifecycle`]) specialized to the real [`Engine`].
pub fn serve_loop(engine: &mut Engine, requests: Receiver<Request>) -> Result<()> {
    lifecycle::serve_lifecycle(engine, requests)
}

/// Handle to a background server thread.
pub struct ServerHandle {
    pub requests: Sender<Request>,
    worker: JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Spawn the worker thread; the backend is constructed *inside* it by
    /// `make` (the PJRT client is thread-affine — `!Send` — so it must be
    /// born on the thread that uses it).  Works for any [`ServeBackend`]:
    /// the real [`Engine`] or the artifact-free [`sim::SimBackend`].
    pub fn spawn<B, F>(make: F) -> ServerHandle
    where
        B: ServeBackend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel();
        let worker = std::thread::spawn(move || {
            let mut backend = make()?;
            lifecycle::serve_lifecycle(&mut backend, rx)
        });
        ServerHandle { requests: tx, worker }
    }

    /// Convenience: submit a prompt and return its stream receiver.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests
            .send(Request::new(prompt, max_new, tx))
            .expect("server thread gone");
        rx
    }

    /// Submit a beam-search request (`width` beams); the winning beam's
    /// tokens stream when the group finishes.
    pub fn submit_beam(&self, prompt: Vec<u32>, max_new: usize, width: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests
            .send(Request::beam(prompt, max_new, width, tx))
            .expect("server thread gone");
        rx
    }

    /// Signal shutdown (drains in-flight work, fails queued-but-never-
    /// admitted requests with a terminal event) and join the worker.
    pub fn shutdown(self) -> Result<()> {
        let _ = self.requests.send(Request::shutdown_sentinel());
        drop(self.requests);
        self.worker.join().expect("server thread panicked")
    }
}

/// Collect a full generation from a stream (blocking helper for clients).
pub fn collect(rx: &Receiver<Event>) -> Result<(Vec<u32>, GenMetrics)> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv()? {
            Event::Token(t) => tokens.push(t),
            Event::Done(m) => return Ok((tokens, m)),
            Event::Error(e) => anyhow::bail!("server error: {e}"),
        }
    }
}
