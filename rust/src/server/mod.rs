//! Thread-based serving front end with continuous batching.
//!
//! A single worker thread owns the engine (the PJRT client is not shared
//! across threads); clients submit [`Request`]s through a channel and
//! receive streamed tokens on a per-request channel.  Scheduling is FCFS
//! admission into a decode pool of at most `max_batch` sequences; each
//! iteration admits (prefills) one queued request, then advances every
//! active sequence by one token — the standard continuous-batching loop
//! (Orca-style iteration-level scheduling).

pub mod net;

use crate::coordinator::Engine;
use crate::kvcache::SequenceCache;
use crate::metrics::GenMetrics;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A generation request.
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Streamed output: one event per token, then `Done`.
    pub stream: Sender<Event>,
    /// Shutdown sentinel: the serve loop drains in-flight work and exits.
    /// Needed because auxiliary front ends (TCP accept loop) hold Sender
    /// clones, so channel disconnection alone cannot signal shutdown.
    pub shutdown: bool,
}

impl Request {
    pub fn new(prompt: Vec<u32>, max_new: usize, stream: Sender<Event>) -> Request {
        Request { prompt, max_new, stream, shutdown: false }
    }
}

#[derive(Clone, Debug)]
pub enum Event {
    Token(u32),
    Done(GenMetrics),
    Error(String),
}

struct Active {
    cache: SequenceCache,
    last: u32,
    produced: usize,
    max_new: usize,
    stream: Sender<Event>,
    metrics: GenMetrics,
}

/// Run the serving loop until `requests` disconnects and all work drains.
pub fn serve_loop(engine: &mut Engine, requests: Receiver<Request>) -> Result<()> {
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut shutting_down = false;
    let max_batch = engine.serving.max_batch.min(16);

    loop {
        // Drain newly arrived requests (non-blocking).
        loop {
            match requests.try_recv() {
                Ok(r) if r.shutdown => shutting_down = true,
                Ok(r) => queue.push_back(r),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }
        if shutting_down && queue.is_empty() && active.is_empty() {
            return Ok(());
        }

        // Admission: prefill one queued request per iteration if a slot
        // is free (prefill is long; interleaving one at a time keeps ITL
        // of running sequences bounded).
        if active.len() < max_batch {
            if let Some(req) = queue.pop_front() {
                let mut metrics = GenMetrics {
                    enqueue_us: engine.cx.clock.now_us(),
                    prompt_tokens: req.prompt.len(),
                    ..Default::default()
                };
                let mut cache = SequenceCache::new(engine.model());
                match engine
                    .runner
                    .prefill(&req.prompt, &mut cache, &mut engine.cx)
                    .and_then(|h| engine.runner.lm_head(&h, &mut engine.cx))
                {
                    Ok(logits) => {
                        let tok = engine.sample(logits.row(0));
                        metrics.first_token_us = engine.cx.clock.now_us();
                        metrics.token_done_us.push(metrics.first_token_us);
                        let _ = req.stream.send(Event::Token(tok));
                        active.push(Active {
                            cache,
                            last: tok,
                            produced: 1,
                            max_new: req.max_new,
                            stream: req.stream,
                            metrics,
                        });
                    }
                    Err(e) => {
                        let _ = req.stream.send(Event::Error(e.to_string()));
                    }
                }
            }
        }

        if active.is_empty() {
            if queue.is_empty() {
                if shutting_down {
                    return Ok(());
                }
                // Idle: block for the next request or shutdown.
                match requests.recv() {
                    Ok(r) if r.shutdown => return Ok(()),
                    Ok(r) => queue.push_back(r),
                    Err(_) => return Ok(()),
                }
            }
            continue;
        }

        // One decode step for every active sequence.
        let last: Vec<u32> = active.iter().map(|a| a.last).collect();
        let mut caches: Vec<&mut SequenceCache> =
            active.iter_mut().map(|a| &mut a.cache).collect();
        let next = engine.decode_batch_step(&last, &mut caches)?;
        let now = engine.cx.clock.now_us();
        for (a, tok) in active.iter_mut().zip(next) {
            a.last = tok;
            a.produced += 1;
            a.metrics.token_done_us.push(now);
            let _ = a.stream.send(Event::Token(tok));
        }
        // Retire finished sequences, stamping the engine's cache counters
        // into their final metrics (shared cache: cumulative snapshot).
        let cache_stats = engine.cx.memory.stats().clone();
        active.retain_mut(|a| {
            if a.produced >= a.max_new {
                a.metrics.cache = Some(cache_stats.clone());
                let _ = a.stream.send(Event::Done(a.metrics.clone()));
                false
            } else {
                true
            }
        });
    }
}

/// Handle to a background server thread.
pub struct ServerHandle {
    pub requests: Sender<Request>,
    worker: JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Spawn the worker thread; the engine is constructed *inside* it by
    /// `make` (the PJRT client is thread-affine — `!Send` — so it must be
    /// born on the thread that uses it).
    pub fn spawn<F>(make: F) -> ServerHandle
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel();
        let worker = std::thread::spawn(move || {
            let mut engine = make()?;
            serve_loop(&mut engine, rx)
        });
        ServerHandle { requests: tx, worker }
    }

    /// Convenience: submit a prompt and return its stream receiver.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests
            .send(Request::new(prompt, max_new, tx))
            .expect("server thread gone");
        rx
    }

    /// Signal shutdown (drains in-flight work) and join the worker.
    pub fn shutdown(self) -> Result<()> {
        let (tx, _rx) = channel();
        let _ = self.requests.send(Request {
            prompt: Vec::new(),
            max_new: 0,
            stream: tx,
            shutdown: true,
        });
        drop(self.requests);
        self.worker.join().expect("server thread panicked")
    }
}

/// Collect a full generation from a stream (blocking helper for clients).
pub fn collect(rx: &Receiver<Event>) -> Result<(Vec<u32>, GenMetrics)> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv()? {
            Event::Token(t) => tokens.push(t),
            Event::Done(m) => return Ok((tokens, m)),
            Event::Error(e) => anyhow::bail!("server error: {e}"),
        }
    }
}
