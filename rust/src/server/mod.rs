//! Thread-based serving front end with continuous batching.
//!
//! A single worker thread owns the backend (the PJRT client is not shared
//! across threads); clients submit [`Request`]s through a channel and
//! receive streamed tokens on a per-request channel.  Scheduling lives in
//! [`lifecycle`]: iteration-level (Orca-style) continuous batching with a
//! `Queued → Prefilling → Decoding → Finished/Failed` state machine per
//! request, chunked prefill, pluggable admission policies, a KV-memory
//! budget arbitrating against expert residency, and beam groups decoding
//! inside the shared batch.
//!
//! The engine-agnostic scheduler pieces live in [`core`]; [`fleet`] runs
//! N scheduler instances behind an expert-demand router (`--shards N`).

pub mod core;
pub mod fleet;
pub mod lifecycle;
pub mod net;
pub mod sim;

pub use lifecycle::{serve_lifecycle, ServeBackend};

use crate::config::serving::AdmissionKind;
use crate::coordinator::Engine;
use crate::metrics::GenMetrics;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Hard cap on `prompt + max_new` tokens of one request: the largest
/// sequence the TCP front end accepts and the sizing unit of the
/// startup KV-budget feasibility warning (one max-length width-1 request
/// at [`crate::config::hardware::PAPER_KV_BYTES_PER_TOKEN`]).
pub const MAX_REQUEST_TOKENS: usize = 4096;

/// Why a request terminated without finishing.  Carried on
/// [`Event::Failed`], stamped into [`GenMetrics::fail_reason`], and
/// surfaced as the `reason` field of the TCP `error` line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// Ingest validation failed (empty prompt, bad width, ...).
    BadRequest,
    /// Admission queue at capacity.
    QueueFull,
    /// Worst-case KV footprint exceeds the entire `--kv-budget-mb` pool.
    KvInfeasible,
    /// Per-request deadline lapsed (checked at chunk boundaries).
    Deadline,
    /// Client sent `Cancel{id}` (or the connection demanded it).
    Cancelled,
    /// Server shut down / drained before or during service.
    Shutdown,
    /// Backend step error (real engine failure or injected fault).
    Backend,
    /// TCP front end: connection idle past `--conn-timeout-ms`.
    Timeout,
}

impl FailReason {
    pub fn label(&self) -> &'static str {
        match self {
            FailReason::BadRequest => "bad_request",
            FailReason::QueueFull => "queue_full",
            FailReason::KvInfeasible => "kv_infeasible",
            FailReason::Deadline => "deadline",
            FailReason::Cancelled => "cancelled",
            FailReason::Shutdown => "shutdown",
            FailReason::Backend => "backend",
            FailReason::Timeout => "timeout",
        }
    }
}

/// Fields of a hot config reload; `None` keeps the current value.
/// Applied between serve-loop iterations, so in-flight requests are
/// never dropped by a reload.
#[derive(Clone, Debug, Default)]
pub struct ReloadSpec {
    pub admission: Option<AdmissionKind>,
    pub kv_budget_mb: Option<usize>,
    pub prefill_chunk: Option<usize>,
    pub prefill_tokens: Option<usize>,
    pub slo_ttft_ms: Option<f64>,
    pub max_preemptions: Option<usize>,
}

/// Control-plane message riding the same request channel as generation
/// traffic (ordering with respect to arrivals is therefore well defined,
/// which is what makes recorded control actions replayable).
#[derive(Clone, Debug)]
pub enum ControlMsg {
    /// Cancel a request by serve-loop id (the id streamed back in
    /// [`Event::Queued`]); releases its KV reservation, beam slots, and
    /// any borrowed expert-cache capacity whether queued, prefilling, or
    /// decoding.  Unknown/finished ids ack without effect.
    Cancel { req: u64 },
    /// Swap admission policy / budgets between iterations.
    Reload(ReloadSpec),
    /// Graceful drain: stop admission, fail queued requests, finish
    /// in-flight sequences, then exit the serve loop cleanly.
    Drain,
}

impl ControlMsg {
    /// Label echoed in the [`Event::ControlAck`] and the TCP `ok` line.
    pub fn op(&self) -> &'static str {
        match self {
            ControlMsg::Cancel { .. } => "cancel",
            ControlMsg::Reload(_) => "reload",
            ControlMsg::Drain => "drain",
        }
    }
}

/// A generation request.
pub struct Request {
    /// Pre-assigned serve-loop id.  `None` (the default) lets the
    /// scheduler number the request in its own ingest order; the fleet
    /// router sets it so ids reflect GLOBAL ingest order regardless of
    /// which shard serves the request (trace `req` fields stay unique
    /// across the fleet).
    pub id: Option<u64>,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Beam width: 1 = ordinary sampled generation; >1 = beam search
    /// through the same serve loop (paper scenario c).  Beam requests
    /// stream the winning beam's tokens when the group finishes.
    pub width: usize,
    /// Relative TTFT service-level objective (virtual µs from enqueue);
    /// `None` uses the server's `--slo-ttft-ms` default.  Orders admission
    /// in `--admission slo` mode.  Ordering only — see `deadline_us` for
    /// the enforced variant.
    pub slo_us: Option<f64>,
    /// Enforced end-to-end deadline (virtual µs from enqueue): the
    /// scheduler fails the request with [`FailReason::Deadline`] at the
    /// first chunk boundary past it.  `None` (default) = never enforced.
    pub deadline_us: Option<f64>,
    /// Open-loop drivers: absolute virtual arrival time.  The scheduler
    /// holds the request until the virtual clock reaches it (and fast-
    /// forwards idle time to it), so Poisson traces replay exactly.
    pub arrive_at_us: Option<f64>,
    /// Streamed output: `Queued{id}` at ingest, one event per token, then
    /// `Done` (or a terminal `Failed`).
    pub stream: Sender<Event>,
    /// Shutdown sentinel: in-flight sequences drain, queued-but-never-
    /// admitted requests get a terminal [`Event::Failed`], then the loop
    /// exits.  Needed because auxiliary front ends (TCP accept loop) hold
    /// Sender clones, so channel disconnection alone cannot signal
    /// shutdown.
    pub shutdown: bool,
    /// Control-plane message: when `Some`, every other request field is
    /// ignored and the scheduler applies the control at its next
    /// iteration boundary (honoring `arrive_at_us` if set), answering
    /// with [`Event::ControlAck`] on `stream`.
    pub control: Option<ControlMsg>,
}

impl Request {
    pub fn new(prompt: Vec<u32>, max_new: usize, stream: Sender<Event>) -> Request {
        Request {
            id: None,
            prompt,
            max_new,
            width: 1,
            slo_us: None,
            deadline_us: None,
            arrive_at_us: None,
            stream,
            shutdown: false,
            control: None,
        }
    }

    /// A beam-search request (`width` beams, winning beam streamed at the
    /// end).
    pub fn beam(
        prompt: Vec<u32>,
        max_new: usize,
        width: usize,
        stream: Sender<Event>,
    ) -> Request {
        Request { width, ..Request::new(prompt, max_new, stream) }
    }

    /// A control-plane message (cancel / reload / drain).
    pub fn control(msg: ControlMsg, stream: Sender<Event>) -> Request {
        Request { control: Some(msg), ..Request::new(Vec::new(), 0, stream) }
    }

    /// The shutdown sentinel.
    pub fn shutdown_sentinel() -> Request {
        let (tx, _rx) = channel();
        Request { shutdown: true, ..Request::new(Vec::new(), 0, tx) }
    }
}

#[derive(Clone, Debug)]
pub enum Event {
    /// Ingest ack: the serve-loop id under which this request is tracked —
    /// the handle a client needs to `Cancel` it later.
    Queued(u64),
    Token(u32),
    Done(GenMetrics),
    /// Terminal failure with a typed reason; `metrics` carries whatever
    /// timing the request accrued before failing (with
    /// [`GenMetrics::fail_reason`] stamped).
    Failed { reason: FailReason, message: String, metrics: GenMetrics },
    /// Terminal ack of a control-plane message.
    ControlAck { op: &'static str },
}

impl Event {
    /// Back-compat constructor for terminal errors without a typed
    /// reason (ingest validation paths).
    pub fn error(reason: FailReason, message: impl Into<String>) -> Event {
        let message = message.into();
        let metrics = GenMetrics {
            fail_reason: Some(reason.label().to_string()),
            ..Default::default()
        };
        Event::Failed { reason, message, metrics }
    }
}

/// Run the serving loop until `requests` disconnects and all work drains.
/// Thin wrapper over the request-lifecycle scheduler
/// ([`lifecycle::serve_lifecycle`]) specialized to the real [`Engine`].
pub fn serve_loop(engine: &mut Engine, requests: Receiver<Request>) -> Result<()> {
    lifecycle::serve_lifecycle(engine, requests)
}

/// Handle to a background server thread.
pub struct ServerHandle {
    pub requests: Sender<Request>,
    worker: JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Spawn the worker thread; the backend is constructed *inside* it by
    /// `make` (the PJRT client is thread-affine — `!Send` — so it must be
    /// born on the thread that uses it).  Works for any [`ServeBackend`]:
    /// the real [`Engine`] or the artifact-free [`sim::SimBackend`].
    pub fn spawn<B, F>(make: F) -> ServerHandle
    where
        B: ServeBackend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel();
        let worker = std::thread::spawn(move || {
            let mut backend = make()?;
            lifecycle::serve_lifecycle(&mut backend, rx)
        });
        ServerHandle { requests: tx, worker }
    }

    /// Convenience: submit a prompt and return its stream receiver.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests
            .send(Request::new(prompt, max_new, tx))
            .expect("server thread gone");
        rx
    }

    /// Submit a beam-search request (`width` beams); the winning beam's
    /// tokens stream when the group finishes.
    pub fn submit_beam(&self, prompt: Vec<u32>, max_new: usize, width: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests
            .send(Request::beam(prompt, max_new, width, tx))
            .expect("server thread gone");
        rx
    }

    /// Send a control-plane message (cancel / reload / drain); the
    /// returned receiver yields the terminal [`Event::ControlAck`].
    pub fn control(&self, msg: ControlMsg) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests.send(Request::control(msg, tx)).expect("server thread gone");
        rx
    }

    /// Signal shutdown (drains in-flight work, fails queued-but-never-
    /// admitted requests with a terminal event) and join the worker.
    pub fn shutdown(self) -> Result<()> {
        let _ = self.requests.send(Request::shutdown_sentinel());
        drop(self.requests);
        self.worker.join().expect("server thread panicked")
    }
}

/// Terminal outcome of one request stream: either completed tokens +
/// metrics, or a typed failure (whose partial metrics are still kept for
/// per-reason accounting).
#[derive(Clone, Debug)]
pub struct Outcome {
    pub tokens: Vec<u32>,
    pub metrics: GenMetrics,
    pub failure: Option<(FailReason, String)>,
}

impl Outcome {
    pub fn completed(&self) -> bool {
        self.failure.is_none()
    }
}

/// Drain a stream to its terminal event, keeping the typed failure
/// instead of erasing it into an `anyhow` error.  Returns `Err` only if
/// the sender vanished without a terminal event.
pub fn collect_outcome(rx: &Receiver<Event>) -> Result<Outcome> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv()? {
            Event::Queued(_) | Event::ControlAck { .. } => {}
            Event::Token(t) => tokens.push(t),
            Event::Done(m) => {
                return Ok(Outcome { tokens, metrics: m, failure: None })
            }
            Event::Failed { reason, message, metrics } => {
                return Ok(Outcome { tokens, metrics, failure: Some((reason, message)) })
            }
        }
    }
}

/// Collect a full generation from a stream (blocking helper for clients).
pub fn collect(rx: &Receiver<Event>) -> Result<(Vec<u32>, GenMetrics)> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv()? {
            Event::Queued(_) | Event::ControlAck { .. } => {}
            Event::Token(t) => tokens.push(t),
            Event::Done(m) => return Ok((tokens, m)),
            Event::Failed { message, .. } => anyhow::bail!("server error: {message}"),
        }
    }
}
