//! TCP front end: newline-delimited JSON over a socket.
//!
//! Protocol (one JSON object per line):
//!   client -> {"prompt": [1, 2, 3], "max_new": 16}
//!             optional: "width": W   (beam search; winning beam streams
//!                                     when the group finishes)
//!                       "slo_ms": D  (TTFT deadline for --admission slo)
//!   server -> {"token": 42}            (streamed, one per generated token)
//!   server -> {"done": true, "ttft_us": ..., "queue_delay_us": ...,
//!              "mean_itl_us": ..., "tokens_per_s": ...,
//!              "prompt_tokens": ..., "output_tokens": ...,
//!              "cache": {...}, "experts": {...}}   (optional counters)
//!   server -> {"error": "..."}         (on bad requests)
//!
//! Wire encoding is the shared [`crate::events::wire_event_json`] encoder
//! — the same `GenMetrics::to_json` shape the trace tooling parses.
//!
//! The listener thread accepts connections and forwards requests into the
//! engine worker's queue (`serve_loop`); one relay thread per connection
//! streams events back.  `fiddler serve --listen 127.0.0.1:PORT` wires it.

use super::{Event, Request};
use crate::util::json::Json;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};

/// Parse one request line into (prompt, max_new, width, slo_us).
fn parse_request(line: &str) -> Result<(Vec<u32>, usize, usize, Option<f64>)> {
    let v = Json::parse(line)?;
    let prompt = v
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_usize()? as u32))
        .collect::<Result<Vec<u32>>>()?;
    let max_new = v.get("max_new")?.as_usize()?;
    anyhow::ensure!(max_new > 0 && max_new <= 4096, "max_new out of range");
    let width = match v.get("width") {
        Ok(w) => w.as_usize()?,
        Err(_) => 1,
    };
    anyhow::ensure!(width >= 1 && width <= 16, "width out of range");
    let slo_us = match v.get("slo_ms") {
        Ok(d) => {
            let ms = d.as_f64()?;
            anyhow::ensure!(ms > 0.0, "slo_ms must be positive");
            Some(ms * 1e3)
        }
        Err(_) => None,
    };
    Ok((prompt, max_new, width, slo_us))
}

fn event_line(ev: &Event) -> String {
    format!("{}\n", crate::events::wire_event_json(ev))
}

fn handle_conn(stream: TcpStream, requests: Sender<Request>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let (prompt, max_new, width, slo_us) = match parse_request(&line) {
            Ok(r) => r,
            Err(e) => {
                let _ = writer.write_all(
                    event_line(&Event::Error(format!("bad request: {e}"))).as_bytes(),
                );
                continue;
            }
        };
        let (tx, rx) = channel();
        let req = Request { width, slo_us, ..Request::new(prompt, max_new, tx) };
        if requests.send(req).is_err() {
            let _ = writer
                .write_all(event_line(&Event::Error("server shutting down".into())).as_bytes());
            break;
        }
        // Relay the stream back; one request at a time per connection.
        let mut ok = true;
        for ev in rx.iter() {
            let done = matches!(ev, Event::Done(_) | Event::Error(_));
            if writer.write_all(event_line(&ev).as_bytes()).is_err() {
                ok = false;
                break;
            }
            if done {
                let _ = writer.flush();
                break;
            }
        }
        if !ok {
            break;
        }
    }
    log::debug!("connection {peer} closed");
}

/// Accept-loop: forwards socket requests into the engine queue.  Returns
/// when the listener errors or `requests`' receiver hangs up (detected on
/// the next accepted connection).
pub fn serve_tcp(listener: TcpListener, requests: Sender<Request>) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        stream.set_nodelay(true).ok();
        let tx = requests.clone();
        std::thread::spawn(move || handle_conn(stream, tx));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::serving::Policy;
    use crate::config::HardwareConfig;
    use crate::figures;
    use crate::server::ServerHandle;

    #[test]
    fn parse_request_validates() {
        let (p, n, w, slo) = parse_request(r#"{"prompt": [1, 2], "max_new": 4}"#).unwrap();
        assert_eq!((p, n, w, slo), (vec![1, 2], 4, 1, None));
        let (_, _, w, slo) =
            parse_request(r#"{"prompt": [1], "max_new": 4, "width": 8, "slo_ms": 250}"#)
                .unwrap();
        assert_eq!(w, 8);
        assert_eq!(slo, Some(250_000.0));
        assert!(parse_request(r#"{"prompt": "x", "max_new": 4}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 0}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 4, "width": 0}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 4, "width": 99}"#).is_err());
        assert!(parse_request("garbage").is_err());
    }

    #[test]
    fn event_lines_are_json() {
        let l = event_line(&Event::Token(7));
        assert_eq!(Json::parse(l.trim()).unwrap().get("token").unwrap().as_usize().unwrap(), 7);
        let stats =
            crate::expertcache::CacheStats { hits: 2, ..Default::default() };
        let m = crate::metrics::GenMetrics {
            enqueue_us: 0.0,
            first_token_us: 10.0,
            token_done_us: vec![10.0, 20.0],
            prompt_tokens: 1,
            cache: Some(stats),
            ..Default::default()
        };
        let l = event_line(&Event::Done(m.clone()));
        let v = Json::parse(l.trim()).unwrap();
        assert!(v.get("done").unwrap().as_bool().unwrap());
        assert_eq!(v.get("cache").unwrap().get("hits").unwrap().as_usize().unwrap(), 2);
        // The wire line IS the shared encoder's output — no hand-rolled
        // drift between the TCP front end and the trace tooling.
        assert_eq!(
            l,
            format!("{}\n", crate::events::wire_event_json(&Event::Done(m)))
        );
        assert!(v.get("mean_itl_us").is_ok());
        assert!(v.get("output_tokens").is_ok());
    }

    #[test]
    fn tcp_round_trip_serves_tokens() {
        let hw = HardwareConfig::env1();
        let handle = ServerHandle::spawn(move || {
            figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let req_tx = handle.requests.clone();
        std::thread::spawn(move || serve_tcp(listener, req_tx));

        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"{\"prompt\": [1, 2, 3, 4], \"max_new\": 3}\n").unwrap();
        let mut tokens = Vec::new();
        let mut done = false;
        for line in BufReader::new(sock.try_clone().unwrap()).lines() {
            let v = Json::parse(&line.unwrap()).unwrap();
            if let Ok(t) = v.get("token") {
                tokens.push(t.as_usize().unwrap());
            } else if v.get("done").is_ok() {
                assert!(v.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
                done = true;
                break;
            }
        }
        assert!(done);
        assert_eq!(tokens.len(), 3);
        drop(sock);
        handle.shutdown().unwrap();
    }

    #[test]
    fn tcp_bad_request_gets_error_line() {
        let hw = HardwareConfig::env1();
        let handle = ServerHandle::spawn(move || {
            figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let req_tx = handle.requests.clone();
        std::thread::spawn(move || serve_tcp(listener, req_tx));

        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"not json\n").unwrap();
        let mut line = String::new();
        BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(Json::parse(line.trim()).unwrap().get("error").is_ok());
        drop(sock);
        handle.shutdown().unwrap();
    }
}
