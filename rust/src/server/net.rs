//! TCP front end: newline-delimited JSON over a socket.
//!
//! Protocol (one JSON object per line):
//!   client -> {"prompt": [1, 2, 3], "max_new": 16}
//!             optional: "width": W       (beam search; winning beam
//!                                         streams when the group finishes)
//!                       "slo_ms": D      (TTFT deadline for --admission slo;
//!                                         ordering only)
//!                       "deadline_ms": D (ENFORCED end-to-end deadline:
//!                                         past it the request fails with
//!                                         reason "deadline")
//!   client -> {"cancel": ID}             (ID from the "queued" ack line)
//!   client -> {"reload": {"admission": "slo", "kv_budget_mb": 512,
//!              "prefill_chunk": 32, "prefill_tokens": 128,
//!              "slo_ttft_ms": 250, "max_preemptions": 2}}   (all optional)
//!   client -> {"drain": true}            (graceful drain, then exit)
//!   server -> {"queued": ID}             (ingest ack: the cancel handle)
//!   server -> {"token": 42}              (streamed, one per token)
//!   server -> {"done": true, "ttft_us": ..., "queue_delay_us": ...,
//!              "mean_itl_us": ..., "tokens_per_s": ...,
//!              "prompt_tokens": ..., "output_tokens": ...,
//!              "cache": {...}, "experts": {...}}   (optional counters)
//!   server -> {"error": "...", "reason": "bad_request" | "deadline" |
//!              "cancelled" | "timeout" | ...}      (typed terminal)
//!   server -> {"ok": "cancel" | "reload" | "drain"}  (control ack)
//!
//! Wire encoding is the shared [`crate::events::wire_event_json`] encoder
//! — the same `GenMetrics::to_json` shape the trace tooling parses.
//!
//! Robustness: request lines are capped at [`MAX_LINE_BYTES`] (an
//! oversized line gets a typed error and the connection closes — the
//! parser never buffers unbounded garbage), and `--conn-timeout-ms N`
//! arms a per-connection read timeout (an idle connection gets a typed
//! "timeout" error line, then closes).
//!
//! The listener thread accepts connections and forwards requests into the
//! engine worker's queue (`serve_loop`); one relay thread per connection
//! streams events back.  `fiddler serve --listen 127.0.0.1:PORT` wires it.
//!
//! Fleet front: the same [`serve_tcp`] plugs into an expert-sharded
//! fleet unchanged — `fiddler serve --shards N --listen ...` hands it
//! [`super::fleet::FleetHandle::requests`] instead of a single engine's
//! queue.  The router assigns ids in global ingest order and owns
//! cancel/reload/drain fan-out, so the wire protocol (including cancel
//! ids from the "queued" ack) is identical in both modes.

use super::{ControlMsg, Event, FailReason, ReloadSpec, Request, MAX_REQUEST_TOKENS};
use crate::config::serving::AdmissionKind;
use crate::util::json::Json;
use anyhow::Result;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};

/// Hard cap on one request line: a client that streams an endless line
/// gets a typed error instead of an unbounded buffer.
pub const MAX_LINE_BYTES: u64 = 1 << 20;

/// One parsed client line: a generation request or a control message.
#[derive(Debug)]
enum Parsed {
    Gen {
        prompt: Vec<u32>,
        max_new: usize,
        width: usize,
        slo_us: Option<f64>,
        deadline_us: Option<f64>,
    },
    Control(ControlMsg),
}

/// Parse one request line (generation or control).
fn parse_request(line: &str) -> Result<Parsed> {
    let v = Json::parse(line)?;
    if let Ok(id) = v.get("cancel") {
        return Ok(Parsed::Control(ControlMsg::Cancel { req: id.as_usize()? as u64 }));
    }
    if let Ok(d) = v.get("drain") {
        anyhow::ensure!(d.as_bool()?, "drain must be true");
        return Ok(Parsed::Control(ControlMsg::Drain));
    }
    if let Ok(spec) = v.get("reload") {
        let mut r = ReloadSpec::default();
        if let Ok(a) = spec.get("admission") {
            r.admission = Some(AdmissionKind::by_name(a.as_str()?)?);
        }
        if let Ok(x) = spec.get("kv_budget_mb") {
            r.kv_budget_mb = Some(x.as_usize()?);
        }
        if let Ok(x) = spec.get("prefill_chunk") {
            r.prefill_chunk = Some(x.as_usize()?);
        }
        if let Ok(x) = spec.get("prefill_tokens") {
            r.prefill_tokens = Some(x.as_usize()?);
        }
        if let Ok(x) = spec.get("slo_ttft_ms") {
            let ms = x.as_f64()?;
            anyhow::ensure!(ms > 0.0, "slo_ttft_ms must be positive");
            r.slo_ttft_ms = Some(ms);
        }
        if let Ok(x) = spec.get("max_preemptions") {
            r.max_preemptions = Some(x.as_usize()?);
        }
        return Ok(Parsed::Control(ControlMsg::Reload(r)));
    }
    let prompt = v
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_usize()? as u32))
        .collect::<Result<Vec<u32>>>()?;
    let max_new = v.get("max_new")?.as_usize()?;
    anyhow::ensure!(max_new > 0 && max_new <= MAX_REQUEST_TOKENS, "max_new out of range");
    anyhow::ensure!(
        prompt.len() + max_new <= MAX_REQUEST_TOKENS,
        "prompt + max_new exceeds {MAX_REQUEST_TOKENS} tokens"
    );
    let width = match v.get("width") {
        Ok(w) => w.as_usize()?,
        Err(_) => 1,
    };
    anyhow::ensure!(width >= 1 && width <= 16, "width out of range");
    let ms_field = |key: &str| -> Result<Option<f64>> {
        match v.get(key) {
            Ok(d) => {
                let ms = d.as_f64()?;
                anyhow::ensure!(ms > 0.0, "{key} must be positive");
                Ok(Some(ms * 1e3))
            }
            Err(_) => Ok(None),
        }
    };
    let slo_us = ms_field("slo_ms")?;
    let deadline_us = ms_field("deadline_ms")?;
    Ok(Parsed::Gen { prompt, max_new, width, slo_us, deadline_us })
}

fn event_line(ev: &Event) -> String {
    format!("{}\n", crate::events::wire_event_json(ev))
}

/// Read one `\n`-terminated line, enforcing [`MAX_LINE_BYTES`].
/// `Ok(None)` = clean EOF; `Err(Oversized)` = cap blown (connection must
/// close — the rest of the line is unread garbage); `Err(Io)` = socket
/// error or read timeout.
enum LineErr {
    Oversized,
    Io(std::io::Error),
}

fn read_capped_line<R: BufRead>(reader: &mut R) -> std::result::Result<Option<String>, LineErr> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(MAX_LINE_BYTES)
        .read_until(b'\n', &mut buf)
        .map_err(LineErr::Io)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') && n as u64 == MAX_LINE_BYTES {
        return Err(LineErr::Oversized);
    }
    Ok(Some(String::from_utf8_lossy(&buf).trim().to_string()))
}

fn handle_conn(stream: TcpStream, requests: Sender<Request>, conn_timeout_ms: u64) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    if conn_timeout_ms > 0 {
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(conn_timeout_ms)))
            .ok();
    }
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    loop {
        let line = match read_capped_line(&mut reader) {
            Ok(Some(l)) if !l.is_empty() => l,
            Ok(Some(_)) => continue,
            Ok(None) => break,
            Err(LineErr::Oversized) => {
                let _ = writer.write_all(
                    event_line(&Event::error(
                        FailReason::BadRequest,
                        format!("bad request: line exceeds {MAX_LINE_BYTES} bytes"),
                    ))
                    .as_bytes(),
                );
                break;
            }
            Err(LineErr::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                let _ = writer.write_all(
                    event_line(&Event::error(
                        FailReason::Timeout,
                        format!("connection idle past --conn-timeout-ms {conn_timeout_ms}"),
                    ))
                    .as_bytes(),
                );
                break;
            }
            Err(LineErr::Io(_)) => break,
        };
        let parsed = match parse_request(&line) {
            Ok(p) => p,
            Err(e) => {
                let _ = writer.write_all(
                    event_line(&Event::error(FailReason::BadRequest, format!("bad request: {e}")))
                        .as_bytes(),
                );
                continue;
            }
        };
        let (tx, rx) = channel();
        let req = match parsed {
            Parsed::Gen { prompt, max_new, width, slo_us, deadline_us } => Request {
                width,
                slo_us,
                deadline_us,
                ..Request::new(prompt, max_new, tx)
            },
            Parsed::Control(msg) => Request::control(msg, tx),
        };
        if requests.send(req).is_err() {
            let _ = writer.write_all(
                event_line(&Event::error(FailReason::Shutdown, "server shutting down"))
                    .as_bytes(),
            );
            break;
        }
        // Relay the stream back; one request at a time per connection.
        let mut ok = true;
        for ev in rx.iter() {
            let done =
                matches!(ev, Event::Done(_) | Event::Failed { .. } | Event::ControlAck { .. });
            if writer.write_all(event_line(&ev).as_bytes()).is_err() {
                ok = false;
                break;
            }
            if done {
                let _ = writer.flush();
                break;
            }
        }
        if !ok {
            break;
        }
    }
    log::debug!("connection {peer} closed");
}

/// Accept-loop: forwards socket requests into the engine queue.  Returns
/// when the listener errors or `requests`' receiver hangs up (detected on
/// the next accepted connection).  `conn_timeout_ms` > 0 arms a
/// per-connection read timeout.
pub fn serve_tcp(
    listener: TcpListener,
    requests: Sender<Request>,
    conn_timeout_ms: u64,
) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        stream.set_nodelay(true).ok();
        let tx = requests.clone();
        std::thread::spawn(move || handle_conn(stream, tx, conn_timeout_ms));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::serving::Policy;
    use crate::config::HardwareConfig;
    use crate::figures;
    use crate::server::ServerHandle;

    #[test]
    fn parse_request_validates() {
        let Parsed::Gen { prompt, max_new, width, slo_us, deadline_us } =
            parse_request(r#"{"prompt": [1, 2], "max_new": 4}"#).unwrap()
        else {
            panic!("expected gen request")
        };
        assert_eq!(
            (prompt, max_new, width, slo_us, deadline_us),
            (vec![1, 2], 4, 1, None, None)
        );
        let Parsed::Gen { width, slo_us, deadline_us, .. } = parse_request(
            r#"{"prompt": [1], "max_new": 4, "width": 8, "slo_ms": 250, "deadline_ms": 800}"#,
        )
        .unwrap() else {
            panic!("expected gen request")
        };
        assert_eq!(width, 8);
        assert_eq!(slo_us, Some(250_000.0));
        assert_eq!(deadline_us, Some(800_000.0));
        assert!(parse_request(r#"{"prompt": "x", "max_new": 4}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 0}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 4, "width": 0}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 4, "width": 99}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new": 4, "deadline_ms": -5}"#).is_err());
        assert!(parse_request("garbage").is_err());
    }

    #[test]
    fn parse_request_controls() {
        let Parsed::Control(ControlMsg::Cancel { req }) =
            parse_request(r#"{"cancel": 7}"#).unwrap()
        else {
            panic!("expected cancel")
        };
        assert_eq!(req, 7);
        assert!(matches!(
            parse_request(r#"{"drain": true}"#).unwrap(),
            Parsed::Control(ControlMsg::Drain)
        ));
        assert!(parse_request(r#"{"drain": false}"#).is_err());
        let Parsed::Control(ControlMsg::Reload(spec)) = parse_request(
            r#"{"reload": {"admission": "slo", "kv_budget_mb": 512, "max_preemptions": 2}}"#,
        )
        .unwrap() else {
            panic!("expected reload")
        };
        assert_eq!(spec.admission, Some(AdmissionKind::Deadline));
        assert_eq!(spec.kv_budget_mb, Some(512));
        assert_eq!(spec.max_preemptions, Some(2));
        assert_eq!(spec.prefill_chunk, None);
        assert!(parse_request(r#"{"reload": {"admission": "wedge"}}"#).is_err());
    }

    #[test]
    fn capped_line_reader_enforces_cap() {
        let mut small = std::io::Cursor::new(b"hello\nworld\n".to_vec());
        assert_eq!(read_capped_line(&mut small).ok().flatten().unwrap(), "hello");
        assert_eq!(read_capped_line(&mut small).ok().flatten().unwrap(), "world");
        assert!(read_capped_line(&mut small).ok().flatten().is_none(), "EOF");
        let mut huge = std::io::Cursor::new(vec![b'x'; MAX_LINE_BYTES as usize + 10]);
        assert!(matches!(read_capped_line(&mut huge), Err(LineErr::Oversized)));
    }

    #[test]
    fn event_lines_are_json() {
        let l = event_line(&Event::Token(7));
        assert_eq!(Json::parse(l.trim()).unwrap().get("token").unwrap().as_usize().unwrap(), 7);
        let stats =
            crate::expertcache::CacheStats { hits: 2, ..Default::default() };
        let m = crate::metrics::GenMetrics {
            enqueue_us: 0.0,
            first_token_us: 10.0,
            token_done_us: vec![10.0, 20.0],
            prompt_tokens: 1,
            cache: Some(stats),
            ..Default::default()
        };
        let l = event_line(&Event::Done(m.clone()));
        let v = Json::parse(l.trim()).unwrap();
        assert!(v.get("done").unwrap().as_bool().unwrap());
        assert_eq!(v.get("cache").unwrap().get("hits").unwrap().as_usize().unwrap(), 2);
        // The wire line IS the shared encoder's output — no hand-rolled
        // drift between the TCP front end and the trace tooling.
        assert_eq!(
            l,
            format!("{}\n", crate::events::wire_event_json(&Event::Done(m)))
        );
        assert!(v.get("mean_itl_us").is_ok());
        assert!(v.get("output_tokens").is_ok());
    }

    #[test]
    fn tcp_round_trip_serves_tokens() {
        let hw = HardwareConfig::env1();
        let handle = ServerHandle::spawn(move || {
            figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let req_tx = handle.requests.clone();
        std::thread::spawn(move || serve_tcp(listener, req_tx, 0));

        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"{\"prompt\": [1, 2, 3, 4], \"max_new\": 3}\n").unwrap();
        let mut tokens = Vec::new();
        let mut queued = false;
        let mut done = false;
        for line in BufReader::new(sock.try_clone().unwrap()).lines() {
            let v = Json::parse(&line.unwrap()).unwrap();
            if v.get("queued").is_ok() {
                queued = true;
            } else if let Ok(t) = v.get("token") {
                tokens.push(t.as_usize().unwrap());
            } else if v.get("done").is_ok() {
                assert!(v.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
                done = true;
                break;
            }
        }
        assert!(queued, "ingest must ack with the serve-loop id");
        assert!(done);
        assert_eq!(tokens.len(), 3);
        drop(sock);
        handle.shutdown().unwrap();
    }

    #[test]
    fn tcp_bad_request_gets_typed_error_line() {
        let hw = HardwareConfig::env1();
        let handle = ServerHandle::spawn(move || {
            figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let req_tx = handle.requests.clone();
        std::thread::spawn(move || serve_tcp(listener, req_tx, 0));

        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"not json\n").unwrap();
        let mut line = String::new();
        BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_ok());
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "bad_request");
        drop(sock);
        handle.shutdown().unwrap();
    }

    #[test]
    fn tcp_idle_connection_times_out_with_typed_error() {
        let hw = HardwareConfig::env1();
        let handle = ServerHandle::spawn(move || {
            figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let req_tx = handle.requests.clone();
        std::thread::spawn(move || serve_tcp(listener, req_tx, 50));

        let sock = TcpStream::connect(addr).unwrap();
        // Send nothing: the 50 ms read timeout must answer with a typed
        // "timeout" error line and close.
        let mut line = String::new();
        BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_ok());
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "timeout");
        drop(sock);
        handle.shutdown().unwrap();
    }
}
