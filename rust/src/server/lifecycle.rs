//! Request-lifecycle scheduler — the continuous-batching loop all three
//! paper scenarios flow through.
//!
//! Every request advances through one state machine:
//!
//! ```text
//!            admission (policy + KV budget)
//!   Queued ────────────────────────────────▶ Prefilling(chunk cursor)
//!                                                  │ prompt complete
//!                                                  ▼
//!   Failed ◀── error / reject / shutdown ──── Decoding(1..width slots)
//!                                                  │ max_new reached
//!                                                  ▼
//!                                               Finished
//! ```
//!
//! * **Chunked prefill** (`--prefill-chunk N`): an admitted prompt
//!   advances at most `N` tokens per loop iteration, interleaved with one
//!   decode step for every running sequence, so the inter-token latency of
//!   running sequences is bounded by one chunk instead of one prompt.
//!   `0` = monolithic prefill (the original demo-loop behavior).
//! * **Admission policies** (`--admission fcfs|sjf|slo`): FCFS, shortest
//!   prompt first, or earliest-TTFT-deadline first driven by the virtual
//!   clock ([`AdmissionKind`](crate::config::serving::AdmissionKind)).
//! * **KV-memory budget** (`--kv-budget-mb M`): admission reserves each
//!   request's worst-case KV footprint (paper scale,
//!   [`PAPER_KV_BYTES_PER_TOKEN`](crate::config::hardware::PAPER_KV_BYTES_PER_TOKEN))
//!   against a bounded pool and queues —
//!   or rejects outright-infeasible requests — instead of OOMing.  Under
//!   pressure the budget *borrows* headroom by shrinking the
//!   [`ExpertCache`]'s unpinned capacity one expert slot at a time and
//!   returns the slots when pressure subsides ([`KvBudget`]) — KV cache
//!   and expert weights arbitrate over one GPU memory pool
//!   (MoE-Lightning-style).
//! * **Beam search in the batch** (paper scenario c): a `width > 1`
//!   request prefills once, expands into `width` [`Slot`]s whose KV caches
//!   fork copy-on-write, and decodes as ordinary batch rows alongside
//!   unrelated requests; the beam update reuses the exact
//!   [`select_candidates`] kernel of the standalone driver.
//!
//! The loop is generic over [`ServeBackend`] so the scheduler itself is
//! testable in pure virtual time without model artifacts
//! ([`crate::server::sim::SimBackend`]); the real [`Engine`] is the
//! production backend.
//!
//! The engine-agnostic pieces — [`KvBudget`], the [`SequenceGroup`] /
//! [`Phase`] / [`Slot`] state machine, admission ordering — live in
//! [`super::core`] and are re-exported here; each shard of a
//! [`super::fleet`] runs one `serve_lifecycle` instance over that core.

use super::{ControlMsg, Event, FailReason, Request, MAX_REQUEST_TOKENS};
use crate::config::hardware::MIB;
use crate::config::serving::ServingConfig;
use crate::coordinator::beam::{select_candidates, top_indices_desc};
use crate::coordinator::engine::log_softmax;
use crate::coordinator::Engine;
use crate::expertcache::{CacheStats, ExpertCache};
use crate::kvcache::SequenceCache;
use crate::metrics::GenMetrics;
use crate::util::rank_key;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc::Receiver;

pub use super::core::{
    admission_order, effective_max_batch, kv_worst_case_bytes, park_pending, KvBudget, Phase,
    SequenceGroup, Slot,
};

/// Everything the lifecycle scheduler needs from an inference engine.
/// Implemented by the real [`Engine`] and by the artifact-free
/// [`crate::server::sim::SimBackend`].
pub trait ServeBackend {
    fn serving(&self) -> &ServingConfig;
    /// Current virtual time (µs).
    fn now_us(&self) -> f64;
    /// Jump the virtual clock forward to `t_us` (idle wait until the next
    /// scheduled arrival); must be a no-op when `t_us` is in the past.
    fn advance_to_us(&mut self, t_us: f64);
    /// Fresh, empty per-sequence KV cache.
    fn new_cache(&self) -> SequenceCache;
    /// The GPU expert-residency cache (KV/weight arbitration shrinks and
    /// re-grows its capacity).
    fn expert_cache_mut(&mut self) -> &mut ExpertCache;
    /// Snapshot of the expert cache's cumulative counters.
    fn cache_stats(&self) -> CacheStats;
    /// Run one prefill chunk, continuing whatever prefix `cache` already
    /// holds.  Returns the next-token logits row when `is_last` completes
    /// the prompt, `None` for interior chunks.
    fn prefill_chunk(
        &mut self,
        chunk: &[u32],
        cache: &mut SequenceCache,
        is_last: bool,
    ) -> Result<Option<Vec<f32>>>;
    /// One decode step for a batch of sequences; returns one next-token
    /// logits row per sequence, in batch order.  Rows are owned (beam
    /// groups score and fork from them after the call), which costs one
    /// vocab-sized copy per sequence per step at the trait boundary — the
    /// serve loop only takes this path when a beam group is decoding;
    /// width-1 batches go through [`ServeBackend::decode_sample`].
    fn decode_logits(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<Vec<f32>>>;
    /// Fused decode + sample: one next token per sequence, in batch
    /// order.  For batches of width-1 groups nobody needs the logits
    /// rows, so this path skips the per-sequence vocab-row copy that
    /// [`ServeBackend::decode_logits`] pays at the trait boundary.  The
    /// default routes through `decode_logits` and samples each row in
    /// batch order — bit- and RNG-stream-identical to the unfused path —
    /// while [`Engine`] overrides with its zero-copy fused kernel
    /// ([`Engine::decode_batch_step`]).
    fn decode_sample(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<u32>> {
        let rows = self.decode_logits(last, caches)?;
        Ok(rows.iter().map(|r| self.sample(r)).collect())
    }
    /// Sample a next token from a logits row (greedy at temperature 0).
    fn sample(&mut self, logits: &[f32]) -> u32;
    /// The backend's trace-event sink (disabled unless installed via
    /// [`ServeBackend::set_event_sink`]).  Cloning shares the sink.
    fn event_sink(&self) -> crate::events::EventSink {
        crate::events::EventSink::disabled()
    }
    /// Install a trace-event sink on the backend AND its expert cache, so
    /// cache/prefetch/exec events interleave with the lifecycle stream.
    /// The default drops the sink (backend emits nothing of its own).
    fn set_event_sink(&mut self, _sink: crate::events::EventSink) {}
    /// Snapshot of the backend's cumulative expert-execution counters
    /// (resident / transferred / CPU / prefetch-overlapped); the serve
    /// loop stamps per-request deltas of this into [`GenMetrics`].
    fn expert_events(&self) -> crate::moe::ExpertEvents {
        crate::moe::ExpertEvents::default()
    }
    /// Hot-reload hook: the serve loop calls this after applying a
    /// `Reload` control so the backend can pick up the serving knobs it
    /// caches (e.g. pipeline lookahead).  Default: nothing to refresh.
    fn reload(&mut self, _cfg: &ServingConfig) {}
}

impl ServeBackend for Engine {
    fn serving(&self) -> &ServingConfig {
        &self.serving
    }

    fn now_us(&self) -> f64 {
        self.cx.clock.now_us()
    }

    fn advance_to_us(&mut self, t_us: f64) {
        self.cx.clock.advance_to_us(t_us);
        let now = self.cx.clock.now_us();
        self.cx.timeline.reset_to(now);
    }

    fn new_cache(&self) -> SequenceCache {
        SequenceCache::new(self.model())
    }

    fn expert_cache_mut(&mut self) -> &mut ExpertCache {
        &mut self.cx.memory
    }

    fn cache_stats(&self) -> CacheStats {
        self.cx.memory.stats().clone()
    }

    fn prefill_chunk(
        &mut self,
        chunk: &[u32],
        cache: &mut SequenceCache,
        is_last: bool,
    ) -> Result<Option<Vec<f32>>> {
        let h = self.runner.prefill_chunk(chunk, cache, &mut self.cx)?;
        if !is_last {
            return Ok(None);
        }
        let logits = self.runner.lm_head(&h, &mut self.cx)?;
        Ok(Some(logits.row(0).to_vec()))
    }

    fn decode_logits(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<Vec<f32>>> {
        self.decode_batch_logits(last, caches)
    }

    fn decode_sample(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<u32>> {
        // Fused engine kernel: samples straight from each logits row with
        // zero copies; same RNG stream as sampling decode_logits rows in
        // batch order.
        self.decode_batch_step(last, caches)
    }

    fn sample(&mut self, logits: &[f32]) -> u32 {
        Engine::sample(self, logits)
    }

    fn event_sink(&self) -> crate::events::EventSink {
        self.cx.sink.clone()
    }

    fn set_event_sink(&mut self, sink: crate::events::EventSink) {
        Engine::set_event_sink(self, sink);
    }

    fn expert_events(&self) -> crate::moe::ExpertEvents {
        self.cx.events.clone()
    }
}

/// Run the lifecycle scheduler until `requests` disconnects (or a
/// shutdown sentinel / `Drain` control arrives) and all in-flight work
/// drains.  On shutdown, queued-but-never-admitted requests receive a
/// terminal [`Event::Failed`] — their receivers never hang — while
/// admitted sequences run to completion.
pub fn serve_lifecycle<B: ServeBackend>(
    backend: &mut B,
    requests: Receiver<Request>,
) -> Result<()> {
    let mut cfg = backend.serving().clone();
    let (max_batch, over_ceiling) = effective_max_batch(cfg.max_batch);
    if over_ceiling {
        // eprintln!, not log::warn! — the CLI installs no logger, and this
        // must reach the user (once per server, the loop runs below).
        eprintln!(
            "warning: --max-batch {} exceeds the AOT decode-batch bucket ceiling {}; clamping",
            cfg.max_batch, max_batch
        );
    }
    // Install the file sink requested by --events-out unless the caller
    // already armed one (trace-record passes its own through the config).
    if let Some(path) = cfg.events_out.as_deref() {
        if !backend.event_sink().is_enabled() {
            match crate::events::EventSink::to_path(path) {
                Ok(s) => backend.set_event_sink(s),
                Err(e) => eprintln!("warning: --events-out {path}: {e}"),
            }
        }
    }
    let sink = backend.event_sink();
    sink.emit_with(|| crate::events::TraceEvent::Meta {
        seed: cfg.seed,
        temperature: cfg.temperature,
        max_batch,
        queue_capacity: cfg.queue_capacity,
        prefill_chunk: cfg.prefill_chunk,
        admission: cfg.admission.label().to_string(),
        kv_budget_mb: cfg.kv_budget_mb,
        slo_ttft_ms: cfg.slo_ttft_ms,
        lookahead: cfg.pipeline_lookahead,
        prefill_tokens: cfg.prefill_tokens,
        max_preemptions: cfg.max_preemptions,
        faults: cfg.faults.clone().unwrap_or_default(),
        fault_seed: cfg.fault_seed,
        shards: cfg.shards,
        shard_plan: cfg.shard_plan.label().to_string(),
        replicate_hot: cfg.replicate_hot,
        quant_tier: cfg.quant_tier,
        quant_bits: cfg.quant_bits as usize,
        error_budget: cfg.error_budget,
        cache_partition: cfg.cache_partition.label().to_string(),
        adaptive: cfg.adaptive,
    });
    // Serve-loop request ids, in ingest order (Cell: the ingest closure
    // and the loop body both touch it).  Requests carrying a pre-assigned
    // id (fleet router ingest order) keep it; the counter only serves
    // locally-numbered requests.
    let next_id = std::cell::Cell::new(0u64);
    // Loop 4 of the adaptive control plane (`--adaptive on`): learned
    // TTFT/ITL admission estimates, updated at retire time from measured
    // virtual-µs GenMetrics — replay reproduces the estimator exactly.
    // RefCell: the ingest closure reads it while the retire loop writes.
    let slo_est: std::cell::RefCell<Option<crate::control::SloEstimator>> =
        std::cell::RefCell::new(
            cfg.adaptive.then(|| crate::control::SloEstimator::new(cfg.slo_ttft_ms * 1e3)),
        );
    let mut kv = KvBudget::new(cfg.kv_budget_mb);
    // Fail loudly at startup when the budget cannot EVER fit a single
    // max-length request — every long request would otherwise be
    // rejected one by one with no hint at the real cause.
    if !kv.unlimited() {
        let one_max = kv_worst_case_bytes(MAX_REQUEST_TOKENS, 0, 1);
        if !kv.ever_feasible(one_max, backend.expert_cache_mut()) {
            eprintln!(
                "warning: --kv-budget-mb {} cannot hold one max-length request \
                 ({MAX_REQUEST_TOKENS} tokens = {} MiB) even after borrowing every \
                 unpinned expert slot; such requests will be rejected at ingest",
                cfg.kv_budget_mb,
                one_max / MIB
            );
        }
    }
    let mut queue: VecDeque<SequenceGroup> = VecDeque::new();
    // Requests scheduled to arrive at a future virtual time (open-loop
    // drivers), sorted ascending by arrival.
    let mut pending: Vec<Request> = Vec::new();
    // Requests re-routed from the blocking idle receive back to the
    // top-of-loop triage (keeps ONE ingest/control application point).
    let mut inbox: VecDeque<Request> = VecDeque::new();
    let mut groups: Vec<SequenceGroup> = Vec::new();
    let mut shutting_down = false;

    // Turn an arrived request into a queued group (or reject it with a
    // terminal event).  Returns true when it was the shutdown sentinel.
    // `cfg` is passed per call (not captured) so hot reload can mutate it
    // between iterations.
    let ingest = |r: Request,
                  queue: &mut VecDeque<SequenceGroup>,
                  kv: &KvBudget,
                  backend: &mut B,
                  cfg: &ServingConfig|
     -> bool {
        if r.shutdown {
            return true;
        }
        let id = match r.id {
            Some(id) => id,
            None => {
                let id = next_id.get();
                next_id.set(id + 1);
                id
            }
        };
        let enqueue_us = r.arrive_at_us.unwrap_or_else(|| backend.now_us());
        sink.emit_with(|| crate::events::TraceEvent::RequestArrived {
            req: id,
            t_us: enqueue_us,
            prompt: r.prompt.clone(),
            max_new: r.max_new,
            width: r.width,
            slo_us: r.slo_us,
            deadline_us: r.deadline_us,
        });
        let reject = |r: &Request, reason: FailReason, msg: String| {
            let kind = reason.label().to_string();
            sink.emit_with(|| crate::events::TraceEvent::RequestRejected {
                req: id,
                t_us: enqueue_us,
                reason: msg.clone(),
                kind: kind.clone(),
            });
            let _ = r.stream.send(Event::error(reason, msg));
        };
        if r.prompt.is_empty() {
            reject(&r, FailReason::BadRequest, "bad request: empty prompt".into());
            return false;
        }
        if r.max_new == 0 {
            reject(&r, FailReason::BadRequest, "bad request: max_new must be at least 1".into());
            return false;
        }
        if r.width == 0 || r.width > max_batch {
            reject(
                &r,
                FailReason::BadRequest,
                format!("bad request: beam width {} not in 1..={max_batch}", r.width),
            );
            return false;
        }
        if queue.len() >= cfg.queue_capacity {
            reject(&r, FailReason::QueueFull, format!("queue full ({} requests)", cfg.queue_capacity));
            return false;
        }
        let worst = kv_worst_case_bytes(r.prompt.len(), r.max_new, r.width);
        if !kv.ever_feasible(worst, backend.expert_cache_mut()) {
            reject(
                &r,
                FailReason::KvInfeasible,
                format!("request KV footprint ({} MiB) exceeds --kv-budget-mb", worst / MIB),
            );
            return false;
        }
        // Default TTFT budget for requests carrying no explicit SLO: the
        // static `--slo-ttft-ms` prior, or — under `--adaptive on` — the
        // estimator's learned budget once enough requests have retired.
        let default_budget_us = match slo_est.borrow().as_ref() {
            Some(est) => est.ttft_budget_us(),
            None => cfg.slo_ttft_ms * 1e3,
        };
        let deadline_us = enqueue_us + r.slo_us.unwrap_or(default_budget_us);
        // Ingest ack carrying the serve-loop id — the handle `Cancel`
        // needs.  Client-stream-only (not a trace event).
        let _ = r.stream.send(Event::Queued(id));
        queue.push_back(SequenceGroup {
            id,
            metrics: GenMetrics {
                enqueue_us,
                prompt_tokens: r.prompt.len(),
                ..Default::default()
            },
            prompt: r.prompt,
            max_new: r.max_new,
            width: r.width,
            stream: r.stream,
            deadline_us,
            hard_deadline_us: r.deadline_us.map(|d| enqueue_us + d),
            preemptions: 0,
            resume_prefix: None,
            kv_reserved: 0,
            cache_base: CacheStats::default(),
            events_base: crate::moe::ExpertEvents::default(),
            produced: 0,
            phase: Phase::Queued,
        });
        false
    };

    loop {
        // 1. Drain newly arrived requests (non-blocking); future-dated
        //    requests wait in `pending` until the virtual clock reaches
        //    their arrival time.  Live requests (no arrival stamp) are
        //    staged and ingested only AFTER step 2 promotes already-due
        //    pending arrivals: those arrived at an earlier virtual time,
        //    so they must reach the queue (FCFS order, capacity slots)
        //    first.
        let mut live: Vec<Request> = inbox.drain(..).collect();
        loop {
            match requests.try_recv() {
                Ok(r) if r.arrive_at_us.map(|t| t > backend.now_us()).unwrap_or(false) => {
                    park_pending(r, &mut pending);
                }
                Ok(r) => live.push(r),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }
        // 2. Promote pending arrivals whose time has come, then the live
        //    batch.  Control messages are staged and applied AFTER every
        //    same-iteration ingest, at one fixed point — a recorded
        //    control replays at the same iteration boundary whether it
        //    originally arrived live (TCP) or time-stamped (replay).
        let mut controls: Vec<Request> = Vec::new();
        while pending.first().map(|r| r.arrive_at_us.unwrap_or(0.0) <= backend.now_us())
            == Some(true)
        {
            let r = pending.remove(0);
            if r.control.is_some() {
                controls.push(r);
            } else if ingest(r, &mut queue, &kv, backend, &cfg) {
                shutting_down = true;
            }
        }
        for r in live {
            if r.control.is_some() {
                controls.push(r);
            } else if ingest(r, &mut queue, &kv, backend, &cfg) {
                shutting_down = true;
            }
        }
        // 2b. Apply staged controls between iterations: cancel releases
        //     everything the request holds; reload swaps scheduling knobs
        //     without touching in-flight groups; drain flips shutdown.
        for r in controls {
            let now = backend.now_us();
            let msg = r.control.clone().expect("staged control");
            match &msg {
                ControlMsg::Cancel { req } => {
                    let req = *req;
                    if let Some(pos) = queue.iter().position(|g| g.id == req) {
                        let g = queue.remove(pos).unwrap();
                        sink.emit_with(|| crate::events::TraceEvent::RequestCancelled {
                            req,
                            t_us: now,
                            phase: "queued".to_string(),
                        });
                        g.fail(FailReason::Cancelled, "request cancelled");
                    } else if let Some(pos) = groups.iter().position(|g| g.id == req) {
                        let g = groups.remove(pos);
                        let phase = match &g.phase {
                            Phase::Queued => "queued",
                            Phase::Prefilling { .. } => "prefilling",
                            Phase::Decoding { .. } => "decoding",
                        };
                        sink.emit_with(|| crate::events::TraceEvent::RequestCancelled {
                            req,
                            t_us: now,
                            phase: phase.to_string(),
                        });
                        kv.release(g.kv_reserved, backend.expert_cache_mut());
                        let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                        sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                            t_us: now,
                            used_bytes: used,
                            borrowed_slots: borrowed,
                        });
                        g.fail(FailReason::Cancelled, "request cancelled");
                    }
                    // Unknown / already-finished id: ack only, no trace
                    // event — replay never re-sends a no-op cancel.
                }
                ControlMsg::Reload(spec) => {
                    if let Some(a) = spec.admission {
                        cfg.admission = a;
                    }
                    if let Some(mb) = spec.kv_budget_mb {
                        cfg.kv_budget_mb = mb;
                        kv.set_pool_mb(mb, backend.expert_cache_mut());
                        let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                        sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                            t_us: now,
                            used_bytes: used,
                            borrowed_slots: borrowed,
                        });
                    }
                    if let Some(p) = spec.prefill_chunk {
                        cfg.prefill_chunk = p;
                    }
                    if let Some(p) = spec.prefill_tokens {
                        cfg.prefill_tokens = p;
                    }
                    if let Some(s) = spec.slo_ttft_ms {
                        cfg.slo_ttft_ms = s;
                    }
                    if let Some(m) = spec.max_preemptions {
                        cfg.max_preemptions = m;
                    }
                    backend.reload(&cfg);
                    // Full post-reload snapshot: replay re-applies the
                    // snapshot rather than the delta, so one recorded
                    // event suffices regardless of which fields changed.
                    let snap = (
                        cfg.admission.label().to_string(),
                        cfg.kv_budget_mb,
                        cfg.prefill_chunk,
                        cfg.prefill_tokens,
                        cfg.slo_ttft_ms,
                        cfg.max_preemptions,
                    );
                    sink.emit_with(|| crate::events::TraceEvent::ConfigReloaded {
                        t_us: now,
                        admission: snap.0.clone(),
                        kv_budget_mb: snap.1,
                        prefill_chunk: snap.2,
                        prefill_tokens: snap.3,
                        slo_ttft_ms: snap.4,
                        max_preemptions: snap.5,
                    });
                }
                ControlMsg::Drain => {
                    shutting_down = true;
                    sink.emit_with(|| crate::events::TraceEvent::DrainStarted { t_us: now });
                }
            }
            let _ = r.stream.send(Event::ControlAck { op: msg.op() });
        }
        // 3. Shutdown: everything not yet admitted gets a terminal event
        //    (receivers must never hang); admitted groups drain below.
        if shutting_down {
            for g in queue.drain(..) {
                let (id, t) = (g.id, backend.now_us());
                sink.emit_with(|| crate::events::TraceEvent::RequestFailed {
                    req: id,
                    t_us: t,
                    reason: "server shutting down before admission".to_string(),
                    kind: FailReason::Shutdown.label().to_string(),
                });
                g.fail(FailReason::Shutdown, "server shutting down before admission");
            }
            for r in pending.drain(..) {
                if !r.shutdown {
                    let _ = r.stream.send(Event::error(
                        FailReason::Shutdown,
                        "server shutting down before admission",
                    ));
                }
            }
            if groups.is_empty() {
                return Ok(());
            }
        }

        // 4. Idle: nothing active, nothing admissible.
        if groups.is_empty() && queue.is_empty() {
            if let Some(t) = pending.first().and_then(|r| r.arrive_at_us) {
                backend.advance_to_us(t);
                continue;
            }
            match requests.recv() {
                // Everything received here re-enters through the
                // top-of-loop triage (park / ingest / stage-control), so
                // live drivers get the same exact virtual-time replay as
                // pre-loaded channels.
                Ok(r) => {
                    inbox.push_back(r);
                    continue;
                }
                Err(_) => return Ok(()),
            }
        }

        // 4b. Deadline enforcement at the iteration (= chunk) boundary:
        //     any request — queued, prefilling, or decoding — whose
        //     enforced deadline has lapsed fails with a typed reason and
        //     releases whatever it holds.
        {
            let now = backend.now_us();
            let lapsed = |g: &SequenceGroup| g.hard_deadline_us.map(|d| now > d).unwrap_or(false);
            let mut qi = 0;
            while qi < queue.len() {
                if !lapsed(&queue[qi]) {
                    qi += 1;
                    continue;
                }
                let g = queue.remove(qi).unwrap();
                let id = g.id;
                sink.emit_with(|| crate::events::TraceEvent::RequestFailed {
                    req: id,
                    t_us: now,
                    reason: "deadline exceeded before completion".to_string(),
                    kind: FailReason::Deadline.label().to_string(),
                });
                g.fail(FailReason::Deadline, "deadline exceeded before completion");
            }
            let mut gi = 0;
            while gi < groups.len() {
                if !lapsed(&groups[gi]) {
                    gi += 1;
                    continue;
                }
                let g = groups.remove(gi);
                let id = g.id;
                sink.emit_with(|| crate::events::TraceEvent::RequestFailed {
                    req: id,
                    t_us: now,
                    reason: "deadline exceeded before completion".to_string(),
                    kind: FailReason::Deadline.label().to_string(),
                });
                kv.release(g.kv_reserved, backend.expert_cache_mut());
                let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                    t_us: now,
                    used_bytes: used,
                    borrowed_slots: borrowed,
                });
                g.fail(FailReason::Deadline, "deadline exceeded before completion");
            }
        }

        // 5. Admission: one request per iteration — the first candidate in
        //    policy order that fits the free batch slots AND the KV budget
        //    (backfill: a wide or KV-hungry head never starves admissible
        //    requests behind it).  With the legacy single-prefill cadence
        //    (`--prefill-tokens 0`) admission is held while a prefill is
        //    in flight so the running sequences' ITL bound is preserved;
        //    with a prefill token budget admission stays open and the
        //    budget bounds ITL instead.
        //
        //    Preemption (`--max-preemptions N`): when the candidate fits
        //    the batch but not the KV budget, evict the width-1 decoding
        //    group with the LATEST admission deadline — provided that
        //    deadline is strictly later than the candidate's (preempting
        //    never helps an already-later request) and the victim has
        //    preemptions left.  The victim's KV is dropped and recomputed
        //    from prompt + generated tokens on readmission; at most one
        //    victim per iteration keeps the policy conservative.
        let active_slots: usize = groups.iter().map(|g| g.slot_count()).sum();
        let hold_for_prefill = cfg.prefill_tokens == 0
            && groups.iter().any(|g| matches!(g.phase, Phase::Prefilling { .. }));
        if !hold_for_prefill && !shutting_down {
            let mut preempted_this_iter = false;
            for i in admission_order(&queue, cfg.admission) {
                if active_slots + queue[i].width > max_batch {
                    continue;
                }
                let worst =
                    kv_worst_case_bytes(queue[i].prompt.len(), queue[i].max_new, queue[i].width);
                let mut reserved = kv.try_reserve(worst, backend.expert_cache_mut());
                if !reserved && cfg.max_preemptions > 0 && !preempted_this_iter {
                    let cand_deadline = queue[i].deadline_us;
                    let victim = groups
                        .iter()
                        .enumerate()
                        .filter(|(_, g)| {
                            g.width == 1
                                && matches!(g.phase, Phase::Decoding { .. })
                                && g.preemptions < cfg.max_preemptions
                                && g.deadline_us > cand_deadline
                        })
                        .max_by(|(_, a), (_, b)| a.deadline_us.total_cmp(&b.deadline_us))
                        .map(|(vi, _)| vi);
                    if let Some(vi) = victim {
                        let mut v = groups.remove(vi);
                        let now = backend.now_us();
                        kv.release(v.kv_reserved, backend.expert_cache_mut());
                        let released = v.kv_reserved;
                        v.kv_reserved = 0;
                        v.preemptions += 1;
                        // Drop-and-recompute: prefill prompt + generated
                        // on readmission, resume at token `produced`.
                        let generated = match &v.phase {
                            Phase::Decoding { slots } => slots[0].tokens.clone(),
                            _ => unreachable!("victim filter keeps only decoding groups"),
                        };
                        let mut prefix = v.prompt.clone();
                        prefix.extend_from_slice(&generated);
                        v.resume_prefix = Some(prefix);
                        v.phase = Phase::Queued;
                        let (vid, n_pre, n_tok) = (v.id, v.preemptions, v.produced);
                        sink.emit_with(|| crate::events::TraceEvent::RequestPreempted {
                            req: vid,
                            t_us: now,
                            kv_released: released,
                            preemptions: n_pre,
                            tokens_done: n_tok,
                        });
                        sink.emit_with(|| crate::events::TraceEvent::RequestRequeued {
                            req: vid,
                            t_us: now,
                        });
                        let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                        sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                            t_us: now,
                            used_bytes: used,
                            borrowed_slots: borrowed,
                        });
                        queue.push_back(v);
                        preempted_this_iter = true;
                        reserved = kv.try_reserve(worst, backend.expert_cache_mut());
                    }
                }
                if reserved {
                    let mut g = queue.remove(i).unwrap();
                    g.kv_reserved = worst;
                    g.metrics.admitted_us = backend.now_us();
                    g.cache_base = backend.cache_stats();
                    g.events_base = backend.expert_events();
                    g.phase = Phase::Prefilling { cursor: 0, cache: backend.new_cache() };
                    let (id, t, qd) = (g.id, backend.now_us(), g.metrics.queue_delay_us());
                    sink.emit_with(|| crate::events::TraceEvent::RequestAdmitted {
                        req: id,
                        t_us: t,
                        kv_reserved: worst,
                        queue_delay_us: qd,
                    });
                    let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                    sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                        t_us: t,
                        used_bytes: used,
                        borrowed_slots: borrowed,
                    });
                    groups.push(g);
                    break;
                }
            }
        }

        // 6. Prefill.  Legacy cadence (`--prefill-tokens 0`): exactly one
        //    prefill in flight, one chunk per iteration.  Budgeted
        //    cadence (`--prefill-tokens B`): every prefilling group
        //    advances in admission order until the iteration's token
        //    budget is spent — the FIRST group always advances one full
        //    chunk (progress guarantee even when B < chunk), later ones
        //    consume what remains of B.  On completion a group emits its
        //    next token at index `produced` (0 for fresh prompts, the
        //    resume index after a preemption) and expands into decode
        //    slots.
        let mut failed: Vec<(usize, String)> = Vec::new();
        let prefill_idx: Vec<usize> = groups
            .iter()
            .enumerate()
            .filter(|(_, g)| matches!(g.phase, Phase::Prefilling { .. }))
            .map(|(i, _)| i)
            .collect();
        let mut budget_left = cfg.prefill_tokens;
        for (k, &gi) in prefill_idx.iter().enumerate() {
            if k > 0 && cfg.prefill_tokens == 0 {
                break; // legacy: a single prefill holds admission anyway
            }
            let g = &mut groups[gi];
            let Phase::Prefilling { cursor, cache } = &mut g.phase else { unreachable!() };
            // Split borrows: prefix fields are disjoint from `phase`.
            let prefix: &[u32] = match &g.resume_prefix {
                Some(p) => p,
                None => &g.prompt,
            };
            let remaining = prefix.len() - *cursor;
            let mut step =
                if cfg.prefill_chunk == 0 { remaining } else { cfg.prefill_chunk.min(remaining) };
            if cfg.prefill_tokens > 0 {
                if k > 0 {
                    step = step.min(budget_left);
                }
                if step == 0 {
                    break; // budget spent: later prefills wait their turn
                }
                budget_left = budget_left.saturating_sub(step);
            }
            let is_last = *cursor + step == prefix.len();
            let chunk_start = *cursor;
            match backend.prefill_chunk(&prefix[*cursor..*cursor + step], cache, is_last) {
                Err(e) => {
                    failed.push((gi, e.to_string()));
                }
                Ok(None) => {
                    *cursor += step;
                    let (id, t) = (g.id, backend.now_us());
                    sink.emit_with(|| crate::events::TraceEvent::PrefillChunk {
                        req: id,
                        t_us: t,
                        start: chunk_start,
                        len: step,
                        is_last: false,
                    });
                }
                Ok(Some(logits)) => {
                    let now = backend.now_us();
                    let id = g.id;
                    sink.emit_with(|| crate::events::TraceEvent::PrefillChunk {
                        req: id,
                        t_us: now,
                        start: chunk_start,
                        len: step,
                        is_last: true,
                    });
                    if g.produced == 0 {
                        g.metrics.first_token_us = now;
                    }
                    g.metrics.token_done_us.push(now);
                    let slots = if g.width == 1 {
                        let tok = backend.sample(&logits);
                        let _ = g.stream.send(Event::Token(tok));
                        let idx = g.produced;
                        sink.emit_with(|| crate::events::TraceEvent::TokenEmitted {
                            req: id,
                            t_us: now,
                            token: tok,
                            index: idx,
                        });
                        let cache = std::mem::replace(
                            cache,
                            SequenceCache { layers: Vec::new(), quant_budget: None },
                        );
                        // A resumed group carries its first-stint tokens
                        // forward (a second preemption rebuilds its
                        // prefix from this list).
                        let mut tokens: Vec<u32> = g
                            .resume_prefix
                            .as_ref()
                            .map(|p| p[g.prompt.len()..].to_vec())
                            .unwrap_or_default();
                        tokens.push(tok);
                        vec![Slot { cache, last: tok, tokens, score: 0.0 }]
                    } else {
                        // Beam expansion: top-width first tokens, caches
                        // forked copy-on-write (scenario c).  Beam groups
                        // are never preempted, so no resume path here.
                        let lsm = log_softmax(&logits);
                        top_indices_desc(&lsm, g.width)
                            .into_iter()
                            .map(|t| Slot {
                                cache: cache.fork(),
                                last: t as u32,
                                tokens: vec![t as u32],
                                score: lsm[t],
                            })
                            .collect()
                    };
                    g.produced += 1;
                    g.resume_prefix = None;
                    g.phase = Phase::Decoding { slots };
                }
            }
        }
        for (gi, msg) in failed.into_iter().rev() {
            let g = groups.remove(gi);
            let (id, t) = (g.id, backend.now_us());
            let reason = msg.clone();
            sink.emit_with(|| crate::events::TraceEvent::RequestFailed {
                req: id,
                t_us: t,
                reason,
                kind: FailReason::Backend.label().to_string(),
            });
            kv.release(g.kv_reserved, backend.expert_cache_mut());
            let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
            sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                t_us: t,
                used_bytes: used,
                borrowed_slots: borrowed,
            });
            g.fail(FailReason::Backend, &msg);
        }

        // 7. One decode step for every decoding slot (beam slots decode as
        //    ordinary batch rows — cross-request batching per scenario c).
        //    A batch of pure width-1 groups takes the fused decode+sample
        //    path — nobody needs the logits rows, so the per-sequence
        //    vocab-row copy at the trait boundary is skipped; any beam
        //    group in the batch forces the logits path for everyone (its
        //    update scores whole rows).  Sampling order — and with it the
        //    RNG stream — is identical either way: batch order.
        enum StepOut {
            Tokens(Vec<u32>),
            Logits(Vec<Vec<f32>>),
            Error(String),
        }
        let step = {
            let mut last: Vec<u32> = Vec::new();
            let mut caches: Vec<&mut SequenceCache> = Vec::new();
            let mut all_width1 = true;
            for g in groups.iter_mut() {
                if g.produced >= g.max_new {
                    continue; // already complete (e.g. max_new == 1): retire below
                }
                if let Phase::Decoding { slots } = &mut g.phase {
                    if g.width > 1 {
                        all_width1 = false;
                    }
                    for s in slots.iter_mut() {
                        last.push(s.last);
                        caches.push(&mut s.cache);
                    }
                }
            }
            if last.is_empty() {
                None
            } else if all_width1 {
                match backend.decode_sample(&last, &mut caches) {
                    Ok(toks) => Some(StepOut::Tokens(toks)),
                    Err(e) => Some(StepOut::Error(e.to_string())),
                }
            } else {
                match backend.decode_logits(&last, &mut caches) {
                    Ok(rows) => Some(StepOut::Logits(rows)),
                    Err(e) => Some(StepOut::Error(e.to_string())),
                }
            }
        };
        // A failed decode step fails every group that contributed a row —
        // their KV histories are suspect — and the server keeps serving
        // everyone else (a backend fault is a request-scoped incident,
        // not a process-scoped one).
        if let Some(StepOut::Error(msg)) = &step {
            let msg = format!("decode step failed: {msg}");
            let now = backend.now_us();
            let mut gi = 0;
            while gi < groups.len() {
                let contributed = groups[gi].produced < groups[gi].max_new
                    && matches!(groups[gi].phase, Phase::Decoding { .. });
                if !contributed {
                    gi += 1;
                    continue;
                }
                let g = groups.remove(gi);
                let id = g.id;
                let reason = msg.clone();
                sink.emit_with(|| crate::events::TraceEvent::RequestFailed {
                    req: id,
                    t_us: now,
                    reason,
                    kind: FailReason::Backend.label().to_string(),
                });
                kv.release(g.kv_reserved, backend.expert_cache_mut());
                let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
                sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                    t_us: now,
                    used_bytes: used,
                    borrowed_slots: borrowed,
                });
                g.fail(FailReason::Backend, &msg);
            }
        }
        if let Some(step) = step.filter(|s| !matches!(s, StepOut::Error(_))) {
            let now = backend.now_us();
            let mut ri = 0;
            for g in groups.iter_mut() {
                if g.produced >= g.max_new {
                    continue; // contributed no rows above
                }
                let Phase::Decoding { slots } = &mut g.phase else { continue };
                let w = slots.len();
                if let StepOut::Tokens(toks) = &step {
                    debug_assert_eq!(w, 1, "fused path only runs width-1 batches");
                    let tok = toks[ri];
                    ri += w;
                    let s = &mut slots[0];
                    s.last = tok;
                    s.tokens.push(tok);
                    let _ = g.stream.send(Event::Token(tok));
                    let (id, idx) = (g.id, g.produced);
                    sink.emit_with(|| crate::events::TraceEvent::TokenEmitted {
                        req: id,
                        t_us: now,
                        token: tok,
                        index: idx,
                    });
                    g.produced += 1;
                    g.metrics.token_done_us.push(now);
                    continue;
                }
                let StepOut::Logits(rows) = &step else { unreachable!() };
                let rows_g = &rows[ri..ri + w];
                ri += w;
                if g.width == 1 {
                    let tok = backend.sample(&rows_g[0]);
                    let s = &mut slots[0];
                    s.last = tok;
                    s.tokens.push(tok);
                    let _ = g.stream.send(Event::Token(tok));
                    let (id, idx) = (g.id, g.produced);
                    sink.emit_with(|| crate::events::TraceEvent::TokenEmitted {
                        req: id,
                        t_us: now,
                        token: tok,
                        index: idx,
                    });
                } else {
                    // Same beam-update kernel as the standalone driver.
                    let scores: Vec<f32> = slots.iter().map(|s| s.score).collect();
                    let all_lsm: Vec<Vec<f32>> =
                        rows_g.iter().map(|r| log_softmax(r)).collect();
                    let cands = select_candidates(&scores, &all_lsm, g.width);
                    let next: Vec<Slot> = cands
                        .iter()
                        .map(|&(score, bi, t)| {
                            let parent = &slots[bi];
                            let mut tokens = parent.tokens.clone();
                            tokens.push(t as u32);
                            Slot { cache: parent.cache.fork(), last: t as u32, tokens, score }
                        })
                        .collect();
                    *slots = next;
                }
                g.produced += 1;
                g.metrics.token_done_us.push(now);
            }
        }

        // 8. Retire finished groups: stamp the per-request cache-stat
        //    delta, stream beam winners, release KV reservations.
        let mut gi = 0;
        while gi < groups.len() {
            if groups[gi].produced < groups[gi].max_new {
                gi += 1;
                continue;
            }
            let mut g = groups.remove(gi);
            g.metrics.cache = Some(backend.cache_stats().delta_since(&g.cache_base));
            g.metrics.experts = Some(backend.expert_events().delta_since(&g.events_base));
            g.metrics.preemptions = g.preemptions;
            let (id, t) = (g.id, backend.now_us());
            if g.width > 1 {
                if let Phase::Decoding { slots } = &g.phase {
                    let best = slots
                        .iter()
                        .max_by(|a, b| rank_key(a.score).total_cmp(&rank_key(b.score)))
                        .expect("beam group without slots");
                    for (i, &tok) in best.tokens.iter().enumerate() {
                        let _ = g.stream.send(Event::Token(tok));
                        sink.emit_with(|| crate::events::TraceEvent::TokenEmitted {
                            req: id,
                            t_us: t,
                            token: tok,
                            index: i,
                        });
                    }
                }
            }
            let _ = g.stream.send(Event::Done(g.metrics.clone()));
            let (tokens, ttft, qd) =
                (g.metrics.token_done_us.len(), g.metrics.ttft_us(), g.metrics.queue_delay_us());
            sink.emit_with(|| crate::events::TraceEvent::RequestFinished {
                req: id,
                t_us: t,
                tokens,
                ttft_us: ttft,
                queue_delay_us: qd,
            });
            // Loop 4 (--adaptive): absorb this request's measured outcome
            // into the admission estimator.
            if let Some(est) = slo_est.borrow_mut().as_mut() {
                let itls = g.metrics.itl_us();
                let mean_itl = if itls.is_empty() {
                    0.0
                } else {
                    itls.iter().sum::<f64>() / itls.len() as f64
                };
                est.observe(ttft, mean_itl);
                let (ttft_ms, itl_ms, samples) =
                    (est.ttft_est_us() / 1e3, est.itl_est_us() / 1e3, est.samples());
                sink.emit_with(|| crate::events::TraceEvent::SloEstimateUpdated {
                    t_us: t,
                    ttft_ms,
                    itl_ms,
                    samples,
                });
            }
            kv.release(g.kv_reserved, backend.expert_cache_mut());
            let (used, borrowed) = (kv.used_bytes(), kv.borrowed_slots());
            sink.emit_with(|| crate::events::TraceEvent::KvBudget {
                t_us: t,
                used_bytes: used,
                borrowed_slots: borrowed,
            });
        }
    }
}
