//! Artifact-free serving backend: drives the lifecycle scheduler in pure
//! virtual time, with deterministic token "numerics" that depend only on
//! the per-sequence KV state — never on batching, chunking, or
//! interleaving.  This is what makes the scheduler's contracts (chunked
//! prefill bounds ITL *and* preserves outputs; beams batch with ordinary
//! traffic unchanged) testable and benchmarkable on hosts without the
//! PJRT artifacts, the same way [`crate::expertcache::sim`] does for
//! eviction policies.
//!
//! Cost model (virtual µs, loosely shaped like the calibrated tiny-model
//! engine): a prefill chunk of `n` tokens costs
//! `prefill_chunk_base_us + n * prefill_per_token_us` — the base term is
//! the per-chunk expert-amortization loss that makes chunking a genuine
//! throughput/latency trade-off — and a decode step over `b` sequences
//! costs `decode_base_us + b * decode_per_seq_us` (batching amortizes the
//! base).  Every processed token also does one expert-cache lookup so
//! per-request cache-stat deltas have real counters to attribute.

use super::lifecycle::{serve_lifecycle, ServeBackend};
use super::{collect, Request};
use crate::config::serving::ServingConfig;
use crate::config::ModelConfig;
use crate::coordinator::engine::sample_token;
use crate::expertcache::{CacheStats, ExpertCache};
use crate::hardware::VirtualClock;
use crate::kvcache::SequenceCache;
use crate::metrics::Aggregate;
use crate::util::rng::Rng;
use crate::workload::{Dataset, PoissonArrivals, WorkloadGen};
use anyhow::Result;

pub struct SimBackend {
    pub serving: ServingConfig,
    cfg: ModelConfig,
    clock: VirtualClock,
    cache: ExpertCache,
    rng: Rng,
    sink: crate::events::EventSink,
    events: crate::moe::ExpertEvents,
    /// Fixed per-chunk cost (expert-base amortization lost to chunking).
    pub prefill_chunk_base_us: f64,
    pub prefill_per_token_us: f64,
    pub decode_base_us: f64,
    pub decode_per_seq_us: f64,
}

impl SimBackend {
    pub fn new(serving: ServingConfig) -> SimBackend {
        let rng = Rng::new(serving.seed ^ 0x51A4);
        SimBackend {
            cfg: ModelConfig::test_tiny(),
            clock: VirtualClock::new(),
            cache: ExpertCache::with_capacity(8),
            rng,
            sink: crate::events::EventSink::disabled(),
            events: crate::moe::ExpertEvents::default(),
            prefill_chunk_base_us: 2_000.0,
            prefill_per_token_us: 1_000.0,
            decode_base_us: 20_000.0,
            decode_per_seq_us: 2_000.0,
            serving,
        }
    }

    pub fn expert_cache(&self) -> &ExpertCache {
        &self.cache
    }

    /// Append one token to every layer of `cache`, encoding the token
    /// value into the K stream — the sim's stand-in for real numerics:
    /// any scheduler bug that skips, repeats, or reorders tokens changes
    /// every subsequent output.
    fn append_token(&mut self, cache: &mut SequenceCache, tok: u32) {
        let kvd = self.cfg.kv_dim();
        let mut k = vec![0.0f32; kvd];
        k[0] = tok as f32;
        let v = vec![0.0f32; kvd];
        for l in &mut cache.layers {
            l.append(&k, &v);
        }
        // One expert-cache access per token: gives per-request cache-stat
        // deltas real counters, and keeps the arbitration path (capacity
        // shrink/grow) exercised under load.
        if self.cache.fetch((0, tok as usize % self.cfg.n_experts)) {
            self.events.transferred += 1;
        } else {
            self.events.resident += 1;
        }
    }

    /// Deterministic next-token logits from the sequence's KV state: an
    /// FNV-1a hash over the token history picks the peak.  Rows depend
    /// only on this sequence — batching and chunking cannot change them.
    fn logits_for(&self, cache: &SequenceCache) -> Vec<f32> {
        let kvd = self.cfg.kv_dim();
        let lc = &cache.layers[0];
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for i in 0..lc.len {
            h = (h ^ lc.k[i * kvd] as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        let peak = (h % self.cfg.vocab as u64) as usize;
        let mut row = vec![0.0f32; self.cfg.vocab];
        // Distinct top-3 so beam groups have real alternatives to fork.
        row[peak] = 4.0;
        row[(peak + 1) % self.cfg.vocab] = 2.0;
        row[(peak + 2) % self.cfg.vocab] = 1.0;
        row
    }
}

impl ServeBackend for SimBackend {
    fn serving(&self) -> &ServingConfig {
        &self.serving
    }

    fn now_us(&self) -> f64 {
        self.clock.now_us()
    }

    fn advance_to_us(&mut self, t_us: f64) {
        self.clock.advance_to_us(t_us);
    }

    fn new_cache(&self) -> SequenceCache {
        SequenceCache::new(&self.cfg)
    }

    fn expert_cache_mut(&mut self) -> &mut ExpertCache {
        &mut self.cache
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats().clone()
    }

    fn prefill_chunk(
        &mut self,
        chunk: &[u32],
        cache: &mut SequenceCache,
        is_last: bool,
    ) -> Result<Option<Vec<f32>>> {
        anyhow::ensure!(!chunk.is_empty(), "empty prefill chunk");
        self.clock
            .advance_us(self.prefill_chunk_base_us + chunk.len() as f64 * self.prefill_per_token_us);
        self.cache.set_time_hint(self.clock.now_us());
        for &t in chunk {
            self.append_token(cache, t);
        }
        if is_last { Ok(Some(self.logits_for(cache))) } else { Ok(None) }
    }

    fn decode_logits(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(last.len(), caches.len());
        self.clock
            .advance_us(self.decode_base_us + last.len() as f64 * self.decode_per_seq_us);
        self.cache.set_time_hint(self.clock.now_us());
        let mut rows = Vec::with_capacity(last.len());
        for (i, cache) in caches.iter_mut().enumerate() {
            self.append_token(cache, last[i]);
            rows.push(self.logits_for(&**cache));
        }
        Ok(rows)
    }

    fn sample(&mut self, logits: &[f32]) -> u32 {
        sample_token(logits, self.serving.temperature, &mut self.rng)
    }

    fn event_sink(&self) -> crate::events::EventSink {
        self.sink.clone()
    }

    fn set_event_sink(&mut self, sink: crate::events::EventSink) {
        self.cache.set_event_sink(sink.clone());
        self.sink = sink;
    }

    fn expert_events(&self) -> crate::moe::ExpertEvents {
        self.events.clone()
    }
}

/// Workload shape for [`run_open_loop`].
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub n_requests: usize,
    /// Open-loop Poisson arrival rate (requests per virtual second).
    pub rate_per_s: f64,
    pub inp: usize,
    pub out: usize,
    /// Every `long_every`-th request carries a `long_inp`-token prompt
    /// (0 = uniform workload) — the prefill interference the chunked
    /// scheduler is built to absorb.
    pub long_every: usize,
    pub long_inp: usize,
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            n_requests: 100,
            rate_per_s: 6.0,
            inp: 24,
            out: 24,
            long_every: 8,
            long_inp: 320,
            seed: 11,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub completed: usize,
    /// Terminal-error outcomes (queue-full / KV-infeasible rejections).
    pub rejected: usize,
    /// First arrival to last token, virtual seconds.
    pub makespan_s: f64,
    pub output_tokens: usize,
    pub agg: Aggregate,
}

impl LoadReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.makespan_s
    }
}

/// Replay an open-loop Poisson workload through the lifecycle scheduler
/// on a [`SimBackend`], entirely in virtual time.  This is the
/// load-generator substrate behind `examples/load_gen.rs` and the
/// `BENCH_PR4.json` section of `benches/e2e_decode.rs`.
pub fn run_open_loop(serving: ServingConfig, spec: &LoadSpec) -> Result<LoadReport> {
    let mut arrivals = PoissonArrivals::new(spec.rate_per_s, spec.seed);
    let mut gen = WorkloadGen::new(Dataset::sharegpt(), 512, spec.seed ^ 0x10AD);
    let (tx, rx) = std::sync::mpsc::channel();
    let mut first_arrival_us = f64::INFINITY;
    let receivers: Vec<_> = (0..spec.n_requests)
        .map(|i| {
            let len = if spec.long_every > 0 && i % spec.long_every == spec.long_every - 1 {
                spec.long_inp
            } else {
                spec.inp
            };
            let (etx, erx) = std::sync::mpsc::channel();
            let mut r = Request::new(gen.prompt(len), spec.out, etx);
            let t = arrivals.next_arrival_us();
            first_arrival_us = first_arrival_us.min(t);
            r.arrive_at_us = Some(t);
            tx.send(r).expect("loop not started yet");
            erx
        })
        .collect();
    let mut sentinel = Request::shutdown_sentinel();
    sentinel.arrive_at_us = Some(1e15); // fires once the loop idles out
    tx.send(sentinel).expect("loop not started yet");

    let mut backend = SimBackend::new(serving);
    serve_lifecycle(&mut backend, rx)?;
    drop(tx);

    let mut report = LoadReport::default();
    for rx in &receivers {
        match collect(rx) {
            Ok((tokens, m)) => {
                report.completed += 1;
                report.output_tokens += tokens.len();
                if let Some(&t) = m.token_done_us.last() {
                    report.makespan_s = report.makespan_s.max(t / 1e6);
                }
                report.agg.push(&m);
            }
            Err(_) => report.rejected += 1,
        }
    }
    // makespan is "first arrival to last token", not "virtual epoch to
    // last token" — the empty lead-in before the first arrival is idle.
    if report.completed > 0 {
        report.makespan_s = (report.makespan_s - first_arrival_us / 1e6).max(0.0);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_depend_on_history_not_chunking() {
        let mut a = SimBackend::new(ServingConfig::default());
        let mut b = SimBackend::new(ServingConfig::default());
        let prompt: Vec<u32> = (1..=10).collect();
        let mut ca = a.new_cache();
        let mut cb = b.new_cache();
        // One monolithic chunk vs three uneven chunks.
        let ra = a.prefill_chunk(&prompt, &mut ca, true).unwrap().unwrap();
        assert!(b.prefill_chunk(&prompt[..3], &mut cb, false).unwrap().is_none());
        assert!(b.prefill_chunk(&prompt[3..4], &mut cb, false).unwrap().is_none());
        let rb = b.prefill_chunk(&prompt[4..], &mut cb, true).unwrap().unwrap();
        assert_eq!(ra, rb, "chunking changed the sim numerics");
        // ...but a different prompt changes them.
        let mut c = SimBackend::new(ServingConfig::default());
        let mut cc = c.new_cache();
        let other: Vec<u32> = (2..=11).collect();
        let rc = c.prefill_chunk(&other, &mut cc, true).unwrap().unwrap();
        assert_ne!(ra, rc);
    }

    #[test]
    fn open_loop_run_serves_everything_at_light_load() {
        let spec = LoadSpec {
            n_requests: 12,
            rate_per_s: 3.0,
            inp: 8,
            out: 6,
            long_every: 4,
            long_inp: 64,
            seed: 5,
        };
        let report = run_open_loop(ServingConfig::default(), &spec).unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.output_tokens, 12 * 6);
        assert!(report.makespan_s > 0.0);
        assert!(report.throughput_tok_s() > 0.0);
        // Open loop: the makespan at 3 req/s over 12 requests spans at
        // least the arrival horizon (~4 s mean).
        assert!(report.makespan_s > 1.0, "arrivals not replayed in virtual time");
    }

    #[test]
    fn decode_charges_amortized_batch_cost() {
        let mut s = SimBackend::new(ServingConfig::default());
        let mut c1 = s.new_cache();
        let mut c2 = s.new_cache();
        s.prefill_chunk(&[1], &mut c1, true).unwrap();
        s.prefill_chunk(&[2], &mut c2, true).unwrap();
        let t0 = s.now_us();
        let mut caches = [&mut c1, &mut c2];
        let rows = s.decode_logits(&[3, 4], &mut caches).unwrap();
        assert_eq!(rows.len(), 2);
        let dt = s.now_us() - t0;
        assert!((dt - (s.decode_base_us + 2.0 * s.decode_per_seq_us)).abs() < 1e-6);
    }
}
