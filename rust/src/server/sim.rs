//! Artifact-free serving backend: drives the lifecycle scheduler in pure
//! virtual time, with deterministic token "numerics" that depend only on
//! the per-sequence KV state — never on batching, chunking, or
//! interleaving.  This is what makes the scheduler's contracts (chunked
//! prefill bounds ITL *and* preserves outputs; beams batch with ordinary
//! traffic unchanged) testable and benchmarkable on hosts without the
//! PJRT artifacts, the same way [`crate::expertcache::sim`] does for
//! eviction policies.
//!
//! Cost model (virtual µs, loosely shaped like the calibrated tiny-model
//! engine): a prefill chunk of `n` tokens costs
//! `prefill_chunk_base_us + n * prefill_per_token_us` — the base term is
//! the per-chunk expert-amortization loss that makes chunking a genuine
//! throughput/latency trade-off — and a decode step over `b` sequences
//! costs `decode_base_us + b * decode_per_seq_us` (batching amortizes the
//! base).  Every processed token also does one expert-cache lookup so
//! per-request cache-stat deltas have real counters to attribute.

use super::lifecycle::{serve_lifecycle, ServeBackend};
use super::{collect_outcome, ControlMsg, Request};
use crate::config::serving::ServingConfig;
use crate::config::ModelConfig;
use crate::coordinator::engine::sample_token;
use crate::expertcache::{CacheStats, ExpertCache};
use crate::hardware::VirtualClock;
use crate::kvcache::SequenceCache;
use crate::metrics::Aggregate;
use crate::util::rng::Rng;
use crate::workload::{Dataset, PoissonArrivals, WorkloadGen};
use anyhow::Result;

/// Deterministic fault-injection layer for the sim backend: a seeded RNG
/// draws once per fault class per backend step, in a fixed order
/// (stall, spike, err), so the whole fault schedule is a pure function of
/// `(--faults, --fault-seed)` and the backend call sequence — which is
/// exactly what lets a recorded faulty run replay bit-identically.
///
/// Spec grammar (`--faults`): comma-separated `stall=P:US`, `spike=P:US`,
/// `err=P` — probabilities in [0,1], delays in virtual µs.  E.g.
/// `stall=0.05:30000,err=0.01`: 5% of steps stall 30 ms (a CPU-GPU
/// transfer hiccup), 1% fail outright.
#[derive(Debug)]
pub struct FailPoints {
    pub enabled: bool,
    /// P(transfer stall) per backend step, and its virtual-µs delay.
    pub stall_p: f64,
    pub stall_us: f64,
    /// P(step-time spike) per backend step, and its virtual-µs delay.
    pub spike_p: f64,
    pub spike_us: f64,
    /// P(backend step error) per backend step.
    pub err_p: f64,
    rng: Rng,
}

impl FailPoints {
    pub fn disabled() -> FailPoints {
        FailPoints {
            enabled: false,
            stall_p: 0.0,
            stall_us: 0.0,
            spike_p: 0.0,
            spike_us: 0.0,
            err_p: 0.0,
            rng: Rng::new(0),
        }
    }

    /// Parse a `--faults` spec.  An empty spec is the disabled layer.
    pub fn parse(spec: &str, seed: u64) -> Result<FailPoints> {
        let mut fp = FailPoints { rng: Rng::new(seed ^ 0xFA17), ..FailPoints::disabled() };
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--faults: expected key=value in {part:?}"))?;
            let parse_p = |s: &str| -> Result<f64> {
                let p: f64 = s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--faults: bad probability {s:?} in {part:?}"))?;
                anyhow::ensure!((0.0..=1.0).contains(&p), "--faults: probability {p} not in [0,1]");
                Ok(p)
            };
            match key {
                "stall" | "spike" => {
                    let (p, us) = val.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("--faults: {key} needs prob:delay_us, got {val:?}")
                    })?;
                    let p = parse_p(p)?;
                    let us: f64 = us.parse().map_err(|_| {
                        anyhow::anyhow!("--faults: bad delay {us:?} in {part:?}")
                    })?;
                    anyhow::ensure!(us >= 0.0, "--faults: negative delay in {part:?}");
                    if key == "stall" {
                        fp.stall_p = p;
                        fp.stall_us = us;
                    } else {
                        fp.spike_p = p;
                        fp.spike_us = us;
                    }
                }
                "err" => fp.err_p = parse_p(val)?,
                _ => anyhow::bail!("--faults: unknown fault class {key:?} (stall|spike|err)"),
            }
        }
        fp.enabled = fp.stall_p > 0.0 || fp.spike_p > 0.0 || fp.err_p > 0.0;
        Ok(fp)
    }
}

/// K-stream offset encoding an accepted quantized execution — far above
/// any vocab id, so a quantized and an fp execution of the same token can
/// never hash alike.
const QUANT_K_OFFSET: u32 = 1 << 20;

pub struct SimBackend {
    pub serving: ServingConfig,
    cfg: ModelConfig,
    clock: VirtualClock,
    cache: ExpertCache,
    rng: Rng,
    sink: crate::events::EventSink,
    events: crate::moe::ExpertEvents,
    faults: FailPoints,
    /// Fixed per-chunk cost (expert-base amortization lost to chunking).
    pub prefill_chunk_base_us: f64,
    pub prefill_per_token_us: f64,
    pub decode_base_us: f64,
    pub decode_per_seq_us: f64,
}

impl SimBackend {
    pub fn new(serving: ServingConfig) -> SimBackend {
        let rng = Rng::new(serving.seed ^ 0x51A4);
        let faults = match serving.faults.as_deref() {
            Some(spec) => match FailPoints::parse(spec, serving.fault_seed) {
                Ok(fp) => fp,
                Err(e) => {
                    eprintln!("warning: ignoring --faults: {e}");
                    FailPoints::disabled()
                }
            },
            None => FailPoints::disabled(),
        };
        let cfg = ModelConfig::test_tiny();
        let mut cache = ExpertCache::with_capacity(8);
        if serving.quant_tier {
            cache.enable_quant_tier(serving.quant_bits);
        }
        if serving.cache_partition == crate::config::serving::CachePartition::Layer {
            cache.partition_by_layer(cfg.n_layers);
        }
        SimBackend {
            cfg,
            clock: VirtualClock::new(),
            cache,
            rng,
            sink: crate::events::EventSink::disabled(),
            events: crate::moe::ExpertEvents::default(),
            faults,
            prefill_chunk_base_us: 2_000.0,
            prefill_per_token_us: 1_000.0,
            decode_base_us: 20_000.0,
            decode_per_seq_us: 2_000.0,
            serving,
        }
    }

    /// One fault-injection pass at a backend step boundary: always three
    /// RNG draws (stall, spike, err — fixed order) when enabled, so the
    /// draw stream stays aligned across runs regardless of which faults
    /// fire.  Stalls/spikes burn extra virtual time; an err aborts the
    /// step.
    fn apply_faults(&mut self, site: &'static str) -> Result<()> {
        if !self.faults.enabled {
            return Ok(());
        }
        let stall = self.faults.rng.f64() < self.faults.stall_p;
        let spike = self.faults.rng.f64() < self.faults.spike_p;
        let err = self.faults.rng.f64() < self.faults.err_p;
        if stall {
            self.clock.advance_us(self.faults.stall_us);
            let (t, us) = (self.clock.now_us(), self.faults.stall_us);
            self.sink.emit_with(|| crate::events::TraceEvent::FaultInjected {
                t_us: t,
                kind: format!("stall:{site}"),
                delay_us: us,
            });
        }
        if spike {
            self.clock.advance_us(self.faults.spike_us);
            let (t, us) = (self.clock.now_us(), self.faults.spike_us);
            self.sink.emit_with(|| crate::events::TraceEvent::FaultInjected {
                t_us: t,
                kind: format!("spike:{site}"),
                delay_us: us,
            });
        }
        if err {
            let t = self.clock.now_us();
            self.sink.emit_with(|| crate::events::TraceEvent::FaultInjected {
                t_us: t,
                kind: format!("err:{site}"),
                delay_us: 0.0,
            });
            anyhow::bail!("injected backend fault ({site})");
        }
        Ok(())
    }

    pub fn expert_cache(&self) -> &ExpertCache {
        &self.cache
    }

    /// Append one token to every layer of `cache`, encoding the token
    /// value into the K stream — the sim's stand-in for real numerics:
    /// any scheduler bug that skips, repeats, or reorders tokens changes
    /// every subsequent output.
    ///
    /// With `--quant-tier on`, the per-token expert access runs the
    /// three-tier plan: fp resident -> unchanged; quantized resident ->
    /// accepted against the sequence's remaining `--error-budget` (an
    /// accepted hit perturbs the K encoding, so downstream tokens can
    /// diverge exactly like real low-bit numerics would) or corrected to
    /// an fp promotion; cold -> fp demand transfer.  Tier off is the
    /// seed path, bit for bit.
    fn append_token(&mut self, cache: &mut SequenceCache, tok: u32) {
        let id = (0usize, tok as usize % self.cfg.n_experts);
        // Plan the expert access first: an accepted quantized hit changes
        // the K value appended below.
        let mut k0 = tok as f32;
        // One expert-cache access per token: gives per-request cache-stat
        // deltas real counters, and keeps the arbitration path (capacity
        // shrink/grow) exercised under load.
        if let Some(bits) = self.cache.quant_bits() {
            let now = self.clock.now_us();
            if self.cache.lookup(id, now) {
                self.events.resident += 1;
            } else {
                let err = crate::quant::synthetic_expert_error(id.0, id.1, bits);
                if self.cache.lookup_quant(id, now, err) {
                    let budget = cache.quant_budget.get_or_insert(self.serving.error_budget);
                    if *budget >= err {
                        *budget -= err;
                        self.events.quant += 1;
                        k0 = (tok + QUANT_K_OFFSET) as f32;
                    } else {
                        // Budget exhausted: schedule the fp master and run
                        // at full precision.
                        self.cache.note_quant_corrected(id, now);
                        self.cache.promote(id);
                        self.events.transferred += 1;
                    }
                } else {
                    // Cold in both tiers: fp demand transfer (its eviction
                    // victim demotes into the quantized tier).
                    self.cache.admit(id);
                    self.events.transferred += 1;
                }
            }
        } else if self.cache.fetch(id) {
            self.events.transferred += 1;
        } else {
            self.events.resident += 1;
        }
        let kvd = self.cfg.kv_dim();
        let mut k = vec![0.0f32; kvd];
        k[0] = k0;
        let v = vec![0.0f32; kvd];
        for l in &mut cache.layers {
            l.append(&k, &v);
        }
    }

    /// Deterministic next-token logits from the sequence's KV state: an
    /// FNV-1a hash over the token history picks the peak.  Rows depend
    /// only on this sequence — batching and chunking cannot change them.
    fn logits_for(&self, cache: &SequenceCache) -> Vec<f32> {
        let kvd = self.cfg.kv_dim();
        let lc = &cache.layers[0];
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for i in 0..lc.len {
            h = (h ^ lc.k[i * kvd] as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        let peak = (h % self.cfg.vocab as u64) as usize;
        let mut row = vec![0.0f32; self.cfg.vocab];
        // Distinct top-3 so beam groups have real alternatives to fork.
        row[peak] = 4.0;
        row[(peak + 1) % self.cfg.vocab] = 2.0;
        row[(peak + 2) % self.cfg.vocab] = 1.0;
        row
    }
}

impl ServeBackend for SimBackend {
    fn serving(&self) -> &ServingConfig {
        &self.serving
    }

    fn now_us(&self) -> f64 {
        self.clock.now_us()
    }

    fn advance_to_us(&mut self, t_us: f64) {
        self.clock.advance_to_us(t_us);
    }

    fn new_cache(&self) -> SequenceCache {
        SequenceCache::new(&self.cfg)
    }

    fn expert_cache_mut(&mut self) -> &mut ExpertCache {
        &mut self.cache
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats().clone()
    }

    fn prefill_chunk(
        &mut self,
        chunk: &[u32],
        cache: &mut SequenceCache,
        is_last: bool,
    ) -> Result<Option<Vec<f32>>> {
        anyhow::ensure!(!chunk.is_empty(), "empty prefill chunk");
        self.apply_faults("prefill")?;
        self.clock
            .advance_us(self.prefill_chunk_base_us + chunk.len() as f64 * self.prefill_per_token_us);
        self.cache.set_time_hint(self.clock.now_us());
        for &t in chunk {
            self.append_token(cache, t);
        }
        if is_last { Ok(Some(self.logits_for(cache))) } else { Ok(None) }
    }

    fn decode_logits(
        &mut self,
        last: &[u32],
        caches: &mut [&mut SequenceCache],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(last.len(), caches.len());
        // Single injection site for decode: `decode_sample` routes through
        // here (SimBackend keeps the default), so fused and unfused paths
        // share one draw stream.
        self.apply_faults("decode")?;
        self.clock
            .advance_us(self.decode_base_us + last.len() as f64 * self.decode_per_seq_us);
        self.cache.set_time_hint(self.clock.now_us());
        let mut rows = Vec::with_capacity(last.len());
        for (i, cache) in caches.iter_mut().enumerate() {
            self.append_token(cache, last[i]);
            rows.push(self.logits_for(&**cache));
        }
        Ok(rows)
    }

    fn sample(&mut self, logits: &[f32]) -> u32 {
        sample_token(logits, self.serving.temperature, &mut self.rng)
    }

    fn event_sink(&self) -> crate::events::EventSink {
        self.sink.clone()
    }

    fn set_event_sink(&mut self, sink: crate::events::EventSink) {
        self.cache.set_event_sink(sink.clone());
        self.sink = sink;
    }

    fn expert_events(&self) -> crate::moe::ExpertEvents {
        self.events.clone()
    }
}

/// Workload shape for [`run_open_loop`].
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub n_requests: usize,
    /// Open-loop Poisson arrival rate (requests per virtual second).
    pub rate_per_s: f64,
    pub inp: usize,
    pub out: usize,
    /// Every `long_every`-th request carries a `long_inp`-token prompt
    /// (0 = uniform workload) — the prefill interference the chunked
    /// scheduler is built to absorb.
    pub long_every: usize,
    pub long_inp: usize,
    pub seed: u64,
    /// Every `tight_every`-th request carries an ENFORCED end-to-end
    /// deadline of `tight_deadline_us` (and the same value as its
    /// admission SLO) — the tight-SLO traffic preemption exists to save.
    /// 0 = no deadline-carrying requests.
    pub tight_every: usize,
    pub tight_deadline_us: f64,
    /// Every `cancel_every`-th request is cancelled `cancel_after_us`
    /// virtual µs after its arrival (serve-loop ids equal submission
    /// index for open-loop monotone arrivals, so the driver can address
    /// them up front).  0 = no cancellations.
    pub cancel_every: usize,
    pub cancel_after_us: f64,
    /// Scripted control-plane actions: `(virtual_t_us, msg)` — reloads
    /// and drains injected mid-run.
    pub controls: Vec<(f64, ControlMsg)>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            n_requests: 100,
            rate_per_s: 6.0,
            inp: 24,
            out: 24,
            long_every: 8,
            long_inp: 320,
            seed: 11,
            tight_every: 0,
            tight_deadline_us: 0.0,
            cancel_every: 0,
            cancel_after_us: 0.0,
            controls: Vec::new(),
        }
    }
}

/// One planned open-loop request: the workload built from a [`LoadSpec`]
/// before it reaches any scheduler.  Extracted so the single-engine
/// driver ([`run_open_loop`]) and the fleet harness
/// ([`run_fleet_open_loop`]) replay the SAME workload — byte-identical
/// prompts, arrivals, deadlines, and cancel schedule — which is what the
/// `--shards 1` bit-identity property rests on.
#[derive(Clone, Debug)]
pub struct PlannedRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub arrive_at_us: f64,
    /// Carries an enforced end-to-end deadline (and the same SLO).
    pub tight: bool,
    /// Scheduled cancel time, when this request is a cancel target.
    pub cancel_at_us: Option<f64>,
}

/// Materialize the workload of a [`LoadSpec`]: same RNG streams
/// (arrivals from `seed`, prompts from `seed ^ 0x10AD`) as the original
/// inline driver, in the same draw order.
pub fn plan_workload(spec: &LoadSpec) -> Vec<PlannedRequest> {
    let mut arrivals = PoissonArrivals::new(spec.rate_per_s, spec.seed);
    let mut gen = WorkloadGen::new(Dataset::sharegpt(), 512, spec.seed ^ 0x10AD);
    (0..spec.n_requests)
        .map(|i| {
            let len = if spec.long_every > 0 && i % spec.long_every == spec.long_every - 1 {
                spec.long_inp
            } else {
                spec.inp
            };
            let prompt = gen.prompt(len);
            let t = arrivals.next_arrival_us();
            let tight = spec.tight_every > 0 && i % spec.tight_every == spec.tight_every - 1;
            let cancel_at_us =
                if spec.cancel_every > 0 && i % spec.cancel_every == spec.cancel_every - 1 {
                    Some(t + spec.cancel_after_us)
                } else {
                    None
                };
            PlannedRequest { prompt, max_new: spec.out, arrive_at_us: t, tight, cancel_at_us }
        })
        .collect()
}

/// Outcome of one open-loop run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub completed: usize,
    /// Terminal-failure outcomes of any kind (rejections, deadlines,
    /// cancellations, faults, shutdown) — `reasons` has the breakdown.
    pub rejected: usize,
    /// Failure count per typed reason label ("deadline", "cancelled",
    /// "queue_full", ...).
    pub reasons: std::collections::BTreeMap<String, usize>,
    /// Deadline-carrying requests sent / completed within their deadline.
    /// (Deadline enforcement fails a request the moment it lapses, so
    /// completion implies attainment.)
    pub slo_eligible: usize,
    pub slo_attained: usize,
    /// Total preemptions across completed requests.
    pub preemptions: usize,
    /// First arrival to last token, virtual seconds.
    pub makespan_s: f64,
    pub output_tokens: usize,
    pub agg: Aggregate,
    /// Per-request terminal outcome, indexed by submission order: the
    /// token stream (partial for failures) and the typed failure label
    /// (`None` = completed).  What the fleet bit-identity and
    /// identical-token-set properties compare.
    pub outcomes: Vec<(Vec<u32>, Option<String>)>,
}

impl LoadReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.makespan_s
    }

    /// Fraction of deadline-carrying requests that finished in time
    /// (1.0 when the workload had none).
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_eligible == 0 {
            return 1.0;
        }
        self.slo_attained as f64 / self.slo_eligible as f64
    }
}

/// Replay an open-loop Poisson workload through the lifecycle scheduler
/// on a [`SimBackend`], entirely in virtual time.  This is the
/// load-generator substrate behind `examples/load_gen.rs` and the
/// `BENCH_PR4.json` section of `benches/e2e_decode.rs`.
pub fn run_open_loop(serving: ServingConfig, spec: &LoadSpec) -> Result<LoadReport> {
    let planned = plan_workload(spec);
    let (tx, rx) = std::sync::mpsc::channel();
    let mut first_arrival_us = f64::INFINITY;
    let mut control_rx = Vec::new();
    let receivers: Vec<_> = planned
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (etx, erx) = std::sync::mpsc::channel();
            let mut r = Request::new(p.prompt.clone(), p.max_new, etx);
            first_arrival_us = first_arrival_us.min(p.arrive_at_us);
            r.arrive_at_us = Some(p.arrive_at_us);
            if p.tight {
                r.slo_us = Some(spec.tight_deadline_us);
                r.deadline_us = Some(spec.tight_deadline_us);
            }
            if let Some(cancel_at) = p.cancel_at_us {
                // Open-loop arrivals are monotone, so serve-loop ids equal
                // submission index: the cancel can be addressed up front.
                let (ctx, crx) = std::sync::mpsc::channel();
                let mut c = Request::control(ControlMsg::Cancel { req: i as u64 }, ctx);
                c.arrive_at_us = Some(cancel_at);
                tx.send(c).expect("loop not started yet");
                control_rx.push(crx);
            }
            tx.send(r).expect("loop not started yet");
            erx
        })
        .collect();
    for (t, msg) in &spec.controls {
        let (ctx, crx) = std::sync::mpsc::channel();
        let mut c = Request::control(msg.clone(), ctx);
        c.arrive_at_us = Some(*t);
        tx.send(c).expect("loop not started yet");
        control_rx.push(crx);
    }
    let mut sentinel = Request::shutdown_sentinel();
    sentinel.arrive_at_us = Some(1e15); // fires once the loop idles out
    tx.send(sentinel).expect("loop not started yet");

    let mut backend = SimBackend::new(serving);
    serve_lifecycle(&mut backend, rx)?;
    drop(tx);

    Ok(collect_report(&receivers, &planned, first_arrival_us))
}

/// Fold per-request terminal outcomes into a [`LoadReport`] (shared by
/// the single-engine and fleet drivers).
fn collect_report(
    receivers: &[std::sync::mpsc::Receiver<super::Event>],
    planned: &[PlannedRequest],
    first_arrival_us: f64,
) -> LoadReport {
    let mut report = LoadReport::default();
    for (i, rx) in receivers.iter().enumerate() {
        if planned[i].tight {
            report.slo_eligible += 1;
        }
        match collect_outcome(rx) {
            Ok(o) if o.completed() => {
                report.completed += 1;
                report.output_tokens += o.tokens.len();
                if let Some(&t) = o.metrics.token_done_us.last() {
                    report.makespan_s = report.makespan_s.max(t / 1e6);
                }
                report.preemptions += o.metrics.preemptions;
                if planned[i].tight {
                    report.slo_attained += 1;
                }
                report.agg.push(&o.metrics);
                report.outcomes.push((o.tokens, None));
            }
            Ok(o) => {
                report.rejected += 1;
                let label = o.failure.map(|(r, _)| r.label()).unwrap_or("unknown");
                *report.reasons.entry(label.to_string()).or_insert(0) += 1;
                report.outcomes.push((o.tokens, Some(label.to_string())));
            }
            Err(_) => {
                report.rejected += 1;
                *report.reasons.entry("disconnected".to_string()).or_insert(0) += 1;
                report.outcomes.push((Vec::new(), Some("disconnected".to_string())));
            }
        }
    }
    // makespan is "first arrival to last token", not "virtual epoch to
    // last token" — the empty lead-in before the first arrival is idle.
    if report.completed > 0 {
        report.makespan_s = (report.makespan_s - first_arrival_us / 1e6).max(0.0);
    }
    report
}

/// Experts the fleet harness may pin per shard — well under the sim
/// cache capacity so KV borrowing keeps unpinned slots to take.
pub const SIM_FLEET_MAX_PINS: usize = 4;
/// Per-shard GPU residency assumed by the fleet planner, matching the
/// [`SimBackend`] expert-cache capacity.
pub const SIM_FLEET_GPU_CAPACITY: usize = 8;

/// Planner demand profile at sim geometry, a pure function of the
/// workload's prompts: layer-0 counts from `tok % n_experts` (the sim
/// routes token `t` to expert `t % n_experts`), deeper layers uniform.
/// Shared by the live fleet driver and trace replay so both derive the
/// SAME sharding plan and cache-admission pins.
pub fn sim_demand_profile<'a>(
    prompts: impl IntoIterator<Item = &'a [u32]>,
) -> crate::popularity::Profile {
    let geometry = ModelConfig::test_tiny();
    let mut profile = crate::popularity::Profile::new(geometry.n_layers, geometry.n_experts);
    for prompt in prompts {
        for &t in prompt {
            profile.record(0, t as usize % geometry.n_experts, 1);
        }
    }
    for l in 1..geometry.n_layers {
        for e in 0..geometry.n_experts {
            profile.record(l, e, 1);
        }
    }
    profile
}

/// Arrival horizon (virtual seconds, floored away from zero) — the
/// admission-pricing window, derived from the arrivals themselves so the
/// recorder and the replayer agree on it.
pub fn sim_arrival_horizon_s(arrivals_us: impl IntoIterator<Item = f64>) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for t in arrivals_us {
        lo = lo.min(t);
        hi = hi.max(t);
    }
    if lo.is_finite() && hi > lo { (hi - lo) / 1e6 } else { 1.0 }
}

/// Outcome of one fleet run: the global [`LoadReport`] plus the routing
/// and planner decisions that produced it.
#[derive(Debug)]
pub struct FleetReport {
    pub report: LoadReport,
    /// Owning shard per request, indexed by submission order.
    pub shard_of: Vec<usize>,
    /// Requests assigned per shard.
    pub per_shard: Vec<usize>,
    /// Resolved partition layout ("layer" or "hash").
    pub plan: String,
    /// Comma-joined per-shard bottleneck labels from the planner.
    pub bottlenecks: String,
    /// Worst-shard priced step time (µs) from the planner.
    pub max_step_us: f64,
}

/// Replay an open-loop workload through an N-shard fleet
/// (`serving.shards`), entirely in virtual time: requests are routed up
/// front by the [`FleetRouter`] in global ingest order, then each
/// shard's lifecycle scheduler drains its queue on its own
/// [`SimBackend`] (own virtual clock — shards run concurrently in real
/// deployments, so fleet makespan is the max over shards).  Cancels go
/// to the owning shard; reloads and drains broadcast to every shard.
/// With `shards == 1` this is token-bit-identical to [`run_open_loop`].
pub fn run_fleet_open_loop(serving: ServingConfig, spec: &LoadSpec) -> Result<FleetReport> {
    use super::fleet::{pin_worthwhile, plan_shards, FleetRouter};
    use crate::latency::LatencyModel;
    use crate::prefetch::TransitionProfile;

    let n = serving.shards.max(1);
    let planned = plan_workload(spec);
    let first_arrival_us = planned.iter().map(|p| p.arrive_at_us).fold(f64::INFINITY, f64::min);

    // Shared trace sink, pre-armed on every backend (each shard's serve
    // loop sees it enabled and skips installing its own).
    let sink = match serving.events_out.as_deref() {
        Some(path) => crate::events::EventSink::to_path(path)?,
        None => crate::events::EventSink::disabled(),
    };

    let geometry = ModelConfig::test_tiny();
    let profile = sim_demand_profile(planned.iter().map(|p| p.prompt.as_slice()));
    let model = LatencyModel::from_hardware(&crate::config::HardwareConfig::env1());
    let quant_bits = serving.quant_tier.then_some(serving.quant_bits);
    let plan =
        plan_shards(&profile, &model, n, serving.shard_plan, SIM_FLEET_GPU_CAPACITY, quant_bits);
    let transitions = TransitionProfile::uniform(geometry.n_layers, geometry.n_experts);
    let mut router =
        FleetRouter::new(plan.clone(), Some(transitions), serving.replicate_hot, sink.clone());

    // Route everything up front, in submission (= global ingest) order.
    let shard_of: Vec<usize> = planned
        .iter()
        .map(|p| router.route(&p.prompt, p.max_new, p.arrive_at_us).1)
        .collect();
    let mut per_shard = vec![0usize; n];
    for &s in &shard_of {
        per_shard[s] += 1;
    }

    // Build each shard's pre-loaded channel: requests carry their global
    // id, cancels go to the owning shard, controls broadcast everywhere.
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        txs.push(tx);
        rxs.push(rx);
    }
    let mut control_rx = Vec::new();
    let receivers: Vec<_> = planned
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let shard = shard_of[i];
            let (etx, erx) = std::sync::mpsc::channel();
            let mut r = Request::new(p.prompt.clone(), p.max_new, etx);
            r.id = Some(i as u64);
            r.arrive_at_us = Some(p.arrive_at_us);
            if p.tight {
                r.slo_us = Some(spec.tight_deadline_us);
                r.deadline_us = Some(spec.tight_deadline_us);
            }
            if let Some(cancel_at) = p.cancel_at_us {
                let (ctx, crx) = std::sync::mpsc::channel();
                let mut c = Request::control(ControlMsg::Cancel { req: i as u64 }, ctx);
                c.arrive_at_us = Some(cancel_at);
                txs[shard].send(c).expect("loop not started yet");
                control_rx.push(crx);
            }
            txs[shard].send(r).expect("loop not started yet");
            erx
        })
        .collect();
    for (t, msg) in &spec.controls {
        for tx in &txs {
            let (ctx, crx) = std::sync::mpsc::channel();
            let mut c = Request::control(msg.clone(), ctx);
            c.arrive_at_us = Some(*t);
            tx.send(c).expect("loop not started yet");
            control_rx.push(crx);
        }
    }
    for tx in &txs {
        let mut sentinel = Request::shutdown_sentinel();
        sentinel.arrive_at_us = Some(1e15);
        tx.send(sentinel).expect("loop not started yet");
    }

    // Drain each shard sequentially on its own backend and clock (the
    // virtual-time analogue of N engines running in parallel).  The
    // admission horizon and per-shard rates derive from the ARRIVALS,
    // not the spec, so trace replay (which only sees arrivals) can
    // reproduce the exact same pin decisions.
    let horizon_s = sim_arrival_horizon_s(planned.iter().map(|p| p.arrive_at_us));
    for (s, rx) in rxs.into_iter().enumerate() {
        let mut backend = SimBackend::new(serving.clone());
        backend.set_event_sink(sink.clone());
        if n > 1 {
            // Batch-aware cache admission: pre-pin the shard's experts
            // whose predicted reuse at this shard's arrival rate beats
            // their transfer cost.  Capped well under the cache capacity
            // so KV borrowing keeps unpinned slots to take.
            let shard_rate = per_shard[s] as f64 / horizon_s;
            pin_worthwhile(
                backend.expert_cache_mut(),
                &profile,
                &plan,
                s,
                shard_rate,
                horizon_s,
                &model,
                SIM_FLEET_MAX_PINS,
            );
        }
        serve_lifecycle(&mut backend, rx)?;
    }
    drop(txs);

    let report = collect_report(&receivers, &planned, first_arrival_us);
    Ok(FleetReport {
        report,
        shard_of,
        per_shard,
        plan: plan.plan.label().to_string(),
        bottlenecks: plan.bottleneck_summary(),
        max_step_us: plan.max_step_us(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_depend_on_history_not_chunking() {
        let mut a = SimBackend::new(ServingConfig::default());
        let mut b = SimBackend::new(ServingConfig::default());
        let prompt: Vec<u32> = (1..=10).collect();
        let mut ca = a.new_cache();
        let mut cb = b.new_cache();
        // One monolithic chunk vs three uneven chunks.
        let ra = a.prefill_chunk(&prompt, &mut ca, true).unwrap().unwrap();
        assert!(b.prefill_chunk(&prompt[..3], &mut cb, false).unwrap().is_none());
        assert!(b.prefill_chunk(&prompt[3..4], &mut cb, false).unwrap().is_none());
        let rb = b.prefill_chunk(&prompt[4..], &mut cb, true).unwrap().unwrap();
        assert_eq!(ra, rb, "chunking changed the sim numerics");
        // ...but a different prompt changes them.
        let mut c = SimBackend::new(ServingConfig::default());
        let mut cc = c.new_cache();
        let other: Vec<u32> = (2..=11).collect();
        let rc = c.prefill_chunk(&other, &mut cc, true).unwrap().unwrap();
        assert_ne!(ra, rc);
    }

    #[test]
    fn open_loop_run_serves_everything_at_light_load() {
        let spec = LoadSpec {
            n_requests: 12,
            rate_per_s: 3.0,
            inp: 8,
            out: 6,
            long_every: 4,
            long_inp: 64,
            seed: 5,
            ..LoadSpec::default()
        };
        let report = run_open_loop(ServingConfig::default(), &spec).unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.output_tokens, 12 * 6);
        assert!(report.makespan_s > 0.0);
        assert!(report.throughput_tok_s() > 0.0);
        // Open loop: the makespan at 3 req/s over 12 requests spans at
        // least the arrival horizon (~4 s mean).
        assert!(report.makespan_s > 1.0, "arrivals not replayed in virtual time");
    }

    #[test]
    fn failpoints_parse_and_reject_junk() {
        let fp = FailPoints::parse("stall=0.05:30000,spike=0.1:5000,err=0.01", 7).unwrap();
        assert!(fp.enabled);
        assert!((fp.stall_p - 0.05).abs() < 1e-12);
        assert!((fp.stall_us - 30000.0).abs() < 1e-12);
        assert!((fp.spike_p - 0.1).abs() < 1e-12);
        assert!((fp.err_p - 0.01).abs() < 1e-12);
        assert!(!FailPoints::parse("", 7).unwrap().enabled);
        assert!(!FailPoints::parse("stall=0:1000,err=0", 7).unwrap().enabled);
        assert!(FailPoints::parse("wedge=0.5", 7).is_err());
        assert!(FailPoints::parse("err=1.5", 7).is_err());
        assert!(FailPoints::parse("stall=0.5", 7).is_err(), "stall needs a delay");
        assert!(FailPoints::parse("err", 7).is_err());
    }

    #[test]
    fn injected_faults_are_seed_deterministic() {
        let run = |fault_seed: u64| -> (usize, usize, f64) {
            let serving = ServingConfig {
                faults: Some("stall=0.2:30000,err=0.05".to_string()),
                fault_seed,
                ..ServingConfig::default()
            };
            let spec = LoadSpec { n_requests: 16, out: 8, ..LoadSpec::default() };
            let r = run_open_loop(serving, &spec).unwrap();
            (r.completed, r.rejected, r.makespan_s)
        };
        let a = run(3);
        let b = run(3);
        assert_eq!(a, b, "same fault seed must reproduce the same run");
        assert!(a.1 > 0, "5% err rate over 16 requests x 8 tokens should kill at least one");
        let c = run(1717);
        assert!(a != c || a.1 == 0, "different fault seed should reshuffle the schedule");
    }

    #[test]
    fn backend_errors_fail_requests_not_the_server() {
        // err=1: every backend step fails — every request must come back
        // with a typed backend failure, and the loop must still exit
        // cleanly (no Err bubbled out of serve_lifecycle).
        let serving = ServingConfig {
            faults: Some("err=1".to_string()),
            ..ServingConfig::default()
        };
        let spec = LoadSpec { n_requests: 4, out: 4, ..LoadSpec::default() };
        let r = run_open_loop(serving, &spec).unwrap();
        assert_eq!(r.completed, 0);
        assert_eq!(r.rejected, 4);
        assert_eq!(r.reasons.get("backend"), Some(&4));
    }

    #[test]
    fn plan_workload_is_deterministic_and_flags_requests() {
        let spec = LoadSpec {
            n_requests: 9,
            long_every: 3,
            long_inp: 64,
            inp: 8,
            tight_every: 4,
            tight_deadline_us: 5e5,
            cancel_every: 5,
            cancel_after_us: 1e4,
            ..LoadSpec::default()
        };
        let a = plan_workload(&spec);
        let b = plan_workload(&spec);
        assert_eq!(a.len(), 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrive_at_us, y.arrive_at_us);
        }
        assert_eq!(a[2].prompt.len(), 64, "every 3rd request is long");
        assert_eq!(a[0].prompt.len(), 8);
        assert!(a[3].tight && !a[0].tight);
        assert!(a[4].cancel_at_us.is_some() && a[0].cancel_at_us.is_none());
        assert!((a[4].cancel_at_us.unwrap() - a[4].arrive_at_us - 1e4).abs() < 1e-9);
    }

    #[test]
    fn fleet_single_shard_matches_single_engine_bit_for_bit() {
        let spec = LoadSpec { n_requests: 10, out: 8, ..LoadSpec::default() };
        let single = run_open_loop(ServingConfig::default(), &spec).unwrap();
        let serving = ServingConfig { shards: 1, ..ServingConfig::default() };
        let fleet = run_fleet_open_loop(serving, &spec).unwrap();
        assert_eq!(single.outcomes, fleet.report.outcomes, "shards=1 must be a pass-through");
        assert_eq!(single.completed, fleet.report.completed);
        assert!(fleet.shard_of.iter().all(|&s| s == 0));
    }

    #[test]
    fn fleet_three_shards_serves_everything_and_reports_the_plan() {
        let serving = ServingConfig { shards: 3, ..ServingConfig::default() };
        let spec = LoadSpec { n_requests: 18, out: 6, ..LoadSpec::default() };
        let fleet = run_fleet_open_loop(serving, &spec).unwrap();
        assert_eq!(fleet.report.completed, 18);
        assert_eq!(fleet.report.rejected, 0);
        assert_eq!(fleet.per_shard.iter().sum::<usize>(), 18);
        assert_eq!(fleet.per_shard.len(), 3);
        assert!(fleet.per_shard.iter().filter(|&&c| c > 0).count() >= 2, "router never spread");
        assert!(fleet.plan == "layer" || fleet.plan == "hash");
        assert_eq!(fleet.bottlenecks.split(',').count(), 3);
        assert!(fleet.max_step_us > 0.0);
    }

    #[test]
    fn quant_tier_serves_demoted_experts_from_the_low_bit_copy() {
        let serving = ServingConfig {
            quant_tier: true,
            quant_bits: 8,
            error_budget: 1.0,
            ..ServingConfig::default()
        };
        let mut s = SimBackend::new(serving);
        let mut c = s.new_cache();
        // 8 distinct experts through the halved (4-slot) fp tier: the
        // evicted half demotes to quantized copies...
        let prompt: Vec<u32> = (0..8).collect();
        s.prefill_chunk(&prompt, &mut c, false).unwrap();
        // ...and a revisit serves them from the tier under the generous
        // budget instead of re-transferring.
        s.prefill_chunk(&prompt, &mut c, true).unwrap();
        let ev = s.expert_events();
        assert!(ev.quant > 0, "no quantized hits: {ev:?}");
        assert!(ev.resident > 0, "fp tier never hit: {ev:?}");
        assert!(s.expert_cache().stats().demotions > 0);
    }

    #[test]
    fn quant_tier_zero_budget_tokens_match_fp_only() {
        let spec = LoadSpec { n_requests: 10, out: 8, ..LoadSpec::default() };
        let base = run_open_loop(ServingConfig::default(), &spec).unwrap();
        let serving = ServingConfig {
            quant_tier: true,
            quant_bits: 8,
            error_budget: 0.0,
            ..ServingConfig::default()
        };
        let tiered = run_open_loop(serving, &spec).unwrap();
        assert_eq!(
            base.outcomes, tiered.outcomes,
            "a zero error budget must correct every quantized hit to fp numerics"
        );
        // And directly on a backend: the tier is genuinely exercised —
        // every quantized hit is corrected, none accepted.
        let mut s = SimBackend::new(ServingConfig {
            quant_tier: true,
            quant_bits: 8,
            error_budget: 0.0,
            ..ServingConfig::default()
        });
        let mut c = s.new_cache();
        let prompt: Vec<u32> = (0..8).collect();
        s.prefill_chunk(&prompt, &mut c, false).unwrap();
        s.prefill_chunk(&prompt, &mut c, true).unwrap();
        assert!(s.expert_cache().stats().quant_corrected > 0, "tier never consulted");
        assert_eq!(s.expert_events().quant, 0, "zero budget accepted a hit");
    }

    #[test]
    fn decode_charges_amortized_batch_cost() {
        let mut s = SimBackend::new(ServingConfig::default());
        let mut c1 = s.new_cache();
        let mut c2 = s.new_cache();
        s.prefill_chunk(&[1], &mut c1, true).unwrap();
        s.prefill_chunk(&[2], &mut c2, true).unwrap();
        let t0 = s.now_us();
        let mut caches = [&mut c1, &mut c2];
        let rows = s.decode_logits(&[3, 4], &mut caches).unwrap();
        assert_eq!(rows.len(), 2);
        let dt = s.now_us() - t0;
        assert!((dt - (s.decode_base_us + 2.0 * s.decode_per_seq_us)).abs() < 1e-6);
    }
}
