//! Engine-agnostic scheduler core: the pieces of the request-lifecycle
//! scheduler that do not touch a backend — KV budgeting, batch-size
//! clamping, the per-request state machine ([`SequenceGroup`] /
//! [`Phase`] / [`Slot`]), admission ordering, and pending-arrival
//! parking.  Extracted from [`super::lifecycle`] so every shard of a
//! [`super::fleet`] runs one instance of the same core instead of the
//! fleet duplicating scheduler policy.

use super::{Event, FailReason, Request};
use crate::config::hardware::{MIB, PAPER_EXPERT_BYTES, PAPER_KV_BYTES_PER_TOKEN};
use crate::config::model::DECODE_BATCH_BUCKETS;
use crate::config::serving::AdmissionKind;
use crate::expertcache::{CacheStats, ExpertCache};
use crate::kvcache::SequenceCache;
use crate::metrics::GenMetrics;
use std::collections::VecDeque;

/// Decode-batch cap actually in effect: the configured `max_batch`,
/// clamped to the largest AOT decode-batch bucket (and to >= 1).  The
/// second element reports whether the config exceeded the bucket ceiling
/// (the serve loop warns once).
pub fn effective_max_batch(configured: usize) -> (usize, bool) {
    let ceiling = *DECODE_BATCH_BUCKETS.last().unwrap();
    (configured.clamp(1, ceiling), configured > ceiling)
}

/// Worst-case KV footprint of one request at paper scale: every slot of
/// the group may grow to `prompt + max_new` tokens.
pub fn kv_worst_case_bytes(prompt_tokens: usize, max_new: usize, width: usize) -> u64 {
    ((prompt_tokens + max_new) * width) as u64 * PAPER_KV_BYTES_PER_TOKEN
}

/// KV-cache memory budget, arbitrating against the expert cache.
///
/// Reservations draw from a fixed pool (`--kv-budget-mb`); when the pool
/// alone cannot cover a reservation the budget converts unpinned expert
/// slots into headroom by shrinking the [`ExpertCache`] capacity (each
/// slot is worth [`PAPER_EXPERT_BYTES`]), and returns the slots as
/// reservations release.  Pinned placement is never touched.  A pool of 0
/// disables budgeting entirely.  Each fleet shard owns its own budget —
/// per-shard KV/expert-slot arbitration, MoE-Lightning-style, rather than
/// one contended global pool.
#[derive(Debug)]
pub struct KvBudget {
    pool_bytes: u64,
    expert_bytes: u64,
    used_bytes: u64,
    borrowed_slots: usize,
}

impl KvBudget {
    pub fn new(pool_mb: usize) -> KvBudget {
        KvBudget {
            pool_bytes: pool_mb as u64 * MIB,
            expert_bytes: PAPER_EXPERT_BYTES,
            used_bytes: 0,
            borrowed_slots: 0,
        }
    }

    pub fn unlimited(&self) -> bool {
        self.pool_bytes == 0
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn borrowed_slots(&self) -> usize {
        self.borrowed_slots
    }

    /// Pool plus everything currently borrowed from the expert cache.
    fn ceiling(&self) -> u64 {
        self.pool_bytes + self.borrowed_slots as u64 * self.expert_bytes
    }

    /// Could `bytes` EVER be reserved — against the empty pool plus every
    /// borrowable expert slot (slots currently lent out will return as
    /// reservations drain, so they count)?  `false` means "reject";
    /// anything else merely waits in the queue for `try_reserve`.
    pub fn ever_feasible(&self, bytes: u64, cache: &ExpertCache) -> bool {
        if self.unlimited() {
            return true;
        }
        let unpinned =
            cache.capacity().saturating_sub(cache.pinned_count()) + self.borrowed_slots;
        bytes <= self.pool_bytes + unpinned as u64 * self.expert_bytes
    }

    /// Can `bytes` be covered *right now*, given current usage and the
    /// cache's currently borrowable slots?
    pub fn feasible(&self, bytes: u64, cache: &ExpertCache) -> bool {
        if self.unlimited() {
            return true;
        }
        let borrowable =
            cache.capacity().saturating_sub(cache.pinned_count()) as u64 * self.expert_bytes;
        self.used_bytes + bytes <= self.ceiling() + borrowable
    }

    /// Reserve `bytes`, shrinking `cache` one expert slot at a time when
    /// the pool runs short.  Returns `false` — with no state changed —
    /// when the reservation cannot be covered right now.
    pub fn try_reserve(&mut self, bytes: u64, cache: &mut ExpertCache) -> bool {
        if self.unlimited() {
            return true;
        }
        if !self.feasible(bytes, cache) {
            return false;
        }
        while self.used_bytes + bytes > self.ceiling() {
            debug_assert!(cache.capacity() > cache.pinned_count());
            cache.set_capacity(cache.capacity() - 1);
            self.borrowed_slots += 1;
        }
        self.used_bytes += bytes;
        true
    }

    /// Release a reservation, returning borrowed expert slots to the cache
    /// as whole slots' worth of headroom free up.
    pub fn release(&mut self, bytes: u64, cache: &mut ExpertCache) {
        if self.unlimited() {
            return;
        }
        self.used_bytes = self.used_bytes.saturating_sub(bytes);
        while self.borrowed_slots > 0 && self.used_bytes + self.expert_bytes <= self.ceiling() {
            cache.set_capacity(cache.capacity() + 1);
            self.borrowed_slots -= 1;
        }
    }

    /// Hot-reload the pool size (`Reload{kv_budget_mb}`), rebalancing the
    /// expert-cache borrow: a grown pool returns borrowed slots, a shrunk
    /// pool borrows unpinned slots to keep covering current reservations.
    /// A shrink that cannot be covered leaves the budget transiently
    /// overcommitted — no new reservation fits until enough in-flight
    /// requests release.  Going unlimited (0) returns every borrowed slot
    /// and stops tracking; the reverse transition starts tracking from
    /// zero (in-flight reservations made under the unlimited regime
    /// release as no-ops via `saturating_sub`).
    pub fn set_pool_mb(&mut self, pool_mb: usize, cache: &mut ExpertCache) {
        self.pool_bytes = pool_mb as u64 * MIB;
        if self.unlimited() {
            while self.borrowed_slots > 0 {
                cache.set_capacity(cache.capacity() + 1);
                self.borrowed_slots -= 1;
            }
            self.used_bytes = 0;
            return;
        }
        while self.borrowed_slots > 0 && self.used_bytes + self.expert_bytes <= self.ceiling() {
            cache.set_capacity(cache.capacity() + 1);
            self.borrowed_slots -= 1;
        }
        while self.used_bytes > self.ceiling() && cache.capacity() > cache.pinned_count() {
            cache.set_capacity(cache.capacity() - 1);
            self.borrowed_slots += 1;
        }
    }
}

/// One decoding slot of a sequence group: a beam, or the single lane of
/// an ordinary request.
pub struct Slot {
    pub cache: SequenceCache,
    pub last: u32,
    pub tokens: Vec<u32>,
    pub score: f32,
}

/// Lifecycle phase of a group.  `Queued` groups live in the scheduler's
/// queue (admission swaps in `Prefilling` with a real KV cache); terminal
/// groups are retired immediately, so no variant exists for them.
pub enum Phase {
    Queued,
    Prefilling { cursor: usize, cache: SequenceCache },
    Decoding { slots: Vec<Slot> },
}

/// One request moving through the lifecycle: an ordinary generation
/// (`width == 1`) or a beam group (`width > 1`) — same machinery.
pub struct SequenceGroup {
    /// Serve-loop-scoped request id — the `req` field correlating this
    /// group's trace events.  Single engine: ingest order from 0; fleet:
    /// assigned by the router at global ingest (see
    /// [`Request::id`](super::Request::id)).
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub width: usize,
    pub stream: std::sync::mpsc::Sender<Event>,
    pub metrics: GenMetrics,
    /// Absolute virtual TTFT deadline (admission `slo` mode orders by it).
    pub deadline_us: f64,
    /// Absolute *enforced* end-to-end deadline, when the request carried
    /// `deadline_ms` on the wire: past this instant the scheduler fails
    /// the request with [`FailReason::Deadline`] at the next chunk
    /// boundary.  `None` = never expire (the SLO deadline above only
    /// orders admission).
    pub hard_deadline_us: Option<f64>,
    /// Times this group has been preempted (KV dropped, requeued).
    pub preemptions: usize,
    /// Prompt plus already-generated tokens, set at preemption: the
    /// readmitted group recomputes its KV by prefilling this prefix
    /// (drop-and-recompute, Sarathi-style) and resumes decoding at token
    /// index `produced`.
    pub resume_prefix: Option<Vec<u32>>,
    /// Paper-scale KV bytes reserved for this group at admission.
    pub kv_reserved: u64,
    /// Cumulative cache counters at admission; completion stamps the delta.
    pub cache_base: CacheStats,
    /// Cumulative expert-execution counters at admission (same delta
    /// stamping as `cache_base`).
    pub events_base: crate::moe::ExpertEvents,
    pub produced: usize,
    pub phase: Phase,
}

impl SequenceGroup {
    /// Batch slots this group occupies (or will occupy once its prefill
    /// completes — a beam group reserves its full width up front).
    pub fn slot_count(&self) -> usize {
        match &self.phase {
            Phase::Queued | Phase::Prefilling { .. } => self.width,
            Phase::Decoding { slots } => slots.len(),
        }
    }

    /// The token prefix prefill must process: the original prompt, or —
    /// after a preemption — prompt plus everything already generated.
    pub fn prefill_prefix(&self) -> &[u32] {
        self.resume_prefix.as_deref().unwrap_or(&self.prompt)
    }

    /// Terminal failure: stamp the typed reason into the metrics and send
    /// the typed terminal event (receivers never hang).
    pub fn fail(self, reason: FailReason, msg: &str) {
        let mut metrics = self.metrics;
        metrics.fail_reason = Some(reason.label().to_string());
        metrics.preemptions = self.preemptions;
        let _ = self.stream.send(Event::Failed {
            reason,
            message: msg.to_string(),
            metrics,
        });
    }
}

/// Queue indices in the order the [`AdmissionKind`] would admit them;
/// ties resolve to the earliest arrival (queue order — the sorts are
/// stable).  The serve loop admits the FIRST candidate that fits the
/// batch and the KV budget, so a wide beam group (or a KV-hungry prompt)
/// at the head never starves narrow requests behind it (backfill).
pub fn admission_order(queue: &VecDeque<SequenceGroup>, kind: AdmissionKind) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..queue.len()).collect();
    match kind {
        AdmissionKind::Fcfs => {}
        AdmissionKind::ShortestFirst => idx.sort_by_key(|&i| queue[i].prompt.len()),
        AdmissionKind::Deadline => {
            idx.sort_by(|&a, &b| queue[a].deadline_us.total_cmp(&queue[b].deadline_us))
        }
    }
    idx
}

/// Park a future-dated request in `pending`, keeping it sorted ascending
/// by arrival time (stable for ties — earlier sends first).
pub fn park_pending(r: Request, pending: &mut Vec<Request>) {
    let t = r.arrive_at_us.unwrap_or(0.0);
    let at =
        pending.iter().position(|p| p.arrive_at_us.unwrap_or(0.0) > t).unwrap_or(pending.len());
    pending.insert(at, r);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_max_batch_clamps_to_bucket_ceiling() {
        let ceiling = *DECODE_BATCH_BUCKETS.last().unwrap();
        assert_eq!(effective_max_batch(4), (4, false));
        assert_eq!(effective_max_batch(ceiling), (ceiling, false));
        assert_eq!(effective_max_batch(ceiling + 10), (ceiling, true));
        assert_eq!(effective_max_batch(0), (1, false));
    }

    #[test]
    fn kv_worst_case_scales_with_width() {
        let one = kv_worst_case_bytes(10, 6, 1);
        assert_eq!(one, 16 * PAPER_KV_BYTES_PER_TOKEN);
        assert_eq!(kv_worst_case_bytes(10, 6, 4), 4 * one);
    }

    #[test]
    fn kv_budget_zero_is_unlimited() {
        let mut kv = KvBudget::new(0);
        let mut cache = ExpertCache::with_capacity(2);
        assert!(kv.try_reserve(u64::MAX, &mut cache));
        assert_eq!(kv.used_bytes(), 0, "unlimited budget tracks nothing");
        kv.release(u64::MAX, &mut cache);
        assert_eq!(cache.capacity(), 2);
    }

    #[test]
    fn kv_budget_reserves_and_releases() {
        let mut kv = KvBudget::new(1); // 1 MiB pool
        let mut cache = ExpertCache::with_capacity(4);
        assert!(kv.try_reserve(MIB / 2, &mut cache));
        assert!(kv.try_reserve(MIB / 2, &mut cache));
        assert_eq!(kv.used_bytes(), MIB);
        assert_eq!(kv.borrowed_slots(), 0);
        kv.release(MIB / 2, &mut cache);
        assert_eq!(kv.used_bytes(), MIB / 2);
    }

    #[test]
    fn kv_budget_borrows_expert_slots_and_returns_them() {
        let mut kv = KvBudget::new(1);
        let mut cache = ExpertCache::with_capacity(4);
        cache.pin((0, 0));
        // Needs ~1 expert slot beyond the pool.
        let big = MIB + PAPER_EXPERT_BYTES / 2;
        assert!(kv.try_reserve(big, &mut cache));
        assert_eq!(kv.borrowed_slots(), 1);
        assert_eq!(cache.capacity(), 3, "one unpinned slot converted to KV headroom");
        // Release: the slot comes back.
        kv.release(big, &mut cache);
        assert_eq!(kv.borrowed_slots(), 0);
        assert_eq!(cache.capacity(), 4);
    }

    #[test]
    fn kv_budget_transiently_full_pool_queues_instead_of_rejecting() {
        // Regression: a request that fits the EMPTY pool must not be
        // rejected just because another request currently holds it.
        let mut kv = KvBudget::new(1);
        let mut cache = ExpertCache::with_capacity(2);
        cache.pin((0, 0));
        cache.pin((0, 1)); // nothing borrowable
        assert!(kv.try_reserve(MIB - MIB / 4, &mut cache));
        let b = MIB / 2;
        assert!(kv.ever_feasible(b, &cache), "fits the empty pool: must queue");
        assert!(!kv.feasible(b, &cache), "but not right now");
        kv.release(MIB - MIB / 4, &mut cache);
        assert!(kv.try_reserve(b, &mut cache));
        // Slots currently lent out still count toward "ever".
        let mut kv2 = KvBudget::new(1);
        let mut cache2 = ExpertCache::with_capacity(1);
        assert!(kv2.try_reserve(MIB + PAPER_EXPERT_BYTES / 2, &mut cache2));
        assert_eq!(kv2.borrowed_slots(), 1);
        assert!(kv2.ever_feasible(MIB + PAPER_EXPERT_BYTES / 2, &cache2));
    }

    #[test]
    fn kv_budget_infeasible_is_rejected_without_side_effects() {
        let mut kv = KvBudget::new(1);
        let mut cache = ExpertCache::with_capacity(2);
        cache.pin((0, 0));
        cache.pin((0, 1)); // nothing borrowable
        let big = MIB + 3 * PAPER_EXPERT_BYTES;
        assert!(!kv.feasible(big, &cache));
        assert!(!kv.try_reserve(big, &mut cache));
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(cache.capacity(), 2, "failed reservation must not shrink the cache");
    }

    fn queued(prompt_len: usize, deadline_us: f64) -> SequenceGroup {
        let (tx, _rx) = std::sync::mpsc::channel();
        SequenceGroup {
            id: 0,
            prompt: vec![1; prompt_len],
            max_new: 1,
            width: 1,
            stream: tx,
            metrics: GenMetrics::default(),
            deadline_us,
            hard_deadline_us: None,
            preemptions: 0,
            resume_prefix: None,
            kv_reserved: 0,
            cache_base: CacheStats::default(),
            events_base: crate::moe::ExpertEvents::default(),
            produced: 0,
            phase: Phase::Queued,
        }
    }

    #[test]
    fn kv_budget_pool_reload_rebalances_borrow() {
        // Shrink under load: borrows unpinned slots to keep covering the
        // in-flight reservation.
        let mut kv = KvBudget::new(2);
        let mut cache = ExpertCache::with_capacity(4);
        cache.pin((0, 0));
        assert!(kv.try_reserve(2 * MIB, &mut cache));
        assert_eq!(kv.borrowed_slots(), 0);
        kv.set_pool_mb(1, &mut cache);
        assert!(kv.borrowed_slots() >= 1, "shrunk pool must borrow to cover usage");
        assert!(kv.used_bytes() <= kv.ceiling());
        // Grow back: the borrow returns.
        kv.set_pool_mb(2, &mut cache);
        assert_eq!(kv.borrowed_slots(), 0);
        assert_eq!(cache.capacity(), 4);
        // Going unlimited returns everything and stops tracking.
        assert!(kv.try_reserve(MIB + PAPER_EXPERT_BYTES / 2, &mut cache));
        kv.set_pool_mb(0, &mut cache);
        assert!(kv.unlimited());
        assert_eq!(kv.borrowed_slots(), 0);
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(cache.capacity(), 4);
    }

    #[test]
    fn kv_budget_unsatisfiable_shrink_overcommits_transiently() {
        let mut kv = KvBudget::new(4);
        let mut cache = ExpertCache::with_capacity(1);
        cache.pin((0, 0)); // nothing borrowable
        assert!(kv.try_reserve(4 * MIB, &mut cache));
        kv.set_pool_mb(1, &mut cache);
        // Cannot cover: overcommitted, so nothing new fits ...
        assert!(kv.used_bytes() > kv.ceiling());
        assert!(!kv.try_reserve(1, &mut cache));
        // ... until the in-flight reservation releases.
        kv.release(4 * MIB, &mut cache);
        assert!(kv.try_reserve(MIB / 2, &mut cache));
    }

    #[test]
    fn admission_order_per_policy() {
        let mut q = VecDeque::new();
        q.push_back(queued(100, 900.0));
        q.push_back(queued(4, 500.0));
        q.push_back(queued(4, 700.0));
        assert_eq!(admission_order(&q, AdmissionKind::Fcfs), vec![0, 1, 2]);
        // Shortest prompt; ties resolve to the earlier arrival.
        assert_eq!(admission_order(&q, AdmissionKind::ShortestFirst), vec![1, 2, 0]);
        assert_eq!(admission_order(&q, AdmissionKind::Deadline), vec![1, 2, 0]);
        q[1].deadline_us = 1_000.0;
        assert_eq!(admission_order(&q, AdmissionKind::Deadline), vec![2, 0, 1]);
        assert!(admission_order(&VecDeque::new(), AdmissionKind::Fcfs).is_empty());
    }
}
