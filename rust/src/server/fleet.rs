//! Expert-sharded multi-engine fleet (`--shards N`).
//!
//! One box runs Fiddler's Algorithm 1; a fleet runs N of them behind a
//! front-end router that owns GLOBAL ingest order and dispatches each
//! request to the engine predicted to already hold its experts:
//!
//! ```text
//!                        ┌────────────┐     shard 0: serve_lifecycle
//!   clients ──requests──▶│ FleetRouter│────▶ (own KvBudget, ExpertCache)
//!                        │  ids, plan,│     shard 1: serve_lifecycle
//!                        │  load acct │────▶   ...
//!                        └────────────┘     shard N-1
//!                          ▲        │
//!                   popularity   ShardAssigned / ReplicaScaled /
//!                   + chains     PlanChosen trace events
//! ```
//!
//! * **Sharding planner** ([`plan_shards`]): partitions the expert set
//!   per-layer (`layer`: layer `l` owned by shard `l % N`) or by hash
//!   (`hash`: FNV over `(layer, expert)`), pricing each candidate layout
//!   against a MoE-Lens-style bottleneck model — per shard, resident
//!   demand runs on the GPU, missed demand runs on whichever of the CPU
//!   path or the PCIe weight-copy path is cheaper, and the shard's step
//!   time is the max of the overlapped streams.  `auto` picks the layout
//!   with the lower worst-shard step time.
//! * **Router** ([`FleetRouter`]): predicts a request's expert demand
//!   from its prompt (layer-0 histogram propagated through the
//!   [`TransitionProfile`] chain) and scores each shard by owned demand
//!   mass minus a load-balance term; ids are assigned at the router so
//!   trace `req` fields reflect global ingest order on every shard.
//! * **Replica scaling**: the router accounts observed demand in a
//!   [`Profile`] and replicates any expert whose share exceeds
//!   `--replicate-hot F` onto `ceil(share/F)` shards
//!   ([`Profile::replica_counts`]), emitting `replica_scaled` as counts
//!   grow — a hot expert stops funneling every request to one engine.
//! * **Batch-aware admission** ([`worth_admitting`]): an expert is worth
//!   a pinned GPU slot on a shard only when its predicted reuse at that
//!   shard's arrival rate beats the PCIe transfer it saves.
//!
//! With `--shards 1` the router degenerates to a pass-through (every
//! request to shard 0, ids in arrival order) and the fleet is
//! token-bit-identical to the single-engine scheduler — property-tested
//! in `tests/fleet.rs`.

use super::{ControlMsg, Event, Request, ServeBackend};
use crate::config::serving::ShardPlan;
use crate::events::{EventSink, TraceEvent};
use crate::expertcache::ExpertCache;
use crate::latency::LatencyModel;
use crate::popularity::Profile;
use crate::prefetch::TransitionProfile;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// FNV-1a over `(layer, expert)` — the hash partition's shard pick.
fn expert_hash(layer: usize, expert: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in (layer as u64).to_le_bytes().into_iter().chain((expert as u64).to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Home shard of `(layer, expert)` under a RESOLVED partition (`auto`
/// must be resolved by [`plan_shards`] first).
pub fn shard_of_expert(plan: ShardPlan, layer: usize, expert: usize, n_shards: usize) -> usize {
    match plan {
        ShardPlan::Layer => layer % n_shards.max(1),
        ShardPlan::Hash => (expert_hash(layer, expert) % n_shards.max(1) as u64) as usize,
        ShardPlan::Auto => unreachable!("auto must be resolved by plan_shards"),
    }
}

/// One shard's priced step-time decomposition (µs per unit of demand
/// mass, MoE-Lens style): resident demand on the GPU, quantized-resident
/// demand on the GPU at the dequant-overhead rate (`--quant-tier on`),
/// missed demand on the cheaper of the CPU path and the PCIe weight-copy
/// path.
#[derive(Clone, Debug)]
pub struct ShardCost {
    pub gpu_us: f64,
    /// GPU time of demand served from the low-bit tier (0 with tier off).
    pub quant_us: f64,
    pub cpu_us: f64,
    pub pcie_us: f64,
}

impl ShardCost {
    /// Step time of the shard: the GPU stream (fp + quantized executions)
    /// overlaps the miss stream (Fiddler's orchestration), and misses take
    /// the cheaper path.
    pub fn step_us(&self) -> f64 {
        (self.gpu_us + self.quant_us).max(self.cpu_us.min(self.pcie_us))
    }

    /// Which resource saturates first: `gpu`, `cpu-bw`, or `pcie`.
    pub fn bottleneck(&self) -> &'static str {
        let miss = self.cpu_us.min(self.pcie_us);
        if self.gpu_us + self.quant_us >= miss {
            "gpu"
        } else if self.cpu_us <= self.pcie_us {
            "cpu-bw"
        } else {
            "pcie"
        }
    }
}

/// A priced expert partition: the resolved layout plus each shard's
/// bottleneck decomposition.
#[derive(Clone, Debug)]
pub struct ShardingPlan {
    /// Resolved partition — `Layer` or `Hash`, never `Auto`.
    pub plan: ShardPlan,
    pub n_shards: usize,
    pub costs: Vec<ShardCost>,
}

impl ShardingPlan {
    pub fn shard_of(&self, layer: usize, expert: usize) -> usize {
        shard_of_expert(self.plan, layer, expert, self.n_shards)
    }

    /// Worst shard's step time — the fleet's throughput bound.
    pub fn max_step_us(&self) -> f64 {
        self.costs.iter().map(|c| c.step_us()).fold(0.0, f64::max)
    }

    /// Comma-joined per-shard bottleneck labels (the `plan_chosen`
    /// event's `bottleneck` field), e.g. `"cpu-bw,pcie,gpu"`.
    pub fn bottleneck_summary(&self) -> String {
        self.costs.iter().map(|c| c.bottleneck()).collect::<Vec<_>>().join(",")
    }
}

/// Price one candidate partition: each shard's owned demand mass is
/// normalized to 1; the most popular owned experts up to
/// `gpu_capacity_per_shard` are resident (GPU), the rest miss.  With
/// `quant_bits = Some(b)` the shard's HBM is split like
/// [`ExpertCache::enable_quant_tier`] — half the slots hold fp masters,
/// the freed half holds `16/b` low-bit copies each, so the next most
/// popular experts serve on the GPU at the dequant-overhead rate instead
/// of missing.
fn price_plan(
    plan: ShardPlan,
    profile: &Profile,
    model: &LatencyModel,
    n_shards: usize,
    gpu_capacity_per_shard: usize,
    quant_bits: Option<u32>,
) -> ShardingPlan {
    let mut owned: Vec<Vec<(u64, usize, usize)>> = vec![Vec::new(); n_shards];
    for l in 0..profile.n_layers {
        for e in 0..profile.n_experts {
            let s = shard_of_expert(plan, l, e, n_shards);
            owned[s].push((profile.counts[l][e], l, e));
        }
    }
    let (fp_cap, quant_cap) = match quant_bits {
        Some(bits) => {
            let fp = (gpu_capacity_per_shard / 2).max(1).min(gpu_capacity_per_shard);
            (fp, (gpu_capacity_per_shard - fp) * 16 / bits.clamp(2, 16) as usize)
        }
        None => (gpu_capacity_per_shard, 0),
    };
    let costs = owned
        .into_iter()
        .map(|mut experts| {
            // Most popular first; ties by (layer, expert) for determinism.
            experts.sort_by_key(|&(c, l, e)| (std::cmp::Reverse(c), l, e));
            let total: u64 = experts.iter().map(|&(c, _, _)| c).sum();
            let resident: u64 = experts.iter().take(fp_cap).map(|&(c, _, _)| c).sum();
            let quant: u64 =
                experts.iter().skip(fp_cap).take(quant_cap).map(|&(c, _, _)| c).sum();
            let (hit_mass, quant_mass, miss_mass) = if total == 0 {
                // No demand signal: assume uniform residency coverage.
                let n = experts.len().max(1);
                let h = fp_cap.min(experts.len()) as f64 / n as f64;
                let q = quant_cap.min(experts.len().saturating_sub(fp_cap)) as f64 / n as f64;
                (h, q, (1.0 - h - q).max(0.0))
            } else {
                let h = resident as f64 / total as f64;
                let q = quant as f64 / total as f64;
                (h, q, (1.0 - h - q).max(0.0))
            };
            ShardCost {
                gpu_us: hit_mass * model.gpu_lat(1),
                quant_us: quant_mass * model.quant_gpu_lat(1),
                cpu_us: miss_mass * model.cpu_lat(1),
                pcie_us: miss_mass * (model.transfer_lat() + model.gpu_lat(1)),
            }
        })
        .collect();
    ShardingPlan { plan, n_shards, costs }
}

/// Choose and price the expert partition for an `n_shards` fleet.
/// `requested = auto` prices both layouts and keeps the one with the
/// lower worst-shard step time (ties prefer `layer` — contiguous layers
/// keep chain prediction within one shard).  `quant_bits` mirrors
/// `--quant-tier on --quant-bits B` (`None` = fp-only shards).
pub fn plan_shards(
    profile: &Profile,
    model: &LatencyModel,
    n_shards: usize,
    requested: ShardPlan,
    gpu_capacity_per_shard: usize,
    quant_bits: Option<u32>,
) -> ShardingPlan {
    let n_shards = n_shards.max(1);
    match requested {
        ShardPlan::Layer | ShardPlan::Hash => price_plan(
            requested,
            profile,
            model,
            n_shards,
            gpu_capacity_per_shard,
            quant_bits,
        ),
        ShardPlan::Auto => {
            let cap = gpu_capacity_per_shard;
            let layer = price_plan(ShardPlan::Layer, profile, model, n_shards, cap, quant_bits);
            let hash = price_plan(ShardPlan::Hash, profile, model, n_shards, cap, quant_bits);
            if hash.max_step_us() < layer.max_step_us() {
                hash
            } else {
                layer
            }
        }
    }
}

/// Batch-aware cache admission: is `share` (an expert's fraction of the
/// shard's routed demand) worth a pinned GPU slot at this shard's
/// `arrival_rate_per_s`?  Expected uses over the planning horizon save
/// `cpu_lat(1) - gpu_lat(1)` each; admission costs one PCIe transfer.
pub fn worth_admitting(
    share: f64,
    arrival_rate_per_s: f64,
    horizon_s: f64,
    model: &LatencyModel,
) -> bool {
    let expected_uses = share * arrival_rate_per_s * horizon_s;
    expected_uses * (model.cpu_lat(1) - model.gpu_lat(1)) > model.transfer_lat()
}

/// Pre-pin the shard's worthwhile experts (most popular owned first)
/// into its [`ExpertCache`], stopping at `max_pins`, at capacity, or at
/// the first expert whose reuse no longer pays for its transfer.
/// Returns the pinned ids.
#[allow(clippy::too_many_arguments)]
pub fn pin_worthwhile(
    cache: &mut ExpertCache,
    profile: &Profile,
    plan: &ShardingPlan,
    shard: usize,
    arrival_rate_per_s: f64,
    horizon_s: f64,
    model: &LatencyModel,
    max_pins: usize,
) -> Vec<(usize, usize)> {
    let total = profile.total();
    let mut pinned = Vec::new();
    if total == 0 {
        return pinned;
    }
    for (l, e) in profile.ranked() {
        if pinned.len() >= max_pins || cache.pinned_count() >= cache.capacity() {
            break;
        }
        if plan.shard_of(l, e) != shard || cache.is_pinned((l, e)) {
            continue;
        }
        let share = profile.counts[l][e] as f64 / total as f64;
        if !worth_admitting(share, arrival_rate_per_s, horizon_s, model) {
            break; // ranked order: nothing less popular is worth it either
        }
        cache.pin((l, e));
        pinned.push((l, e));
    }
    pinned
}

/// Front-end router: owns global ingest ids, per-shard load accounting,
/// demand-profile accumulation, and replica scaling.  Deterministic —
/// the same request sequence always produces the same assignment, which
/// is what makes the fleet replayable and property-testable.
pub struct FleetRouter {
    plan: ShardingPlan,
    transitions: Option<TransitionProfile>,
    /// Online demand accounting (layer-0 histogram propagated per layer).
    demand: Profile,
    replicate_hot: f64,
    /// Current replica count per (layer, expert); grows monotonically.
    replicas: Vec<Vec<usize>>,
    /// Outstanding assigned work (prompt + max_new tokens) per shard.
    load_tokens: Vec<u64>,
    /// Owning shard of every routed request id (cancel routing).
    assigned: HashMap<u64, usize>,
    next_id: u64,
    sink: EventSink,
}

impl FleetRouter {
    pub fn new(
        plan: ShardingPlan,
        transitions: Option<TransitionProfile>,
        replicate_hot: f64,
        sink: EventSink,
    ) -> FleetRouter {
        let (n_layers, n_experts) = match &transitions {
            Some(t) => (t.n_layers, t.n_experts),
            None => (1, 8),
        };
        let n_shards = plan.n_shards;
        let (plan_label, bottleneck) = (plan.plan.label().to_string(), plan.bottleneck_summary());
        sink.emit_with(|| TraceEvent::PlanChosen {
            t_us: 0.0,
            plan: plan_label.clone(),
            shards: n_shards,
            bottleneck: bottleneck.clone(),
        });
        FleetRouter {
            plan,
            transitions,
            demand: Profile::new(n_layers, n_experts),
            replicate_hot,
            replicas: vec![vec![1; n_experts]; n_layers],
            load_tokens: vec![0; n_shards],
            assigned: HashMap::new(),
            next_id: 0,
            sink,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards
    }

    pub fn plan(&self) -> &ShardingPlan {
        &self.plan
    }

    /// Shards holding a replica of `(layer, expert)`: the home shard and
    /// the next `replicas - 1` shards, wrapping.
    fn replica_shards(&self, layer: usize, expert: usize) -> impl Iterator<Item = usize> + '_ {
        let base = self.plan.shard_of(layer, expert);
        let n = self.plan.n_shards;
        let k = self.replicas[layer][expert].min(n);
        (0..k).map(move |j| (base + j) % n)
    }

    /// Per-layer demand mass predicted for a prompt: layer-0 histogram of
    /// `token % n_experts` (the routing signal available before any
    /// forward pass), propagated layer-to-layer through the transition
    /// chains when available, uniform otherwise.
    fn predicted_demand(&self, prompt: &[u32]) -> Vec<Vec<f64>> {
        let (n_layers, n_experts) = (self.demand.n_layers, self.demand.n_experts);
        let mut first = vec![0.0; n_experts];
        for &t in prompt {
            first[t as usize % n_experts] += 1.0;
        }
        let total: f64 = first.iter().sum();
        if total > 0.0 {
            for m in first.iter_mut() {
                *m /= total;
            }
        } else {
            first.fill(1.0 / n_experts as f64);
        }
        let mut layers = Vec::with_capacity(n_layers);
        layers.push(first);
        for l in 1..n_layers {
            let next = match &self.transitions {
                Some(t) if l < t.n_layers => {
                    let mut m = t.propagate_mass(l - 1, layers.last().unwrap());
                    let s: f64 = m.iter().sum();
                    if s > 0.0 {
                        for x in m.iter_mut() {
                            *x /= s;
                        }
                    }
                    m
                }
                _ => vec![1.0 / n_experts as f64; n_experts],
            };
            layers.push(next);
        }
        layers
    }

    /// Grow replica counts to match measured popularity, emitting
    /// `replica_scaled` for every increase.
    fn rescale_replicas(&mut self, t_us: f64) {
        if self.replicate_hot <= 0.0 || self.plan.n_shards < 2 {
            return;
        }
        let want = self.demand.replica_counts(self.replicate_hot, self.plan.n_shards);
        for l in 0..self.demand.n_layers {
            for e in 0..self.demand.n_experts {
                if want[l][e] > self.replicas[l][e] {
                    self.replicas[l][e] = want[l][e];
                    let n = want[l][e];
                    self.sink.emit_with(|| TraceEvent::ReplicaScaled {
                        t_us,
                        layer: l,
                        expert: e,
                        replicas: n,
                    });
                }
            }
        }
    }

    /// Route one request: assign the next global id, pick the shard with
    /// the most owned predicted-demand mass (minus a load-balance term),
    /// account the demand, and emit `shard_assigned`.
    pub fn route(&mut self, prompt: &[u32], max_new: usize, t_us: f64) -> (u64, usize) {
        let id = self.next_id;
        self.next_id += 1;
        let shard = if self.plan.n_shards == 1 {
            0
        } else {
            let demand = self.predicted_demand(prompt);
            // Affinity normalized to a unit of total demand mass so the
            // load-balance term below is on the same scale.
            let norm = demand.len().max(1) as f64;
            let mut affinity = vec![0.0f64; self.plan.n_shards];
            for (l, layer_mass) in demand.iter().enumerate() {
                for (e, &m) in layer_mass.iter().enumerate() {
                    if m == 0.0 {
                        continue;
                    }
                    // A replicated expert serves its mass from any holder.
                    let k = self.replicas[l][e].min(self.plan.n_shards) as f64;
                    for s in self.replica_shards(l, e) {
                        affinity[s] += m / (k * norm);
                    }
                }
            }
            // Demand accounting feeds replica scaling (layer-0 signal is
            // the measured one; deeper layers are model-predicted).
            for (l, layer_mass) in demand.iter().enumerate() {
                for (e, &m) in layer_mass.iter().enumerate() {
                    let tokens = (m * prompt.len().max(1) as f64).round() as u64;
                    if tokens > 0 {
                        self.demand.record(l, e, tokens);
                    }
                }
            }
            self.rescale_replicas(t_us);
            let total_load: u64 = self.load_tokens.iter().sum();
            let score = |s: usize| {
                let balance = if total_load == 0 {
                    0.0
                } else {
                    0.5 * self.load_tokens[s] as f64 / total_load as f64
                };
                affinity[s] - balance
            };
            (0..self.plan.n_shards)
                .max_by(|&a, &b| {
                    score(a)
                        .total_cmp(&score(b))
                        // Ties: less loaded shard, then lower index.
                        .then(self.load_tokens[b].cmp(&self.load_tokens[a]))
                        .then(b.cmp(&a))
                })
                .unwrap_or(0)
        };
        self.load_tokens[shard] += (prompt.len() + max_new) as u64;
        self.assigned.insert(id, shard);
        self.sink.emit_with(|| TraceEvent::ShardAssigned { req: id, t_us, shard });
        (id, shard)
    }

    /// Owning shard of a routed request (cancel routing).
    pub fn shard_of_request(&self, id: u64) -> Option<usize> {
        self.assigned.get(&id).copied()
    }

    /// Mark a request finished: its outstanding load leaves the balance
    /// accounting (the id stays known for late cancels, which no-op).
    pub fn complete(&mut self, id: u64, prompt_len: usize, max_new: usize) {
        if let Some(&shard) = self.assigned.get(&id) {
            self.load_tokens[shard] =
                self.load_tokens[shard].saturating_sub((prompt_len + max_new) as u64);
        }
    }
}

/// Handle to a running fleet: a router thread fronting N shard worker
/// threads, each owning its backend and running the full lifecycle
/// scheduler.  The public [`FleetHandle::requests`] sender is what
/// `serve_tcp` plugs into — the fleet is wire-compatible with the
/// single-engine server.
pub struct FleetHandle {
    pub requests: Sender<Request>,
    router: JoinHandle<()>,
    shards: Vec<JoinHandle<Result<()>>>,
}

impl FleetHandle {
    /// Spawn the fleet: `make(shard)` constructs each shard's backend on
    /// its own thread (backends are thread-affine).  The router applies
    /// [`FleetRouter`] policy to every generation request, routes
    /// `Cancel` to the owning shard, and broadcasts `Reload` / `Drain` /
    /// shutdown to every shard.
    pub fn spawn<B, F>(mut router: FleetRouter, make: F) -> FleetHandle
    where
        B: ServeBackend,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let n = router.n_shards();
        let make = std::sync::Arc::new(make);
        let mut shard_txs: Vec<Sender<Request>> = Vec::with_capacity(n);
        let mut shards = Vec::with_capacity(n);
        for s in 0..n {
            let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
            shard_txs.push(tx);
            let make = make.clone();
            shards.push(std::thread::spawn(move || {
                let mut backend = make(s)?;
                super::lifecycle::serve_lifecycle(&mut backend, rx)
            }));
        }
        let (front_tx, front_rx): (Sender<Request>, Receiver<Request>) = channel();
        let router_thread = std::thread::spawn(move || {
            for r in front_rx {
                if r.shutdown {
                    for tx in &shard_txs {
                        let _ = tx.send(Request::shutdown_sentinel());
                    }
                    break;
                }
                if let Some(ctl) = r.control.clone() {
                    match &ctl {
                        ControlMsg::Cancel { req } => {
                            // Unknown ids go to shard 0, which acks the
                            // no-op exactly like the single-engine path.
                            let s = router.shard_of_request(*req).unwrap_or(0);
                            let _ = shard_txs[s].send(r);
                        }
                        ControlMsg::Reload(_) | ControlMsg::Drain => {
                            // Broadcast; every shard acks on the same
                            // stream (clients treat acks as idempotent).
                            for tx in &shard_txs {
                                let mut c = Request::control(ctl.clone(), r.stream.clone());
                                c.arrive_at_us = r.arrive_at_us;
                                let _ = tx.send(c);
                            }
                        }
                    }
                    continue;
                }
                let t = r.arrive_at_us.unwrap_or(0.0);
                let (id, shard) = router.route(&r.prompt, r.max_new, t);
                let mut r = r;
                r.id = Some(id);
                // A dead shard drops the request; its stream disconnects
                // and the client sees the channel close.
                let _ = shard_txs[shard].send(r);
            }
            // front_tx dropped: shard channels close and shards drain.
        });
        FleetHandle { requests: front_tx, router: router_thread, shards }
    }

    /// Convenience mirror of [`super::ServerHandle::submit`].
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests.send(Request::new(prompt, max_new, tx)).expect("fleet router gone");
        rx
    }

    /// Send a control (cancel / reload / drain); broadcasts ack once per
    /// shard for reload/drain.
    pub fn control(&self, msg: ControlMsg) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.requests.send(Request::control(msg, tx)).expect("fleet router gone");
        rx
    }

    /// Shut the fleet down: every shard drains in-flight work, queued
    /// requests fail with [`super::FailReason::Shutdown`], threads join.
    pub fn shutdown(self) -> Result<()> {
        let _ = self.requests.send(Request::shutdown_sentinel());
        drop(self.requests);
        self.router.join().expect("fleet router panicked");
        let mut first_err = None;
        for s in self.shards {
            if let Err(e) = s.join().expect("shard thread panicked") {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn model() -> LatencyModel {
        LatencyModel::from_hardware(&HardwareConfig::env1())
    }

    fn skewed_profile(n_layers: usize, n_experts: usize) -> Profile {
        let mut p = Profile::new(n_layers, n_experts);
        for l in 0..n_layers {
            for e in 0..n_experts {
                // One hot expert per layer, the rest cold.
                p.counts[l][e] = if e == 0 { 1000 } else { 10 };
            }
        }
        p
    }

    #[test]
    fn shard_of_expert_partitions_cover_all_shards() {
        for plan in [ShardPlan::Layer, ShardPlan::Hash] {
            let mut seen = vec![false; 3];
            for l in 0..8 {
                for e in 0..8 {
                    let s = shard_of_expert(plan, l, e, 3);
                    assert!(s < 3);
                    seen[s] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "{plan:?} left a shard empty");
        }
        // Single shard: everything home to 0.
        assert_eq!(shard_of_expert(ShardPlan::Hash, 7, 5, 1), 0);
    }

    #[test]
    fn plan_pricing_reports_bottlenecks_and_auto_picks_min_max() {
        let p = skewed_profile(6, 8);
        let m = model();
        for requested in [ShardPlan::Layer, ShardPlan::Hash] {
            let plan = plan_shards(&p, &m, 3, requested, 2, None);
            assert_eq!(plan.plan, requested);
            assert_eq!(plan.costs.len(), 3);
            for c in &plan.costs {
                assert!(c.step_us() > 0.0);
                assert!(["gpu", "cpu-bw", "pcie"].contains(&c.bottleneck()));
            }
            assert_eq!(plan.bottleneck_summary().split(',').count(), 3);
        }
        let auto = plan_shards(&p, &m, 3, ShardPlan::Auto, 2, None);
        let layer = plan_shards(&p, &m, 3, ShardPlan::Layer, 2, None);
        let hash = plan_shards(&p, &m, 3, ShardPlan::Hash, 2, None);
        assert!(auto.plan == ShardPlan::Layer || auto.plan == ShardPlan::Hash);
        assert!(auto.max_step_us() <= layer.max_step_us() + 1e-9);
        assert!(auto.max_step_us() <= hash.max_step_us() + 1e-9);
    }

    #[test]
    fn full_residency_is_gpu_bound() {
        // Capacity covers every expert: no misses, bottleneck is GPU.
        let p = skewed_profile(2, 4);
        let plan = plan_shards(&p, &model(), 2, ShardPlan::Layer, 100, None);
        for c in &plan.costs {
            assert_eq!(c.bottleneck(), "gpu");
            assert!(c.cpu_us.abs() < 1e-9 && c.pcie_us.abs() < 1e-9);
            assert!(c.quant_us.abs() < 1e-9, "tier off must price no quant mass");
        }
    }

    #[test]
    fn quant_tier_pricing_moves_miss_mass_onto_the_gpu_stream() {
        // Capacity 2 over 8 experts/layer: fp-only thrashes.  With Q8 the
        // same bytes hold 1 fp + 2 quant copies — less miss mass, and the
        // quantized coverage shows up as GPU-stream time.
        let p = skewed_profile(2, 8);
        let m = model();
        let fp = plan_shards(&p, &m, 2, ShardPlan::Layer, 2, None);
        let tier = plan_shards(&p, &m, 2, ShardPlan::Layer, 2, Some(8));
        for (a, b) in fp.costs.iter().zip(&tier.costs) {
            assert!(b.quant_us > 0.0, "quant tier priced no quantized mass");
            assert!(b.cpu_us < a.cpu_us, "tier must shrink the miss stream");
        }
        // The shape of the acceptance criterion: under heavy fp miss, the
        // tiered plan's worst-shard step time is no worse.
        assert!(tier.max_step_us() <= fp.max_step_us() + 1e-9);
    }

    #[test]
    fn worth_admitting_thresholds_on_reuse() {
        let m = model();
        // A hot expert at high arrival rate easily repays one transfer.
        assert!(worth_admitting(0.5, 100.0, 10.0, &m));
        // A cold expert at a trickle does not.
        assert!(!worth_admitting(1e-6, 0.1, 1.0, &m));
        // Zero horizon: nothing is worth admitting.
        assert!(!worth_admitting(1.0, 100.0, 0.0, &m));
    }

    #[test]
    fn pin_worthwhile_respects_caps_and_order() {
        let p = skewed_profile(2, 8);
        let m = model();
        let plan = plan_shards(&p, &m, 1, ShardPlan::Layer, 8, None);
        let mut cache = ExpertCache::with_capacity(8);
        let pinned = pin_worthwhile(&mut cache, &p, &plan, 0, 50.0, 10.0, &m, 3);
        assert!(pinned.len() <= 3);
        assert!(!pinned.is_empty(), "hot experts at heavy load must be pinned");
        // The hot experts come first.
        assert!(pinned.contains(&(0, 0)) || pinned.contains(&(1, 0)));
        assert_eq!(cache.pinned_count(), pinned.len());
        // Idempotent: nothing double-pins.
        let again = pin_worthwhile(&mut cache, &p, &plan, 0, 50.0, 10.0, &m, 3);
        for id in &again {
            assert!(!pinned.contains(id));
        }
        // A dead shard rate pins nothing.
        let mut cold = ExpertCache::with_capacity(8);
        assert!(pin_worthwhile(&mut cold, &p, &plan, 0, 0.0, 10.0, &m, 3).is_empty());
    }

    fn router(n_shards: usize, replicate_hot: f64) -> FleetRouter {
        let p = skewed_profile(4, 8);
        let plan = plan_shards(&p, &model(), n_shards, ShardPlan::Layer, 2, None);
        let t = TransitionProfile::uniform(4, 8);
        FleetRouter::new(plan, Some(t), replicate_hot, EventSink::disabled())
    }

    #[test]
    fn single_shard_routing_is_pass_through() {
        let mut r = router(1, 0.25);
        for i in 0..10u64 {
            let (id, shard) = r.route(&[1, 2, 3], 8, i as f64);
            assert_eq!(id, i, "ids are global ingest order");
            assert_eq!(shard, 0);
        }
    }

    #[test]
    fn routing_is_deterministic_and_balances_load() {
        let route_all = || {
            let mut r = router(3, 0.0);
            (0..30u64)
                .map(|i| r.route(&[i as u32, (i * 7) as u32, (i * 13) as u32], 16, i as f64))
                .collect::<Vec<_>>()
        };
        let a = route_all();
        let b = route_all();
        assert_eq!(a, b, "routing must be deterministic");
        // Every id unique and in ingest order.
        for (i, &(id, shard)) in a.iter().enumerate() {
            assert_eq!(id, i as u64);
            assert!(shard < 3);
        }
        // The balance term keeps any one shard from taking everything.
        let mut per_shard = [0usize; 3];
        for &(_, s) in &a {
            per_shard[s] += 1;
        }
        let used = per_shard.iter().filter(|&&n| n > 0).count();
        assert!(used >= 2, "all load on one shard: {per_shard:?}");
    }

    #[test]
    fn hot_drift_triggers_replica_scale_up() {
        let mut r = router(3, 0.2);
        assert!(r.replicas.iter().flatten().all(|&n| n == 1));
        // Hammer one expert: token 5 → expert 5 at layer 0, every request.
        for i in 0..50u64 {
            r.route(&[5; 16], 8, i as f64);
        }
        assert!(
            r.replicas[0][5] > 1,
            "hot expert (0,5) must gain replicas, got {}",
            r.replicas[0][5]
        );
    }

    #[test]
    fn cancel_routing_knows_the_owning_shard() {
        let mut r = router(3, 0.0);
        let (id, shard) = r.route(&[1, 2, 3, 4], 8, 0.0);
        assert_eq!(r.shard_of_request(id), Some(shard));
        assert_eq!(r.shard_of_request(999), None);
        r.complete(id, 4, 8);
        // Completion releases load but keeps the id known for late cancels.
        assert_eq!(r.shard_of_request(id), Some(shard));
    }
}
