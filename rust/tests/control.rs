//! Adaptive-control-plane contracts (PR 10).
//!
//! The acceptance properties:
//!
//! * `--adaptive off` (the default) is the static pipeline, bit for bit:
//!   the engine matrix below re-runs the PR 5 lookahead x threads
//!   bit-identity sweep with the flag both off and ON — controller
//!   decisions move virtual time, never the arithmetic.
//! * Adaptive runs record -> replay bit-identically under cancels and
//!   injected faults: every controller/estimator decision derives from
//!   virtual-time state the replay reproduces.
//! * The controller converges on a stationary workload instead of
//!   oscillating forever, and the learned-SLO estimator's updates stream
//!   into the trace.
//!
//! Engine-level tests need the build-time artifacts and skip gracefully
//! without them (like `tests/engine.rs`); everything else is
//! artifact-free.

use fiddler::config::serving::{AdmissionKind, Policy, ServingConfig};
use fiddler::config::HardwareConfig;
use fiddler::control::sim::{run_lookahead_sim, LookaheadMode, LookaheadSimConfig};
use fiddler::coordinator::Engine;
use fiddler::events::replay::{diff_replay, fold_trace, read_log, replay_trace};
use fiddler::events::TraceEvent;
use fiddler::figures;
use fiddler::kvcache::SequenceCache;
use fiddler::latency::LatencyModel;
use fiddler::runtime::Tensor;
use fiddler::server::sim::{run_open_loop, LoadSpec};
use fiddler::workload::{Dataset, WorkloadGen};
use std::path::PathBuf;

fn tmp_trace(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fiddler-control-{}-{name}.jsonl", std::process::id()))
}

// ---------------------------------------------------------------- sim level

fn adaptive_serving() -> ServingConfig {
    ServingConfig {
        adaptive: true,
        admission: AdmissionKind::Slo,
        temperature: 0.8, // non-greedy: replay must also match the RNG stream
        prefill_chunk: 16,
        max_batch: 4,
        kv_budget_mb: 8,
        seed: 47,
        ..ServingConfig::default()
    }
}

fn churn_spec() -> LoadSpec {
    LoadSpec {
        n_requests: 20,
        rate_per_s: 6.0,
        inp: 10,
        out: 8,
        long_every: 5,
        long_inp: 64,
        cancel_every: 6,
        cancel_after_us: 40_000.0,
        seed: 29,
        ..LoadSpec::default()
    }
}

/// The flag itself must be inert when off: an explicit `adaptive: false`
/// run is the default run, outcome for outcome.
#[test]
fn adaptive_off_matches_the_default_config() {
    let spec = churn_spec();
    let base = run_open_loop(ServingConfig::default(), &spec).unwrap();
    let off = run_open_loop(ServingConfig { adaptive: false, ..Default::default() }, &spec).unwrap();
    assert_eq!(base.completed, off.completed);
    assert_eq!(base.rejected, off.rejected);
    assert_eq!(base.output_tokens, off.output_tokens);
    assert_eq!(base.makespan_s, off.makespan_s);
    assert_eq!(base.agg.tps, off.agg.tps);
    assert_eq!(base.agg.itl_us, off.agg.itl_us);
}

/// Adaptive record -> replay is bit-identical under client cancels AND
/// injected faults: the estimator's deadline decisions replay exactly
/// because they read only virtual-time state the trace reproduces.
#[test]
fn adaptive_record_replay_bit_identical_under_cancels_and_faults() {
    let path = tmp_trace("replay");
    let serving = ServingConfig {
        events_out: Some(path.display().to_string()),
        faults: Some("stall=0.08:20000,spike=0.05:5000,err=0.03".into()),
        fault_seed: 7,
        ..adaptive_serving()
    };
    let report = run_open_loop(serving, &churn_spec()).unwrap();
    assert!(report.completed > 0);

    let events = read_log(&path).unwrap();
    // The trace must carry the adaptive meta flag and estimator updates.
    let metas: Vec<_> = events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Meta { adaptive, .. } => Some(*adaptive),
            _ => None,
        })
        .collect();
    assert_eq!(metas, vec![true], "meta must record the adaptive flag");
    let slo_updates: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::SloEstimateUpdated { samples, .. } => Some(*samples),
            _ => None,
        })
        .collect();
    assert!(!slo_updates.is_empty(), "adaptive run must stream estimator updates");
    let mut sorted = slo_updates.clone();
    sorted.sort_unstable();
    assert_eq!(slo_updates, sorted, "sample counts must be monotone");

    let rec = fold_trace(&events);
    let outcomes = replay_trace(&rec).unwrap();
    let diffs = diff_replay(&rec, &outcomes);
    assert!(diffs.is_empty(), "adaptive replay diverged: {diffs:?}");
    std::fs::remove_file(&path).ok();
}

/// A legacy trace (no `adaptive` key in meta) replays with the loops
/// disarmed, and the new event kinds survive a lossless rewrite.
#[test]
fn new_event_kinds_round_trip_and_default_off() {
    for ev in TraceEvent::examples() {
        let line = ev.encode_line();
        assert_eq!(TraceEvent::parse_line(&line).unwrap(), ev, "{line}");
    }
    // Lenient decode: missing fields default rather than error.
    let ev = TraceEvent::parse_line(r#"{"ev":"controller_adjusted","pass":"decode"}"#).unwrap();
    assert!(matches!(ev, TraceEvent::ControllerAdjusted { lookahead: 0, .. }));
    let ev = TraceEvent::parse_line(r#"{"ev":"slo_estimate_updated"}"#).unwrap();
    assert!(matches!(ev, TraceEvent::SloEstimateUpdated { samples: 0, .. }));
    // A pre-PR-10 meta line decodes adaptive=false: replay stays static.
    let ev = TraceEvent::parse_line(r#"{"ev":"meta","schema":1}"#).unwrap();
    match ev {
        TraceEvent::Meta { adaptive, .. } => assert!(!adaptive),
        other => panic!("expected meta, got {other:?}"),
    }
}

/// On a stationary workload the cache-sim controller settles: it stops
/// adjusting after the settle phase and holds one window for the long
/// tail of the run.
#[test]
fn controller_converges_on_a_stationary_workload() {
    let cfg = LookaheadSimConfig {
        capacity: 24,
        layers: 8,
        experts: 16,
        top_k: 2,
        seed: 5,
        batch: 16,
        segments: vec![(200, 200)], // one phase: no drift at all
    };
    let lat = LatencyModel::from_hardware(&HardwareConfig::env1());
    let r = run_lookahead_sim(&cfg, &lat, LookaheadMode::Adaptive { start: 1, max: 2 });
    assert!(r.adjustments > 0, "controller never explored");
    assert_eq!(r.final_lookahead, 1, "controller should settle on the paying window");
    // Re-running the same config is deterministic to the last bit.
    let r2 = run_lookahead_sim(&cfg, &lat, LookaheadMode::Adaptive { start: 1, max: 2 });
    assert_eq!(r.mean_step_us, r2.mean_step_us);
    assert_eq!(r.final_lookahead, r2.final_lookahead);
    assert_eq!(r.adjustments, r2.adjustments);
}

// ------------------------------------------------------------- engine level

fn artifacts_available() -> bool {
    figures::artifact_dir("mixtral-tiny").join("weights_manifest.json").exists()
}

fn engine(lookahead: usize, threads: usize, adaptive: bool) -> Engine {
    let serving = ServingConfig {
        policy: Policy::Fiddler,
        pipeline_lookahead: lookahead,
        threads,
        adaptive,
        ..Default::default()
    };
    Engine::new(figures::artifact_dir("mixtral-tiny"), &HardwareConfig::env1(), serving)
        .expect("make artifacts first")
}

fn prompt(len: usize, seed: u64) -> Vec<u32> {
    WorkloadGen::new(Dataset::sharegpt(), 512, seed).prompt(len)
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

/// All forward paths once; hidden-state bits + final KV bits.
fn run_all_paths(lookahead: usize, threads: usize, adaptive: bool) -> Vec<Vec<u32>> {
    let mut e = engine(lookahead, threads, adaptive);
    if adaptive && lookahead > 0 {
        assert!(e.cx.pipeline.controller().is_some(), "adaptive engine must arm the controller");
    }
    let mut out: Vec<Vec<u32>> = Vec::new();

    let p = prompt(24, 11);
    let mut cache = SequenceCache::new(e.model());
    let h = e.runner.prefill(&p, &mut cache, &mut e.cx).unwrap();
    out.push(bits(&h));
    for t in [7u32, 19, 42] {
        let xs = e.runner.ws.embed_tokens(&[t]);
        let mut caches = [&mut cache];
        let h = e.runner.decode_step(&xs, &mut caches, &mut e.cx).unwrap();
        out.push(bits(&h));
    }

    let pc = prompt(30, 23);
    let mut chunk_cache = SequenceCache::new(e.model());
    for range in [0..12usize, 12..22, 22..30] {
        let h = e.runner.prefill_chunk(&pc[range], &mut chunk_cache, &mut e.cx).unwrap();
        out.push(bits(&h));
    }
    out
}

/// The acceptance matrix, with the adaptive dimension added to PR 5's:
/// lookahead {0,1,2} x threads {1,2,4} x adaptive {off,on}, every cell
/// bit-identical to the serial static reference.  Controller decisions
/// (effective window, skew-biased overrides, landing protection) reshape
/// plans and virtual time only — never a single output bit.
#[test]
fn adaptive_matrix_is_bit_identical_to_the_static_reference() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let reference = run_all_paths(0, 1, false);
    assert!(!reference.is_empty());
    for lookahead in [0usize, 1, 2] {
        for threads in [1usize, 2, 4] {
            for adaptive in [false, true] {
                if (lookahead, threads, adaptive) == (0, 1, false) {
                    continue;
                }
                let got = run_all_paths(lookahead, threads, adaptive);
                assert_eq!(
                    got, reference,
                    "lookahead={lookahead} threads={threads} adaptive={adaptive}: \
                     outputs not bit-identical"
                );
            }
        }
    }
}

/// Adaptive on a disabled pipeline (lookahead 0) must not arm anything:
/// there is no speculation to control.
#[test]
fn adaptive_without_lookahead_stays_disarmed() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let e = engine(0, 1, true);
    assert!(e.cx.pipeline.controller().is_none());
}
