//! Properties of the three-tier expert hierarchy (`--quant-tier`):
//! the off state is bit-identical to fp-only serving across threads and
//! pipeline lookahead, the on state round-trips record → replay through
//! the tier event kinds, and engine numerics never change (quantized
//! plans price the low-bit copy but execute at full precision).

use fiddler::config::serving::{Policy, ServingConfig};
use fiddler::config::HardwareConfig;
use fiddler::coordinator::Engine;
use fiddler::events::replay::{diff_replay, fold_trace, read_log, replay_trace};
use fiddler::figures;
use fiddler::server::sim::{run_open_loop, LoadSpec};
use fiddler::util::json::Json;
use fiddler::workload::{Dataset, WorkloadGen};
use std::path::PathBuf;

fn tmp_trace(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fiddler-quant-{}-{name}.jsonl", std::process::id()))
}

fn artifacts_available() -> bool {
    figures::artifact_dir("mixtral-tiny").join("weights_manifest.json").exists()
}

fn prompt(len: usize, seed: u64) -> Vec<u32> {
    WorkloadGen::new(Dataset::sharegpt(), 512, seed).prompt(len)
}

const TIER_KINDS: [&str; 4] = ["tier_promoted", "tier_demoted", "quant_hit", "quant_corrected"];

#[test]
fn tier_off_is_bit_identical_across_threads_and_lookahead() {
    // `--quant-tier off` (the default) must be the seed engine, bit for
    // bit, at every thread count x lookahead combination — and because
    // quantized plans run the fp executable, even `on` with a zero budget
    // cannot change engine tokens.
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let hw = HardwareConfig::env1();
    let p = prompt(16, 90);
    let mut baseline: Option<Vec<u32>> = None;
    for threads in [1usize, 2] {
        for lookahead in [0usize, 2] {
            for (tier, budget) in [(false, 0.0), (true, 0.0), (true, 0.5)] {
                let serving = ServingConfig {
                    policy: Policy::FiddlerCached,
                    threads,
                    pipeline_lookahead: lookahead,
                    quant_tier: tier,
                    quant_bits: 8,
                    error_budget: budget,
                    ..Default::default()
                };
                let mut e =
                    Engine::new(figures::artifact_dir("mixtral-tiny"), &hw, serving).unwrap();
                let tokens = e.generate(&p, 6).unwrap().tokens;
                match &baseline {
                    None => baseline = Some(tokens),
                    Some(b) => assert_eq!(
                        b, &tokens,
                        "tokens changed at threads={threads} lookahead={lookahead} \
                         tier={tier} budget={budget}"
                    ),
                }
            }
        }
    }
}

fn spec() -> LoadSpec {
    LoadSpec {
        n_requests: 16,
        rate_per_s: 5.0,
        inp: 10,
        out: 8,
        long_every: 5,
        long_inp: 96,
        seed: 29,
        ..LoadSpec::default()
    }
}

#[test]
fn tier_off_trace_carries_no_tier_events() {
    let path = tmp_trace("off");
    let serving = ServingConfig {
        events_out: Some(path.display().to_string()),
        seed: 37,
        ..Default::default()
    };
    run_open_loop(serving, &spec()).unwrap();
    let events = read_log(&path).unwrap();
    let kinds: std::collections::BTreeSet<&str> = events.iter().map(|e| e.kind()).collect();
    for k in TIER_KINDS {
        assert!(!kinds.contains(k), "tier off must not emit {k}");
    }
    // And the meta line records the off state for the replayer.
    let text = std::fs::read_to_string(&path).unwrap();
    let meta = Json::parse(text.lines().next().unwrap()).unwrap();
    assert!(!meta.get("quant_tier").unwrap().as_bool().unwrap());
    std::fs::remove_file(&path).ok();
}

#[test]
fn tiered_record_replay_round_trips_bit_identically() {
    // A moderate budget with Q8 errors (~0.004 each) accepts the first
    // few quantized hits of each request and corrects the rest — so ALL
    // four tier event kinds land in the trace, and replay (which rebuilds
    // the tiered config from the meta line) must still match every
    // client-visible token stream.
    let path = tmp_trace("replay");
    let serving = ServingConfig {
        events_out: Some(path.display().to_string()),
        temperature: 0.8,
        prefill_chunk: 16,
        max_batch: 4,
        kv_budget_mb: 8,
        seed: 43,
        quant_tier: true,
        quant_bits: 8,
        error_budget: 0.02,
        ..Default::default()
    };
    let report = run_open_loop(serving, &spec()).unwrap();
    assert!(report.completed > 0);

    let events = read_log(&path).unwrap();
    let kinds: std::collections::BTreeSet<&str> = events.iter().map(|e| e.kind()).collect();
    for k in TIER_KINDS {
        assert!(kinds.contains(k), "tiered run never emitted {k} (has {kinds:?})");
    }
    let meta = Json::parse(std::fs::read_to_string(&path).unwrap().lines().next().unwrap())
        .unwrap();
    assert!(meta.get("quant_tier").unwrap().as_bool().unwrap());
    assert_eq!(meta.get("quant_bits").unwrap().as_usize().unwrap(), 8);

    let rec = fold_trace(&events);
    let outcomes = replay_trace(&rec).unwrap();
    let diffs = diff_replay(&rec, &outcomes);
    assert!(diffs.is_empty(), "tiered replay diverged: {diffs:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn accepted_quant_hits_can_change_sim_tokens_but_zero_budget_cannot() {
    let base = run_open_loop(ServingConfig { seed: 51, ..Default::default() }, &spec()).unwrap();
    let zero = run_open_loop(
        ServingConfig {
            seed: 51,
            quant_tier: true,
            quant_bits: 8,
            error_budget: 0.0,
            ..Default::default()
        },
        &spec(),
    )
    .unwrap();
    assert_eq!(base.outcomes, zero.outcomes, "zero budget must preserve fp numerics");
    let loose = run_open_loop(
        ServingConfig {
            seed: 51,
            quant_tier: true,
            quant_bits: 8,
            error_budget: 1.0,
            ..Default::default()
        },
        &spec(),
    )
    .unwrap();
    // Same completion accounting either way; only token values may drift
    // once hits are accepted.
    assert_eq!(base.completed, loose.completed);
    assert_ne!(
        base.outcomes, loose.outcomes,
        "a generous budget never accepted a hit — tier not exercised"
    );
}
