//! Integration tests over the serving engine: policies, beam search,
//! server, and the dominance relations the paper's figures rest on.

use fiddler::config::serving::{Policy, ServingConfig};
use fiddler::config::HardwareConfig;
use fiddler::coordinator::Engine;
use fiddler::figures;
use fiddler::server::{collect, ServerHandle};
use fiddler::workload::{Dataset, WorkloadGen};

fn engine(policy: Policy, env: &HardwareConfig) -> Engine {
    figures::make_engine("mixtral-tiny", env, policy, 0).expect("make artifacts first")
}

fn prompt(len: usize, seed: u64) -> Vec<u32> {
    WorkloadGen::new(Dataset::sharegpt(), 512, seed).prompt(len)
}

/// The parallel-executor tests run on any host; the full-engine assertions
/// need the build-time artifacts (like every other test in this file) and
/// skip gracefully where their siblings would fail loudly.
fn artifacts_available() -> bool {
    figures::artifact_dir("mixtral-tiny").join("weights_manifest.json").exists()
}

#[test]
fn threads_one_regression_pool_is_inline() {
    // `--threads 1` (the default) must build the serial executor: jobs run
    // on the engine thread, no workers spawned — the pre-parallel engine.
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let hw = HardwareConfig::env1();
    let mut e = engine(Policy::Fiddler, &hw);
    assert_eq!(e.serving.threads, 1);
    assert_eq!(e.cx.threads, 1);
    assert!(e.cx.pool.is_inline());
    let g = e.generate(&prompt(12, 80), 4).unwrap();
    assert_eq!(g.tokens.len(), 4);
}

#[test]
fn thread_count_does_not_change_tokens() {
    // Determinism at the engine level (host kernel off, the default): the
    // executor's reduction order is fixed and the latency model is gated
    // on the host kernel, so --threads changes neither plans nor tokens.
    // The parallel host-kernel dispatch itself is covered bit-for-bit by
    // the property tests in `exec` (which need no artifacts).
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let hw = HardwareConfig::env1();
    let p = prompt(16, 81);
    let mut outs = Vec::new();
    for threads in [1usize, 2, 4] {
        let serving = ServingConfig { threads, ..Default::default() };
        let mut e =
            Engine::new(figures::artifact_dir("mixtral-tiny"), &hw, serving).unwrap();
        assert_eq!(e.cx.pool.threads(), threads);
        outs.push(e.generate(&p, 6).unwrap().tokens);
    }
    assert_eq!(outs[0], outs[1], "threads=2 changed the numerics");
    assert_eq!(outs[0], outs[2], "threads=4 changed the numerics");
}

#[test]
fn threaded_latency_model_gated_on_host_kernel() {
    // The engine must never plan against a speedup it does not realize:
    // with the host kernel off (the pool only accelerates the host-kernel
    // path) a threaded engine keeps the single-core latency model, so
    // plans — and the simulated timeline — are identical to --threads 1.
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    assert!(
        !fiddler::cpukernel::host_kernel_enabled(),
        "this test assumes FIDDLER_HOST_KERNEL is unset"
    );
    let hw = HardwareConfig::env1();
    let p = prompt(32, 82);
    let mut runs = Vec::new();
    for threads in [1usize, 8] {
        let serving = ServingConfig { threads, ..Default::default() };
        let mut e =
            Engine::new(figures::artifact_dir("mixtral-tiny"), &hw, serving).unwrap();
        assert!(
            (e.cx.lat.cpu_per_token_us
                - fiddler::latency::LatencyModel::from_hardware(&hw).cpu_per_token_us)
                .abs()
                < 1e-12,
            "threads={threads}: latency model scaled without the host kernel"
        );
        let g = e.generate(&p, 8).unwrap();
        runs.push((g.tokens, e.cx.events.cpu, e.cx.clock.now_us()));
    }
    assert_eq!(runs[0].0, runs[1].0, "tokens diverged");
    assert_eq!(runs[0].1, runs[1].1, "CPU expert events diverged");
    assert!((runs[0].2 - runs[1].2).abs() < 1e-6, "virtual time diverged");
}

#[test]
fn all_policies_generate_identical_tokens() {
    // Policies differ ONLY in time accounting, never in numerics.  The
    // extensions (prefetch, dynamic cache) must obey the same contract.
    let hw = HardwareConfig::env1();
    let p = prompt(16, 1);
    let mut policies = figures::ALL_POLICIES.to_vec();
    policies.push(Policy::FiddlerPrefetch);
    policies.push(Policy::FiddlerCached);
    let mut outs = Vec::new();
    for pol in policies {
        let mut e = engine(pol, &hw);
        outs.push(e.generate(&p, 6).unwrap().tokens);
    }
    for o in &outs[1..] {
        assert_eq!(o, &outs[0], "policy changed the numerics");
    }
}

#[test]
fn cached_policy_reports_cache_stats() {
    let hw = HardwareConfig::env1();
    let serving = ServingConfig {
        policy: Policy::FiddlerCached,
        cache_eviction: fiddler::config::serving::EvictionKind::TransitionAware,
        ..Default::default()
    };
    let mut e = Engine::new(figures::artifact_dir("mixtral-tiny"), &hw, serving).unwrap();
    let g = e.generate(&prompt(16, 50), 8).unwrap();
    let stats = g.metrics.cache.expect("cache stats missing from metrics");
    assert!(stats.lookups() > 0, "no cache lookups recorded");
    assert!(stats.hits > 0, "pinned popular experts must produce hits");
    // Residency never exceeds the scaled capacity.
    assert!(e.cx.memory.resident_count() <= e.cx.memory.capacity());
}

#[test]
fn fiddler_beats_offloaders_on_decode() {
    // Scenario (a) regime: decode-dominated workload. The paper's Fig. 4:
    // offloading baselines pay a weight transfer per missing expert per
    // token and land well below Fiddler.
    let hw = HardwareConfig::env1();
    let p = prompt(32, 2);
    let mut tps = std::collections::HashMap::new();
    for &pol in figures::ALL_POLICIES {
        let mut e = engine(pol, &hw);
        let g = e.generate(&p, 16).unwrap();
        tps.insert(pol.label(), g.metrics.tokens_per_s());
    }
    let f = tps["Fiddler"];
    assert!(f > tps["DeepSpeed-MII*"], "{tps:?}");
    assert!(f > tps["Mixtral-Offloading*"], "{tps:?}");
    assert!(f > tps["llama.cpp*"], "{tps:?}");
}

#[test]
fn offloaders_beat_llamacpp_on_long_prefill() {
    // Scenario (b) regime (Fig. 5): for long prompts the GPU-streaming
    // approaches win over CPU-bound static split; Fiddler is best overall.
    let hw = HardwareConfig::env1();
    let p = prompt(512, 3);
    let mut ttft = std::collections::HashMap::new();
    for &pol in figures::ALL_POLICIES {
        let mut e = engine(pol, &hw);
        let (_tok, us) = e.prefill_ttft(&p).unwrap();
        ttft.insert(pol.label(), us);
    }
    assert!(ttft["Fiddler"] < ttft["llama.cpp*"], "{ttft:?}");
    assert!(ttft["DeepSpeed-MII*"] < ttft["llama.cpp*"], "{ttft:?}");
    assert!(ttft["Fiddler"] <= ttft["DeepSpeed-MII*"] * 1.05, "{ttft:?}");
}

#[test]
fn beam_search_gap_grows_with_width() {
    // Scenario (c) regime (Fig. 6): Fiddler batches beams; llama.cpp
    // decodes them serially. The speedup must grow with width.
    let hw = HardwareConfig::env1();
    let p = prompt(16, 4);
    let mut ratios = Vec::new();
    for width in [2usize, 8] {
        let mut f = engine(Policy::Fiddler, &hw);
        let bf = f.beam_search(&p, width, 4).unwrap();
        let mut l = engine(Policy::StaticSplit, &hw);
        let bl = l.beam_search(&p, width, 4).unwrap();
        assert_eq!(bf.tokens, bl.tokens, "beam numerics differ");
        ratios.push(bf.metrics.tokens_per_s() / bl.metrics.tokens_per_s());
    }
    assert!(ratios[0] > 1.0, "fiddler not faster at width 2: {ratios:?}");
    assert!(ratios[1] > ratios[0], "gap does not grow: {ratios:?}");
}

#[test]
fn beam_search_scores_monotone_and_sorted() {
    let hw = HardwareConfig::env2();
    let mut e = engine(Policy::Fiddler, &hw);
    let p = prompt(8, 5);
    let b4 = e.beam_search(&p, 4, 6).unwrap();
    assert_eq!(b4.tokens.len(), 6);
    assert!(b4.score.is_finite() && b4.score < 0.0);

    // Wider beam can only improve (or match) the best score.
    let mut e2 = engine(Policy::Fiddler, &hw);
    let b8 = e2.beam_search(&p, 8, 6).unwrap();
    assert!(b8.score >= b4.score - 1e-4, "wider beam got worse: {} vs {}", b8.score, b4.score);
}

#[test]
fn beam_width_1_equals_greedy() {
    let hw = HardwareConfig::env1();
    let p = prompt(12, 6);
    let mut a = engine(Policy::Fiddler, &hw);
    let greedy = a.generate(&p, 5).unwrap().tokens;
    let mut b = engine(Policy::Fiddler, &hw);
    let beam = b.beam_search(&p, 1, 5).unwrap().tokens;
    assert_eq!(greedy, beam);
}

#[test]
fn placement_popularity_beats_worst() {
    let hw = HardwareConfig::env1();
    let p = prompt(32, 7);
    let mut tps = Vec::new();
    for placement in ["popularity", "worst"] {
        let mut serving = ServingConfig::default();
        serving.placement =
            fiddler::config::serving::PlacementStrategy::by_name(placement).unwrap();
        let mut e =
            Engine::new(figures::artifact_dir("mixtral-tiny"), &hw, serving).unwrap();
        let g = e.generate(&p, 12).unwrap();
        tps.push((g.metrics.tokens_per_s(), e.cx.events.hit_rate()));
    }
    assert!(
        tps[0].1 > tps[1].1,
        "popularity placement hit rate not better: {tps:?}"
    );
    assert!(tps[0].0 >= tps[1].0 * 0.98, "popularity placement slower: {tps:?}");
}

#[test]
fn server_continuous_batching_serves_all() {
    let hw = HardwareConfig::env1();
    let handle = ServerHandle::spawn(move || {
        figures::make_engine("mixtral-tiny", &hw, Policy::Fiddler, 0)
    });
    let rxs: Vec<_> = (0..5)
        .map(|i| handle.submit(prompt(8 + i, 10 + i as u64), 6))
        .collect();
    for rx in &rxs {
        let (tokens, m) = collect(rx).unwrap();
        assert_eq!(tokens.len(), 6);
        assert!(m.ttft_us() > 0.0);
        assert!(m.tokens_per_s() > 0.0);
    }
    handle.shutdown().unwrap();
}

#[test]
fn server_batched_equals_sequential_numerics() {
    // Continuous batching must not change tokens vs one-at-a-time serving.
    let hw = HardwareConfig::env1();
    let prompts: Vec<Vec<u32>> = (0..3).map(|i| prompt(10, 20 + i)).collect();

    let mut sequential = Vec::new();
    {
        let mut e = engine(Policy::Fiddler, &hw);
        for p in &prompts {
            sequential.push(e.generate(p, 5).unwrap().tokens);
        }
    }
    let hw2 = hw.clone();
    let handle = ServerHandle::spawn(move || {
        figures::make_engine("mixtral-tiny", &hw2, Policy::Fiddler, 0)
    });
    let rxs: Vec<_> =
        prompts.iter().map(|p| handle.submit(p.clone(), 5)).collect();
    for (rx, want) in rxs.iter().zip(&sequential) {
        let (tokens, _) = collect(rx).unwrap();
        assert_eq!(&tokens, want, "batched decode changed the tokens");
    }
    handle.shutdown().unwrap();
}

#[test]
fn env2_faster_than_env1_for_fiddler() {
    let p = prompt(32, 30);
    let mut e1 = engine(Policy::Fiddler, &HardwareConfig::env1());
    let g1 = e1.generate(&p, 8).unwrap();
    let mut e2 = engine(Policy::Fiddler, &HardwareConfig::env2());
    let g2 = e2.generate(&p, 8).unwrap();
    assert!(
        g2.metrics.tokens_per_s() > g1.metrics.tokens_per_s(),
        "env2 ({:.2} tok/s) not faster than env1 ({:.2} tok/s)",
        g2.metrics.tokens_per_s(),
        g1.metrics.tokens_per_s()
    );
}

#[test]
fn online_profile_accumulates_routing() {
    let hw = HardwareConfig::env1();
    let mut e = engine(Policy::Fiddler, &hw);
    let p = prompt(32, 40);
    e.generate(&p, 4).unwrap();
    let total = e.cx.online_profile.total();
    // (32 prompt tokens + 3 decode steps) x top-2 x n_layers (4) = 280.
    assert_eq!(total, (32 + 3) * 2 * 4);
}
